"""Serving-tier load benchmark — the continuous-batching scheduler under
open- and closed-loop traffic, per backend, per worker count.

Two load models (the standard serving-benchmark pair, scope-correct in the
sense of Plagwitz et al.'s "To Spike or Not to Spike?" critique — the same
requests, the same artifact, only the runtime behind the lanes changes):

  * open-loop — Poisson arrivals at a fixed offered rate (derived from a
    measured calibration batch so the bench self-scales to the machine):
    requests are submitted on a schedule regardless of completions, so
    queueing delay shows up in the percentiles — the "heavy traffic" view;
  * closed-loop — C concurrent clients, each submit → block on result() →
    submit again: the interactive view, bounded concurrency.

Every row reports request-latency percentiles (p50/p95/p99), throughput,
queue-depth and batch-fill stats from the scheduler's own account, plus the
accelerator/system scope split. ``--check`` exits non-zero unless EVERY
served label is bit-exact with the software reference — continuous batching,
padding, lane count, and the overflow reroute must not change a single
answer (the paper's single-artifact discipline, extended to the serving
tier).

``--trace-out DIR`` runs every scenario under a fresh telemetry ``Tracer``
(request/batch span trees from the scheduler down through the runtimes),
attaches a ``telemetry`` block to each row, and dumps the full span tree as
``serving_<spec>_w<workers>.trace.jsonl`` into DIR for any scenario whose
labels are NOT bit-exact — the trace shows exactly which lane/batch served
the bad answer.

Emits ``results/bench/serving_load.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import os
import sys
import threading
import time

import numpy as np

from benchmarks import common as CM
from repro.core.reference import SNNReference
from repro.serving.scheduler import ServingError, ServingScheduler
from repro.telemetry import export as texport
from repro.telemetry import trace as ttrace
from repro.telemetry.trace import Tracer

SPECS = ("accelerator-event-fused", "board-batched")
WORKER_COUNTS = (1, 2)
MAX_BATCH = 32
MAX_WAIT_US = 2000.0


def _poisson_open_loop(sched: ServingScheduler, images: np.ndarray,
                       n: int, rate: float, seed: int) -> tuple[list, float]:
    """Submit ``n`` requests with Exp(1/rate) inter-arrival gaps; returns
    (rids in submit order, wall seconds from first submit to full drain)."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    rids = []
    t0 = time.perf_counter()
    t_next = t0
    for i in range(n):
        t_next += gaps[i]
        now = time.perf_counter()
        if t_next > now:
            time.sleep(t_next - now)
        rids.append(sched.submit(images[i % len(images)]))
    done = sched.drain()
    wall = time.perf_counter() - t0
    return [done[r] for r in rids], wall


def _closed_loop(sched: ServingScheduler, images: np.ndarray,
                 n: int, clients: int) -> tuple[list, float]:
    """C clients, each serially submit → result() → next; returns completed
    requests tagged with their image index, plus wall seconds."""
    results: list[tuple[int, object]] = []
    lock = threading.Lock()

    def client(c: int) -> None:
        for i in range(c, n, clients):
            try:
                req = sched.result(sched.submit(images[i % len(images)]),
                                   timeout=300.0)
            except ServingError as e:
                req = e.request      # errored requests are reported, not lost
            with lock:
                results.append((i, req))

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return results, wall


def _labels_exact(results: list, want: np.ndarray, pool_n: int) -> bool:
    """True iff every request completed without error AND with the reference
    label for its image index; errored requests (label=None) are reported,
    not crashed on."""
    errs = [(i, r.error) for i, r in results if r.error is not None]
    if errs:
        for i, msg in errs[:5]:
            print(f"request for image {i} failed: {msg}", file=sys.stderr)
        return False
    return all(int(r.label) == int(want[i % pool_n]) for i, r in results)


def _row(spec: str, load: str, workers: int, n: int, wall: float,
         st: dict, exact: bool, extra: dict | None = None) -> dict:
    row = {
        "runtime": spec,
        "config": f"{load}-w{workers}",
        "scope": f"serving ({load} load, system wall-clock + scheduler "
                 "account)",
        "workers": workers,
        "n_images": n,
        "max_batch": st["max_batch"],
        "max_wait_us": st["max_wait_us"],
        "throughput_img_per_s": n / wall,
        "p50_latency_us": st["p50_latency_us"],
        "p95_latency_us": st["p95_latency_us"],
        "p99_latency_us": st["p99_latency_us"],
        "mean_latency_us": st["mean_latency_us"],
        "accel_us_per_image": st["accel_us_per_image"],
        "system_us_per_image": st["system_us_per_image"],
        "batches": st["batches"],
        "batch_fill_mean": st["batch_fill_mean"],
        "queue_depth_mean": st["queue_depth_mean"],
        "queue_depth_peak": st["queue_depth_peak"],
        "overflow_fallbacks": st["overflow_fallbacks"],
        "labels_bitexact": exact,
    }
    for key in ("board_cycles_per_image", "board_model_us_per_image",
                "board_nj_per_image"):
        if key in st:
            row[key] = st[key]
    if extra:
        row.update(extra)
    return row


def main(quick: bool = False, check: bool = False,
         trace_out: str | None = None) -> int:
    art, xte, yte = CM.get_artifact_and_data(quick=quick)
    n = 128 if quick else 512
    pool = xte[:min(len(xte), 256)]
    want = np.asarray(SNNReference(art).forward(pool).labels)
    clients = 4 if quick else 8

    rows, ok = [], True
    for spec in SPECS:
        for workers in WORKER_COUNTS:
            tracer = Tracer() if trace_out else None
            prev = ttrace.install(tracer) if tracer else None
            scenario_exact = True
            try:
                sched = ServingScheduler(art, spec=spec, workers=workers,
                                         max_batch=MAX_BATCH,
                                         max_wait_us=MAX_WAIT_US)
                with sched:
                    # calibrate: one full batch warms every lane's compiled
                    # program; a second timed one measures steady-state
                    # service
                    for _ in range(max(2, workers)):
                        for i in range(MAX_BATCH):
                            sched.submit(pool[i])
                        sched.drain()
                    t0 = time.perf_counter()
                    for i in range(MAX_BATCH):
                        sched.submit(pool[i])
                    sched.drain()
                    t_batch = time.perf_counter() - t0
                    # offer ~70% of one lane's measured capacity per worker:
                    # under saturation (drain terminates fast) but bursty
                    # enough that batches actually fill
                    rate = 0.7 * workers * MAX_BATCH / max(t_batch, 1e-6)

                    sched.reset_stats()
                    served, wall = _poisson_open_loop(sched, pool, n, rate,
                                                      seed=0)
                    exact = _labels_exact(
                        [(i, r) for i, r in enumerate(served)], want,
                        len(pool))
                    ok &= exact
                    scenario_exact &= exact
                    rows.append(_row(spec, "open-loop-poisson", workers, n,
                                     wall, sched.stats(), exact,
                                     {"offered_rate_img_per_s": rate}))

                    sched.reset_stats()
                    results, wall = _closed_loop(sched, pool, n, clients)
                    exact = (len(results) == n
                             and _labels_exact(results, want, len(pool)))
                    ok &= exact
                    scenario_exact &= exact
                    rows.append(_row(spec, "closed-loop", workers, n, wall,
                                     sched.stats(), exact,
                                     {"clients": clients}))
            finally:
                if tracer is not None:
                    ttrace.install(prev)
            if tracer is not None:
                tele = {"span_count": len(tracer.spans),
                        "dropped_spans": tracer.dropped}
                rows[-1]["telemetry"] = dict(tele)
                rows[-2]["telemetry"] = dict(tele)
                if not scenario_exact:
                    path = os.path.join(
                        trace_out, f"serving_{spec}_w{workers}.trace.jsonl")
                    n_spans = texport.write_jsonl(tracer, path)
                    print(f"trace for non-exact scenario dumped to {path} "
                          f"({n_spans} spans)", file=sys.stderr)
    CM.emit("serving_load", rows)

    for r in rows:
        print(f"{r['runtime']:<26} {r['config']:<22} "
              f"tput {r['throughput_img_per_s']:8.1f} img/s   "
              f"p50 {r['p50_latency_us']:9.1f} us  "
              f"p95 {r['p95_latency_us']:9.1f} us  "
              f"p99 {r['p99_latency_us']:9.1f} us  "
              f"fill {r['batch_fill_mean']:5.1f}  "
              f"{'exact' if r['labels_bitexact'] else 'MISMATCH'}")

    if check:
        loads = {(r["config"].rsplit("-w", 1)[0], r["workers"])
                 for r in rows}
        for load in ("open-loop-poisson", "closed-loop"):
            if len({w for lo, w in loads if lo == load}) < 2:
                print(f"CHECK FAILED: fewer than 2 worker counts for {load}",
                      file=sys.stderr)
                return 1
        if not ok:
            print("CHECK FAILED: served labels are not bit-exact with the "
                  "software reference", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small test split + fewer requests")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every served label matches the "
                         "software reference bit-exactly")
    ap.add_argument("--trace-out", default=None, metavar="DIR",
                    help="record telemetry span trees per scenario and dump "
                         "JSONL traces for non-bit-exact scenarios into DIR")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check, trace_out=a.trace_out))
