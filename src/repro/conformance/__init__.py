"""Cross-runtime differential conformance suite.

The paper's central claim is semantics preservation: ONE exported artifact,
and every runtime that consumes it — software reference, accelerator
(jnp/pallas/fused), board emulator (scheduler/batched) — produces bit-exact
labels and first-spike times. The repo's agreement harness proves that on the
single trained MNIST artifact; this package generalizes the claim to *any
valid artifact*:

  * ``fuzz``    — generates random valid deployment artifacts (topologies,
    quantization, thresholds, leak shifts, decode metadata) plus adversarial
    event streams (floods, never-spike rows, exact-E_max boundaries,
    tie-heavy spike times);
  * ``oracles`` — runs every advertised runtime spec on the same fuzzed
    artifact and asserts the full oracle stack (registry consistency,
    label/first-spike/membrane bit-exactness, scheduler<->batched trace
    equivalence, FIFO never-drops, cycle/energy cost-model consistency,
    quantization error bounds);
  * ``golden``  — pinned-seed golden traces under ``tests/golden/`` with a
    regeneration CLI, so reference-semantics drift is caught even when every
    runtime drifts together.

``benchmarks/bench_conformance.py --check`` is the gate wired into
``scripts/check.sh`` and CI.
"""

from repro.conformance.fuzz import FuzzedCase, fuzz_case, images_from_times
from repro.conformance.oracles import ConformanceReport, OracleOutcome, run_case
from repro.conformance import golden

__all__ = ["FuzzedCase", "fuzz_case", "images_from_times",
           "ConformanceReport", "OracleOutcome", "run_case", "golden"]
