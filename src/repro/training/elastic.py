"""Elastic scaling + straggler mitigation primitives (pure, unit-tested).

At 1000+-node scale the failure model is: hosts drop out, re-join, or run
slow. The policy layer here is deliberately deterministic so every surviving
host computes the SAME new assignment with no coordinator:

  * ``shard_assignment``: data shards -> hosts, rendezvous-hash style;
  * ``rebalance``: minimal-movement reassignment after a failure (only the
    failed host's shards move);
  * ``StragglerMonitor``: flags hosts whose step time exceeds k x median over
    a sliding window; the training loop responds by shrinking that host's
    microbatch share (work stealing) or triggering rebalance;
  * the TokenPipeline (data/tokens.py) being a pure function of
    (seed, step, host) is what makes all of this recoverable: any host can
    recompute any shard of any step.
"""

from __future__ import annotations

import collections
import hashlib
from typing import Sequence


def _score(shard: int, host: str) -> int:
    h = hashlib.sha256(f"{shard}:{host}".encode()).digest()
    return int.from_bytes(h[:8], "big")


def shard_assignment(hosts: Sequence[str], n_shards: int) -> dict[int, str]:
    """Rendezvous hashing: shard -> argmax_host score(shard, host).
    Deterministic, coordinator-free, minimal movement under host churn."""
    assert hosts, "no live hosts"
    return {s: max(hosts, key=lambda h: _score(s, h)) for s in range(n_shards)}


def rebalance(assignment: dict[int, str], live_hosts: Sequence[str]
              ) -> tuple[dict[int, str], list[int]]:
    """Reassign only shards whose host died. Returns (new_assignment,
    moved_shards)."""
    live = set(live_hosts)
    moved = []
    new = {}
    for s, h in assignment.items():
        if h in live:
            new[s] = h
        else:
            new[s] = max(live_hosts, key=lambda x: _score(s, x))
            moved.append(s)
    return new, sorted(moved)


class StragglerMonitor:
    def __init__(self, window: int = 20, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self.times: dict[str, collections.deque] = {}

    def record(self, host: str, step_time: float) -> None:
        self.times.setdefault(
            host, collections.deque(maxlen=self.window)).append(step_time)

    def _median(self, xs: list[float]) -> float:
        xs = sorted(xs)
        n = len(xs)
        return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])

    def stragglers(self) -> list[str]:
        per_host = {h: self._median(list(t)) for h, t in self.times.items() if t}
        if len(per_host) < 2:
            return []
        med = self._median(list(per_host.values()))
        if med <= 0:
            return []
        return sorted(h for h, t in per_host.items()
                      if t > self.threshold * med)

    def work_shares(self, hosts: Sequence[str]) -> dict[str, float]:
        """Inverse-speed work split (straggler gets proportionally less)."""
        med = {h: self._median(list(self.times.get(h, [1.0])) or [1.0])
               for h in hosts}
        inv = {h: 1.0 / max(t, 1e-9) for h, t in med.items()}
        z = sum(inv.values())
        return {h: v / z for h, v in inv.items()}
