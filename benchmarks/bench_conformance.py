"""Cross-runtime differential conformance gate — the fuzzing agreement bench.

Generates N random valid deployment artifacts plus adversarial event streams
(``repro.conformance.fuzz``), runs EVERY advertised runtime spec on each, and
asserts the full oracle stack (``repro.conformance.oracles``): registry
consistency, label/first-spike/membrane bit-exactness vs the software
reference, scheduler<->batched trace equivalence, FIFO never-drops,
cycle/energy cost-model consistency, and quantization error bounds. Then
verifies the pinned-seed golden traces under ``tests/golden/``
(``repro.conformance.golden``) so reference-semantics drift is caught even
when every runtime drifts together.

    --quick   25 fuzzed artifacts (the check.sh / CI configuration)
    --check   exit non-zero on ANY oracle failure or golden drift; failing
              cases are dumped to results/conformance_failures/ (artifact
              .npz + images + JSON report) so drift is reproducible offline —
              CI uploads that directory as a workflow artifact
    --regen   rewrite tests/golden/ instead of checking it (commit the diff)

Emits ``results/bench/conformance.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys
import time

import numpy as np

from benchmarks import common as CM
from repro.conformance import fuzz_case, golden, run_case
from repro.core.runtimes import ADVERTISED_SPECS

SEED_BASE = 1000   # disjoint from golden.PINNED_SEEDS
FAIL_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                        "conformance_failures")


def _dump_failure(case, report) -> str:
    """Persist a failing fuzzed case so it is reproducible from the seed OR
    from the dumped artifact alone (CI uploads this directory)."""
    d = os.path.join(FAIL_DIR, f"seed{case.seed}")
    os.makedirs(d, exist_ok=True)
    case.artifact.save(os.path.join(d, "artifact.npz"))
    np.save(os.path.join(d, "images.npy"), case.images)
    with open(os.path.join(d, "report.json"), "w") as f:
        json.dump({"seed": case.seed, "notes": case.notes,
                   "failures": [dataclasses.asdict(o)
                                for o in report.failures()]},
                  f, indent=1, default=str)
    return d


def _dump_golden_drift(diffs) -> str:
    os.makedirs(FAIL_DIR, exist_ok=True)
    path = os.path.join(FAIL_DIR, "golden_drift.txt")
    with open(path, "w") as f:
        f.write("\n".join(str(d) for d in diffs) + "\n")
    return path


def main(quick: bool = False, check: bool = False, regen: bool = False,
         cases: int | None = None) -> int:
    n_cases = cases if cases is not None else (25 if quick else 40)
    if os.path.isdir(FAIL_DIR):      # stale repros must not mask a green run
        shutil.rmtree(FAIL_DIR)
    t0 = time.perf_counter()

    per_spec = {s: {"img": 0, "label_mm": 0, "first_mm": 0, "alias": 0}
                for s in ADVERTISED_SPECS if s != "reference"}
    per_oracle: dict[str, list[int]] = {}
    boundary_hits = failed_cases = 0
    failures: list[str] = []

    for i in range(n_cases):
        case = fuzz_case(SEED_BASE + i)
        report = run_case(case)
        boundary_hits += int(case.notes["e_max_boundary_hit"])
        for o in report.outcomes:
            per_oracle.setdefault(o.oracle, [0, 0])
            per_oracle[o.oracle][0] += int(o.passed)
            per_oracle[o.oracle][1] += 1
            if o.oracle == "differential" and o.spec in per_spec:
                if "alias" in o.detail:
                    per_spec[o.spec]["alias"] += 1
                else:
                    per_spec[o.spec]["img"] += o.stats.get("img", 0)
                    per_spec[o.spec]["label_mm"] += o.stats.get("labels", 0)
                    per_spec[o.spec]["first_mm"] += o.stats.get(
                        "first_spike", 0)
        if not report.passed:
            failed_cases += 1
            d = _dump_failure(case, report)
            failures.append(report.summary() + f"\n  repro dumped to {d}")

    # ---- golden traces ---------------------------------------------------
    if regen:
        manifest = golden.regen()
        golden_diffs = []
        print(f"regenerated {len(manifest['seeds'])} golden snapshots under "
              f"{golden.GOLDEN_DIR} — commit the diff")
    else:
        golden_diffs = golden.check()
        if golden_diffs:
            failures.append("golden drift:\n  " +
                            "\n  ".join(str(d) for d in golden_diffs))
            _dump_golden_drift(golden_diffs)

    wall = time.perf_counter() - t0

    # ---- emit ------------------------------------------------------------
    rows = []
    for spec, st in sorted(per_spec.items()):
        rows.append({
            "runtime": spec,
            "scope": "conformance (differential vs software reference)",
            "cases": n_cases,
            "img_checked": st["img"],
            "alias_credited_cases": st["alias"],
            "label_mismatch_img": st["label_mm"],
            "first_spike_mismatch_img": st["first_mm"],
            "bitexact_pct": 100.0 if (st["label_mm"] + st["first_mm"]) == 0
            else 100.0 * (1 - (st["label_mm"] + st["first_mm"]) /
                          max(1, 2 * st["img"])),
        })
    for oracle, (npass, ntot) in sorted(per_oracle.items()):
        rows.append({"stage": f"oracle:{oracle}",
                     "scope": "conformance (oracle stack)",
                     "cases": ntot,
                     "cases_pass_pct": 100.0 * npass / max(1, ntot)})
    rows.append({"stage": "golden",
                 "scope": "conformance (golden traces, pinned seeds)",
                 "seeds": list(golden.PINNED_SEEDS),
                 "regenerated": bool(regen),
                 "drift_pct": 0.0 if not golden_diffs else
                 100.0 * len(golden_diffs) / max(1, len(golden.PINNED_SEEDS))})
    rows.append({"stage": "fuzzer", "scope": "conformance (generator)",
                 "cases": n_cases, "seed_base": SEED_BASE,
                 "e_max_boundary_hit_pct": 100.0 * boundary_hits /
                 max(1, n_cases),
                 "wall_s": wall})
    CM.emit("conformance", rows)

    # ---- report ----------------------------------------------------------
    print(f"conformance: {n_cases} fuzzed artifacts x "
          f"{len(ADVERTISED_SPECS)} advertised specs in {wall:.1f}s "
          f"({boundary_hits} exact-E_max boundary cases)")
    for oracle, (npass, ntot) in sorted(per_oracle.items()):
        print(f"  oracle {oracle:<22} {npass}/{ntot} cases")
    print(f"  golden {'regen' if regen else 'check':<22} "
          f"{len(golden.PINNED_SEEDS) - len(set(d.seed for d in golden_diffs))}"
          f"/{len(golden.PINNED_SEEDS)} seeds")
    for f in failures:
        print(f"\n{f}", file=sys.stderr)
    ok = failed_cases == 0 and not golden_diffs
    print(f"conformance gate: {'OK' if ok else 'FAILED'}")

    if check and not ok:
        print(f"CHECK FAILED: {failed_cases} fuzzed cases and "
              f"{len(golden_diffs)} golden arrays disagree — repros under "
              f"{os.path.normpath(FAIL_DIR)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="25 fuzzed artifacts (the CI configuration)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any oracle failure or golden drift")
    ap.add_argument("--regen", action="store_true",
                    help="rewrite tests/golden/ instead of checking it")
    ap.add_argument("--cases", type=int, default=None,
                    help="override the fuzzed-artifact count")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check, regen=a.regen, cases=a.cases))
