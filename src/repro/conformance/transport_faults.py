"""Fault-injecting transport proxy + the *detected-or-bit-exact* invariant.

The network transport's whole value is the guarantee it makes under
corruption: a follower either reconstructs a program FINGERPRINT-IDENTICAL
to the leader's, or fails loudly with a typed error naming the corruption —
never a silently divergent program. This module is the adversarial harness
that proves it: ``FaultyProxy`` sits between a real ``ProgramServer`` and a
real ``fetch_bytes`` client as an in-process TCP proxy, and applies one
packet-level fault per scenario — truncations at every frame boundary,
flipped header/payload bytes, re-framed tampering (a "smart" attacker who
recomputes the frame checksum over a modified envelope, so only the
program-layer fingerprints can catch it), stale envelope replays, duplicate
frames, mid-envelope connection resets, stalled and slow-loris writers —
plus transient variants that fault the first connection(s) and then heal,
exercising the retry arm end to end.

``run_scenario`` classifies each fetch into one of

  * ``bitexact``          — fetch + ``deserialize_program`` succeeded and the
                            program fingerprint equals the leader's;
  * ``detected``          — a typed ``TransportError`` / ``ProgramIOError``
                            named the corruption;
  * ``silent-divergence`` — success with a DIFFERENT fingerprint (the
                            invariant violation this suite exists to forbid);
  * ``unexpected-error``  — an untyped crash (also a violation: failures
                            must be diagnosable).

``benchmarks/bench_transport.py --check`` sweeps every scenario; the
``transport`` conformance oracle runs a seed-rotated window per fuzzed case.
"""

from __future__ import annotations

import dataclasses
import random
import socket
import threading
import time

from repro.core.program_io import ProgramIOError, deserialize_program
from repro.distributed import transport as tp

#: every scenario's client runs with these tight-but-real bounds so the
#: persistent stall/reset cases resolve in well under a second
CLIENT_KW = dict(connect_timeout_s=1.0, read_timeout_s=0.08, retries=2,
                 backoff_s=0.01)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One packet-level fault. ``kind`` names the primitive the proxy
    applies; ``expect`` is the invariant arm the scenario must land on;
    ``faulty_conns`` bounds how many connections see the fault (a huge
    default = persistent; 1–2 = transient, healed by the retry arm)."""

    name: str
    kind: str
    expect: str                 # "bitexact" | "detected"
    faulty_conns: int = 1 << 30
    note: str = ""


SCENARIOS: tuple[Scenario, ...] = (
    Scenario("clean", "clean", "bitexact",
             note="control: the proxy forwards verbatim"),
    # ---- truncations at every frame boundary --------------------------
    Scenario("truncate-header", "truncate-header", "detected",
             note="3 bytes of a 45-byte header, then close"),
    Scenario("truncate-mid-payload", "truncate-mid", "detected",
             note="half the frame, then close"),
    Scenario("truncate-last-byte", "truncate-tail", "detected",
             note="everything but the final byte"),
    Scenario("empty-close", "empty", "detected",
             note="accept then close without a byte"),
    # ---- corrupt headers ----------------------------------------------
    Scenario("flip-magic", "flip-magic", "detected"),
    Scenario("flip-version", "flip-version", "detected"),
    Scenario("length-overflow", "length-huge", "detected",
             note="length field claims 2**48 bytes"),
    Scenario("length-short", "length-short", "detected",
             note="length field shrunk by 7 — checksum catches it"),
    Scenario("length-long", "length-long", "detected",
             note="length field grown by 7 — truncation catches it"),
    Scenario("flip-checksum", "flip-checksum", "detected"),
    Scenario("junk-bytes", "junk", "detected",
             note="64 random bytes instead of a frame"),
    # ---- corrupt payloads ---------------------------------------------
    Scenario("flip-payload-byte", "flip-payload", "detected",
             note="frame checksum catches the flip"),
    Scenario("flip-payload-reframed", "reframe-flip", "detected",
             note="attacker recomputes the checksum; program-io catches it"),
    Scenario("tamper-scalar-reframed", "reframe-scalar", "detected",
             note="scalars['T'] altered, valid frame; program "
                  "fingerprint catches it"),
    Scenario("tamper-array-hash-reframed", "reframe-array-hash", "detected",
             note="array digest altered, valid frame; array hash check "
                  "names the array"),
    # ---- replay / duplication -----------------------------------------
    Scenario("stale-envelope-replay", "stale", "detected",
             note="a VALID envelope for a different artifact; artifact "
                  "fingerprint catches it"),
    Scenario("duplicate-frame", "duplicate", "bitexact",
             note="the same frame twice; the fetcher reads exactly one"),
    Scenario("trailing-junk", "trailing-junk", "bitexact",
             note="garbage after a complete frame is never read"),
    # ---- connection pathologies ---------------------------------------
    Scenario("reset-mid-envelope", "reset-mid", "detected",
             note="RST after half the frame"),
    Scenario("stall-header", "stall-header", "detected",
             note="connected but silent; read deadline fires"),
    Scenario("stall-mid-payload", "stall-mid", "detected",
             note="half the frame then silence"),
    Scenario("slow-loris", "slow-loris", "detected",
             note="one byte per interval, slower than the read deadline"),
    # ---- transient faults: the retry arm must heal them ---------------
    Scenario("transient-truncate", "truncate-mid", "bitexact",
             faulty_conns=1, note="first fetch truncated, retry is clean"),
    Scenario("transient-reset", "reset-mid", "bitexact",
             faulty_conns=1, note="first fetch reset, retry is clean"),
    Scenario("transient-stall", "stall-header", "bitexact",
             faulty_conns=1, note="first fetch stalls, retry is clean"),
    Scenario("transient-flip-twice", "flip-payload", "bitexact",
             faulty_conns=2,
             note="two corrupted fetches, the third (last) retry is clean"),
)


class FaultyProxy:
    """In-process TCP proxy between a fetcher and a ``ProgramServer``.

    Per client connection it pulls the COMPLETE upstream frame first, then
    replays it through the scenario's fault primitive — faults are applied
    to known-good bytes, so every scenario tests exactly one corruption, not
    a compound of proxy timing and fault."""

    def __init__(self, upstream_host: str, upstream_port: int,
                 scenario: Scenario, *, seed: int = 0,
                 stall_s: float = 0.25, stale_blob: bytes | None = None):
        self.upstream = (upstream_host, upstream_port)
        self.scenario = scenario
        self.rng = random.Random(seed)
        self.stall_s = float(stall_s)
        self.stale_blob = stale_blob
        self.host = "127.0.0.1"
        self.port: int | None = None
        self.connections = 0
        self._lock = threading.Lock()
        self._stop = False
        self._sock: socket.socket | None = None
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "FaultyProxy":
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, 0))
        sock.listen(16)
        sock.settimeout(0.05)
        self._sock = sock
        self.port = sock.getsockname()[1]
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        with self._lock:
            self._stop = True
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    def __enter__(self) -> "FaultyProxy":
        return self.start() if self.port is None else self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    def _accept_loop(self) -> None:
        while True:
            with self._lock:
                if self._stop:
                    return
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with self._lock:
                index = self.connections
                self.connections += 1
            threading.Thread(target=self._serve_one, args=(conn, index),
                             daemon=True).start()

    # -------------------------------------------------------------- faults
    def _upstream_frame(self) -> bytes:
        up = socket.create_connection(self.upstream, timeout=2.0)
        try:
            chunks = []
            while True:
                chunk = up.recv(65536)
                if not chunk:
                    return b"".join(chunks)
                chunks.append(chunk)
        finally:
            up.close()

    def _payload_of(self, frame: bytes) -> bytes:
        return frame[tp.HEADER_LEN:]

    def _serve_one(self, conn: socket.socket, index: int) -> None:
        try:
            conn.settimeout(5.0)
            data = self._upstream_frame()
            kind = (self.scenario.kind
                    if index < self.scenario.faulty_conns else "clean")
            self._apply(conn, kind, data)
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _apply(self, conn: socket.socket, kind: str, data: bytes) -> None:
        half = len(data) // 2
        if kind == "clean":
            conn.sendall(data)
        elif kind == "truncate-header":
            conn.sendall(data[:3])
        elif kind == "truncate-mid":
            conn.sendall(data[:half])
        elif kind == "truncate-tail":
            conn.sendall(data[:-1])
        elif kind == "empty":
            pass
        elif kind == "flip-magic":
            conn.sendall(self._flip(data, 0))
        elif kind == "flip-version":
            conn.sendall(self._flip(data, 4))
        elif kind == "length-huge":
            conn.sendall(self._with_length(data, 1 << 48))
        elif kind == "length-short":
            conn.sendall(self._with_length(data, self._length(data) - 7))
        elif kind == "length-long":
            conn.sendall(self._with_length(data, self._length(data) + 7))
        elif kind == "flip-checksum":
            conn.sendall(self._flip(data, 13))
        elif kind == "junk":
            conn.sendall(bytes(self.rng.randrange(256) for _ in range(64)))
        elif kind == "flip-payload":
            conn.sendall(self._flip(data, tp.HEADER_LEN + half // 2))
        elif kind == "reframe-flip":
            payload = bytearray(self._payload_of(data))
            payload[self.rng.randrange(len(payload))] ^= 0x20
            conn.sendall(tp.encode_frame(bytes(payload)))
        elif kind == "reframe-scalar":
            conn.sendall(tp.encode_frame(self._tamper_scalar(data)))
        elif kind == "reframe-array-hash":
            conn.sendall(tp.encode_frame(self._tamper_array_hash(data)))
        elif kind == "stale":
            conn.sendall(tp.encode_frame(self.stale_blob))
        elif kind == "duplicate":
            conn.sendall(data + data)
        elif kind == "trailing-junk":
            conn.sendall(data + b"\xde\xad\xbe\xef" * 8)
        elif kind == "reset-mid":
            conn.sendall(data[:half])
            # SO_LINGER(on, 0): close() sends RST, not FIN — the client
            # sees ECONNRESET mid-frame, not a clean truncation
            conn.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                            b"\x01\x00\x00\x00\x00\x00\x00\x00")
        elif kind == "stall-header":
            time.sleep(self.stall_s)
        elif kind == "stall-mid":
            conn.sendall(data[:half])
            time.sleep(self.stall_s)
        elif kind == "slow-loris":
            for i in range(4):
                conn.sendall(data[i:i + 1])
                time.sleep(self.stall_s / 2)
        else:
            raise AssertionError(f"unknown fault kind {kind!r}")

    @staticmethod
    def _flip(data: bytes, index: int) -> bytes:
        out = bytearray(data)
        out[index] ^= 0xFF
        return bytes(out)

    @staticmethod
    def _length(data: bytes) -> int:
        return int.from_bytes(data[5:13], "big")

    @staticmethod
    def _with_length(data: bytes, length: int) -> bytes:
        out = bytearray(data)
        out[5:13] = int(length).to_bytes(8, "big")
        return bytes(out)

    def _tamper_scalar(self, data: bytes) -> bytes:
        import json
        env = json.loads(self._payload_of(data))
        env["scalars"]["T"] = int(env["scalars"]["T"]) + 1
        return json.dumps(env, sort_keys=True,
                          separators=(",", ":")).encode()

    def _tamper_array_hash(self, data: bytes) -> bytes:
        import json
        env = json.loads(self._payload_of(data))
        name = sorted(env["arrays"])[0]
        digest = env["arrays"][name]
        env["arrays"][name] = ("0" if digest[0] != "0" else "1") + digest[1:]
        return json.dumps(env, sort_keys=True,
                          separators=(",", ":")).encode()


def run_scenario(scenario: Scenario, *, blob: bytes, artifact,
                 leader_fingerprint: str, stale_blob: bytes | None = None,
                 seed: int = 0, stall_s: float = 0.25,
                 client_kw: dict | None = None) -> dict:
    """One scenario end to end: real server, faulty proxy, real fetcher +
    ``deserialize_program``. Returns a verdict dict whose ``ok`` field is
    the detected-or-bit-exact invariant for this scenario."""
    if scenario.kind == "stale" and stale_blob is None:
        raise ValueError("the stale-replay scenario needs a stale_blob "
                         "(a valid envelope for a DIFFERENT artifact)")
    kw = dict(CLIENT_KW)
    if client_kw:
        kw.update(client_kw)
    t0 = time.perf_counter()
    outcome, detail = "bitexact", ""
    with tp.ProgramServer(blob) as upstream:
        with FaultyProxy(upstream.host, upstream.port, scenario, seed=seed,
                         stall_s=stall_s, stale_blob=stale_blob) as proxy:
            try:
                fetched = tp.fetch_bytes(proxy.host, proxy.port, seed=seed,
                                         **kw)
                prog = deserialize_program(fetched, artifact, cache=False)
                if prog.fingerprint != leader_fingerprint:
                    outcome = "silent-divergence"
                    detail = (f"fetched program {prog.fingerprint[:12]}... "
                              f"!= leader {leader_fingerprint[:12]}...")
            except tp.FetchRetriesExhausted as e:
                outcome = "detected"
                detail = f"{type(e.last).__name__}: {e.last}"
            except (tp.TransportError, ProgramIOError) as e:
                outcome = "detected"
                detail = f"{type(e).__name__}: {e}"
            except Exception as e:            # noqa: BLE001 — classified
                outcome = "unexpected-error"
                detail = f"{type(e).__name__}: {e}"
            connections = proxy.connections
    return {"scenario": scenario.name, "kind": scenario.kind,
            "expect": scenario.expect, "outcome": outcome,
            "ok": outcome == scenario.expect, "detail": detail,
            "connections": connections, "note": scenario.note,
            "wall_ms": 1e3 * (time.perf_counter() - t0)}


def run_suite(blob: bytes, artifact, leader_fingerprint: str, *,
              stale_blob: bytes | None = None,
              scenarios: tuple = SCENARIOS, seed: int = 0,
              stall_s: float = 0.25) -> list[dict]:
    """Every scenario's verdict (skipping stale-replay when no stale blob
    is supplied)."""
    verdicts = []
    for sc in scenarios:
        if sc.kind == "stale" and stale_blob is None:
            continue
        verdicts.append(run_scenario(
            sc, blob=blob, artifact=artifact,
            leader_fingerprint=leader_fingerprint, stale_blob=stale_blob,
            seed=seed, stall_s=stall_s))
    return verdicts
