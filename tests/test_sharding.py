"""Sharding resolver: divisibility fallbacks, rule coverage (no real mesh
needed — the resolver only reads mesh.shape / mesh.axis_names)."""

import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as SH


@dataclasses.dataclass
class MockMesh:
    shape: dict
    axis_names: tuple


SINGLE = MockMesh({"data": 16, "model": 16}, ("data", "model"))
MULTI = MockMesh({"pod": 2, "data": 16, "model": 16}, ("pod", "data", "model"))


def test_resolve_axis_divisibility():
    assert SH.resolve_axis(SINGLE, 64, "model") == "model"
    assert SH.resolve_axis(SINGLE, 40, "model") is None          # 40 % 16
    assert SH.resolve_axis(SINGLE, 40, ("model", None)) is None
    assert SH.resolve_axis(MULTI, 64, "data") == ("pod", "data")  # 32-way
    assert SH.resolve_axis(MULTI, 48, "data") is None             # 48 % 32


def test_spec_no_axis_reuse():
    s = SH.spec(SINGLE, (16, 16), ("model", "model"))
    assert s == P("model", None)          # second use of model dropped


def test_gqa_kv_heads_fall_back():
    """(periods, B, Hkv=8, S, D=128): heads don't divide 16 -> head_dim does."""
    s = SH.spec(SINGLE, (32, 128, 8, 1024, 128),
                (None, "data", ("model", None), None,
                 "model"))
    assert s == P(None, "data", None, None, "model")


def test_param_rules_cover_all_archs():
    """Every param of every full config gets a legal spec (no exceptions) and
    big 2D+ params always get at least one sharded dim on the single mesh."""
    import jax
    from repro.configs.registry import ALIASES, get_config
    from repro.models.model import LM
    for arch in ALIASES:
        cfg = get_config(arch)
        lm = LM(cfg)
        specs = lm.param_specs()
        pspecs = SH.param_pspecs(SINGLE, specs)
        flat, _ = jax.tree_util.tree_flatten_with_path(pspecs)
        sflat, _ = jax.tree_util.tree_flatten_with_path(specs)
        for (path, spec), (_, leaf) in zip(flat, sflat):
            # legality: every named axis divides its dim
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = int(np.prod([SINGLE.shape[a] for a in axes]))
                assert dim % size == 0, (arch, SH.path_str(path), leaf.shape, spec)
            if leaf.size >= 1 << 22:      # >= 4M params must be sharded
                assert any(a is not None for a in spec), \
                    (arch, SH.path_str(path), leaf.shape, spec)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096))
def test_spec_always_legal(a, b):
    s = SH.spec(SINGLE, (a, b), (("model", None), ("data", None)))
    for dim, ax in zip((a, b), tuple(s)):
        if ax is not None:
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([SINGLE.shape[x] for x in axes]))
            assert dim % size == 0
