"""Blocked online-softmax attention (flash-style) for the LM zoo.

TPU-native tiling: grid (B, Hq, Sq/bq, Skv/bk) with the KV dimension
innermost; running max / sum / accumulator live in VMEM scratch so the
softmax never materializes the (Sq, Skv) score matrix in HBM. Supports:

  * causal masking with a query offset (prefill: offset 0; decode: offset
    Skv - Sq so the single query row sits at the end of the KV cache),
  * sliding-window attention (Mixtral-style SWA) — kv younger than
    (qpos - window) is masked, which is what makes long-context linear,
  * GQA via the KV BlockSpec index map (kv_head = q_head // group_size) —
    no KV replication in memory.

bq = bk = 128 blocks, f32 accumulation, bf16/f32 inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  q_offset: int, bq: int, bk: int, kv_blocks: int,
                  kv_len: int):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_idx = pl.program_id(2)
    qpos = q_offset + q_idx * bq + jax.lax.iota(jnp.int32, bq)      # (bq,)
    kpos = kb * bk + jax.lax.iota(jnp.int32, bk)                    # (bk,)

    # Static block-level relevance: skip blocks fully masked by causality or
    # the sliding window. (Computed on traced program ids — resolved to a
    # cheap scalar predicate at run time, zero work when false.)
    first_q = q_offset + q_idx * bq
    last_q = first_q + bq - 1
    first_k = kb * bk
    last_k = first_k + bk - 1
    relevant = jnp.bool_(True)
    if causal:
        relevant &= first_k <= last_q
    if window is not None:
        relevant &= last_k >= first_q - window + 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale                 # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                         # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                         # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))     # (bq, bk)
        mask = kpos[None, :] < kv_len
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                                         # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                                      # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                             # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kb == kv_blocks - 1)
    def _finalize():
        lsum = l_scr[...]
        out = acc_scr[...] / jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int | None = None,
                           q_offset: int = 0, kv_len: int | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D) -> (B, Hq, Sq, D).
    Sq % bq == 0, Skv % bk == 0 (ops wrapper pads). kv_len masks KV padding."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0 and Sq % bq == 0 and Skv % bk == 0
    group = Hq // Hkv
    kv_blocks = Skv // bk
    kv_len = Skv if kv_len is None else kv_len
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, kv_blocks=kv_blocks, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, Sq // bq, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
