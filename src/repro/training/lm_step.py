"""LM train/serve step factories — the functions the launcher jits and the
dry-run lowers.

Distributed-optimization tricks baked in:
  * gradient accumulation by microbatch scan: per-microbatch grads are summed
    LOCALLY and the (GSPMD-inserted) gradient all-reduce happens ONCE per
    step, not once per microbatch — compute/communication overlap by
    construction;
  * optional int8+error-feedback gradient compression before the optimizer;
  * remat policy comes from the ArchConfig (cfg.remat) inside the model.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.training import compress as C
from repro.training import optim as O


def make_train_step(lm: LM, optimizer: O.Optimizer, *, grad_accum: int = 1,
                    compress_grads: bool = False) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params', opt_state',
    metrics). ``opt_state`` carries the compression residual when enabled."""

    def loss_fn(params, batch):
        return lm.loss(params, batch)

    def grads_of(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        B = batch["tokens"].shape[0]
        assert B % grad_accum == 0
        mb = B // grad_accum

        def micro(carry, i):
            acc, loss_sum = carry
            sl = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0),
                batch)
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, sl)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return (acc, loss_sum + loss), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), _ = jax.lax.scan(
            micro, (zero, jnp.float32(0.0)), jnp.arange(grad_accum))
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        loss = loss_sum / grad_accum
        return loss, {"ce": loss}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if compress_grads:
            comp, new_res = C.compress(grads, opt_state["residual"])
            grads = C.decompress(comp)
            inner = opt_state["opt"]
        else:
            inner, new_res = opt_state, None
        new_params, new_inner = optimizer.update(grads, inner, params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "grad_norm": gnorm, **{k: v for k, v in metrics.items()}}
        if compress_grads:
            return new_params, {"opt": new_inner, "residual": new_res}, out_metrics
        return new_params, new_inner, out_metrics

    return train_step


def make_opt_state(params, optimizer: O.Optimizer, compress_grads: bool = False):
    inner = optimizer.init(params)
    if compress_grads:
        return {"opt": inner, "residual": C.init_residual(params)}
    return inner


def make_serve_step(lm: LM) -> Callable:
    """serve_step(params, cache, tokens (B,1)) -> (logits, cache') — the
    function decode_* dry-run cells lower."""
    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens)
    return serve_step


def make_prefill_step(lm: LM) -> Callable:
    """prefill(params, tokens, **frontend) -> logits — what prefill_* cells
    lower (full forward, no labels)."""
    def prefill_step(params, tokens, **kw):
        logits, _ = lm.forward(params, tokens, **kw)
        return logits
    return prefill_step
