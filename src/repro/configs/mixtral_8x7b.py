"""Mixtral 8x7B [arXiv:2401.04088; hf]: 32L, d4096, 32H GQA(kv=8), 8 experts
top-2 (expert d_ff 14336), vocab 32000, sliding-window attention (4096) —
SWA makes it sub-quadratic, so long_500k runs with a window-sized KV ring."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, vocab=32000,
    n_heads=32, n_kv_heads=8, d_head=128,
    n_experts=8, top_k=2, d_ff_expert=14336,
    attn_window=4096, rope_theta=1e6,
    subquadratic=True,
)
