"""Deployment-artifact fuzzer — random *valid* artifacts + adversarial events.

``fuzz_case(seed)`` builds, deterministically from the seed, everything the
differential oracles need:

  * a deployment artifact with fuzzed layer widths (n_in, n_groups x
    per_group), int8 weights drawn from several distribution families,
    per-neuron int32 thresholds calibrated from simulated membrane peaks
    (plus never-fire / hair-trigger outliers), a power-of-two leak from
    ``quant.leak_shift_from_tau`` over fuzzed tau (including the inf/0
    sentinels), grouped TTFS decode metadata with both fallback rules, and
    the padded block layout from ``codesign.plan``/``blocked_layout`` —
    exactly the arrays and meta ``deploy.export`` emits, minus the training;
  * an adversarial evaluation batch expressed as IMAGES (every runtime's
    input contract): uniform-random rows plus a same-tick flood, a
    never-spike row, tie-heavy rows, a deterministic ramp, and a
    front-loaded burst. E_max is calibrated from this exact batch with
    headroom 1.0, so floods on lane-multiple n_in land on the exact-E_max
    boundary (no overflow, maximal FIFO pressure).

Images are constructed by inverting the TTFS encoder (``images_from_times``)
and the roundtrip ``encode_ttfs(images) == times`` is asserted, so the spike
times the oracles reason about are exactly the times every runtime sees.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import codesign, events, quant, ttfs
from repro.core.artifact import FORMAT_VERSION, Artifact
from repro.core.hw import PYNQ_COST

#: weight distribution families the fuzzer cycles through
WEIGHT_FAMILIES = ("normal", "uniform", "sparse", "heavy", "constant", "zero")

#: board capacity: fuzzed n_out may not need more hardware groups than exist
MAX_N_OUT = PYNQ_COST.groups * PYNQ_COST.lane


@dataclasses.dataclass
class FuzzedCase:
    seed: int
    artifact: Artifact
    images: np.ndarray   # (B, n_in) float32 — adversarial evaluation batch
    times: np.ndarray    # (B, n_in) int32 — encode_ttfs(images), verified
    notes: dict          # generator decisions, for failure reports


def images_from_times(times: np.ndarray, T: int) -> np.ndarray:
    """Invert the TTFS encoder: spike times -> images.

    Valid targets are t in [0, T-2] (t = T-1 is unreachable for any
    x >= x_min > 0 because floor((1-x)(T-1)) < T-1) and t = T (never spikes,
    realized as intensity 0). Uses the midpoint intensity of each time bin,
    so the roundtrip is exact for any x_min <= 0.5/(T-1)."""
    times = np.asarray(times)
    if T < 4:
        raise ValueError(f"T={T} too small for a stable inverse (need >= 4)")
    if np.any((times > T) | (times == T - 1) | (times < 0)):
        raise ValueError("times must lie in [0, T-2] or equal T (never)")
    x = 1.0 - (times.astype(np.float64) + 0.5) / (T - 1)
    return np.where(times >= T, 0.0, x).astype(np.float32)


# --------------------------------------------------------------------- streams
def _adversarial_times(rng: np.random.RandomState, n_in: int, T: int,
                       n_random: int = 6) -> tuple[np.ndarray, list[str]]:
    """(B, n_in) spike-time rows: one of each named adversarial pattern,
    then random rows. The named patterns come FIRST so the oracles'
    ``images[:py_slice]`` prefix (the slow per-image board scheduler's
    batch) exercises them, not just the batched paths."""
    rows, names = [], []
    hi = T - 2   # latest reachable spike time

    # same-tick flood: every input spikes at once (peak FIFO occupancy; on a
    # lane-multiple n_in this IS the exact-E_max boundary after calibration)
    rows.append(np.full(n_in, rng.randint(0, hi + 1)))
    names.append("flood")

    # never-spike row: zero events end to end (decode fallback territory)
    rows.append(np.full(n_in, T))
    names.append("never")

    # tie-heavy: all events collapse onto <= 3 distinct ticks
    ticks = rng.choice(hi + 1, size=min(3, hi + 1), replace=False)
    rows.append(ticks[rng.randint(0, len(ticks), size=n_in)])
    names.append("ties")

    # deterministic ramp: every reachable tick exercised
    rows.append(np.arange(n_in) % (hi + 1))
    names.append("ramp")

    # front-loaded burst then silence
    t = rng.randint(0, max(1, min(2, hi + 1)), size=n_in)
    quiet = rng.rand(n_in) < 0.3
    rows.append(np.where(quiet, T, t))
    names.append("burst")

    for i in range(n_random):
        t = rng.randint(0, hi + 1, size=n_in)
        never = rng.rand(n_in) < rng.uniform(0.0, 0.6)
        rows.append(np.where(never, T, t))
        names.append(f"random{i}")

    return np.stack(rows).astype(np.int64), names


# --------------------------------------------------------------------- weights
def _fuzz_weights(rng: np.random.RandomState, family: str, n_in: int,
                  n_out: int) -> np.ndarray:
    shape = (n_in, n_out)
    if family == "normal":
        w = rng.randn(*shape) * rng.uniform(0.01, 2.0)
    elif family == "uniform":
        b = rng.uniform(0.05, 3.0)
        w = rng.uniform(-b, b, size=shape)
    elif family == "sparse":
        w = rng.randn(*shape) * (rng.rand(*shape) < rng.uniform(0.05, 0.4))
    elif family == "heavy":
        w = np.clip(rng.standard_cauchy(shape), -50.0, 50.0)
    elif family == "constant":
        w = np.full(shape, rng.uniform(-1.0, 1.0))
    elif family == "zero":
        w = np.zeros(shape)
    else:
        raise ValueError(f"unknown weight family {family!r}")
    return w.astype(np.float32)


def _simulate_peaks(times: np.ndarray, w_int8: np.ndarray, T: int,
                    leak_shift: int) -> np.ndarray:
    """(B, n_out) per-neuron peak membrane over the batch — a pure-numpy
    mirror of the integer LIF recurrence, used only to place thresholds."""
    B, n_in = times.shape
    raster = (times[:, None, :] == np.arange(T)[None, :, None])
    cur = raster.astype(np.int32).reshape(B * T, n_in) @ w_int8.astype(np.int32)
    cur = cur.reshape(B, T, -1)
    v = np.zeros((B, cur.shape[-1]), np.int32)
    peak = np.full_like(v, np.iinfo(np.int32).min)
    for t in range(T):
        v = v - (v >> leak_shift) + cur[:, t]
        peak = np.maximum(peak, v)
    return peak


def _fuzz_thresholds(rng: np.random.RandomState, peaks: np.ndarray,
                     n_out: int) -> np.ndarray:
    """Quantile-of-peaks placement (the shape deploy.calibrate_thresholds
    produces) with adversarial outliers mixed in."""
    q = rng.uniform(0.4, 0.95)
    scale = rng.uniform(0.3, 1.2)
    base = np.quantile(peaks, q, axis=0) * scale
    thr = np.maximum(1, base).astype(np.int64)
    # outliers: some neurons can never fire, some are hair-triggers
    never = rng.rand(n_out) < rng.uniform(0.0, 0.2)
    hair = (~never) & (rng.rand(n_out) < rng.uniform(0.0, 0.2))
    thr[never] = int(quant.INT32_NEVER_FIRE)
    thr[hair] = 1
    return np.clip(thr, 1, int(quant.INT32_NEVER_FIRE)).astype(np.int32)


# ------------------------------------------------------------------------ case
def fuzz_case(seed: int, n_random_images: int = 6) -> FuzzedCase:
    """Deterministically generate one valid (artifact, adversarial batch)."""
    rng = np.random.RandomState(seed)

    # ---- geometry -------------------------------------------------------
    n_groups = int(rng.randint(2, 13))
    per_group = int(rng.randint(1, 21))
    n_out = n_groups * per_group
    if n_out > MAX_N_OUT:          # respect the board's group capacity
        per_group = MAX_N_OUT // n_groups
        n_out = n_groups * per_group
    if rng.rand() < 0.3:
        # lane-multiple input width: floods hit the exact-E_max boundary
        n_in = int(rng.randint(1, 4)) * PYNQ_COST.lane
    else:
        n_in = int(rng.randint(8, 385))
    T = int(rng.randint(4, 34))
    x_min = float(rng.choice([1.0 / 255.0, 0.01]))
    assert x_min <= 0.5 / (T - 1), "inverse-encode validity"

    # ---- dynamics -------------------------------------------------------
    tau = float(rng.choice([
        rng.uniform(0.5, 4.0), rng.uniform(4.0, 64.0),
        rng.uniform(64.0, 1e3), 1e7, np.inf, 0.0]))
    leak_shift = quant.leak_shift_from_tau(tau)
    fallback = str(rng.choice(["membrane", "zero"]))

    # ---- weights + quantization ----------------------------------------
    family = WEIGHT_FAMILIES[int(rng.randint(len(WEIGHT_FAMILIES)))]
    w_f32 = _fuzz_weights(rng, family, n_in, n_out)
    w_int8, scale = quant.quantize_weights(w_f32)

    # ---- adversarial evaluation batch ----------------------------------
    times, patterns = _adversarial_times(rng, n_in, T, n_random_images)
    images = images_from_times(times, T)
    enc = np.asarray(ttfs.encode_ttfs(images, T, x_min))
    if not np.array_equal(enc, times):
        raise AssertionError(
            f"seed {seed}: TTFS inverse-encode roundtrip broke "
            f"(T={T}, x_min={x_min}) — fuzzer bug, not a runtime bug")
    times = enc.astype(np.int64)

    # ---- thresholds from simulated peaks --------------------------------
    peaks = _simulate_peaks(times, w_int8, T, leak_shift)
    thr = _fuzz_thresholds(rng, peaks, n_out)

    # ---- E_max calibrated from this exact batch (headroom 1.0) ----------
    e_max = events.calibrate_e_max(times, T, headroom=1.0)

    # ---- plan + padded block layout (the connectivity descriptor) -------
    report = codesign.plan(n_in, n_out)
    gids = ttfs.group_map(n_groups, per_group)
    layout = codesign.blocked_layout(w_int8, thr, gids, report.lane)

    meta = {
        "format_version": FORMAT_VERSION,
        "model": {"topology": "linear-ttfs", "n_in": n_in, "n_out": n_out},
        "encode": {"T": T, "x_min": x_min},
        "lif": {"leak_shift": leak_shift, "v_init": 0},
        "readout": {"n_groups": n_groups, "per_group": per_group,
                    "fallback": fallback},
        "quant": {"scale": scale, "bits": 8, "scheme": "symmetric-per-tensor"},
        "events": {"e_max": e_max, "pad": events.PAD},
        "codesign": {"lane": report.lane, "n_pad": report.n_pad,
                     "n_blocks": report.n_blocks,
                     "vmem_util": report.vmem_util,
                     "limiter": report.limiter},
        "conformance": {"seed": seed, "weight_family": family, "tau": repr(tau),
                        "patterns": patterns},
    }
    arrays = {"w_float": w_f32, "w_int8": w_int8, "thresholds": thr,
              "group_ids": gids, **layout}
    art = Artifact(meta, arrays)
    peak = int(max(np.bincount(row[row < T], minlength=T).max()
                   for row in times))
    notes = {"seed": seed, "n_in": n_in, "n_out": n_out, "n_groups": n_groups,
             "per_group": per_group, "T": T, "x_min": x_min, "tau": tau,
             "leak_shift": leak_shift, "fallback": fallback,
             "weight_family": family, "e_max": e_max, "patterns": patterns,
             "e_max_boundary_hit": bool(peak == e_max)}
    return FuzzedCase(seed=seed, artifact=art, images=images,
                      times=times.astype(np.int32), notes=notes)


def fuzz_envelope_mutations(blob: bytes, seed: int = 0) -> list[tuple[str, bytes]]:
    """Adversarial mutations of a serialized program envelope.

    Deterministically from the seed, produce (description, tampered_blob)
    variants that ``deserialize_program`` must reject: altered scalars
    (breaks the recomputed program fingerprint), a flipped array hash
    (breaks re-verification against the local artifact), a dropped required
    key, a wrong format version, and raw byte truncation. Every variant
    parses differently from the original, so an accept is a real hole, not
    a no-op mutation."""
    import json as _json

    rng = np.random.RandomState(seed)
    env = _json.loads(blob)

    def dump(e) -> bytes:
        return _json.dumps(e, sort_keys=True, separators=(",", ":")).encode()

    out: list[tuple[str, bytes]] = []
    scalar = rng.choice(sorted(env["scalars"]))
    e = _json.loads(blob)
    v = e["scalars"][scalar]
    e["scalars"][scalar] = (v + 1) if isinstance(v, (int, float)) else v + "x"
    out.append((f"scalar {scalar} altered", dump(e)))
    arr = rng.choice(sorted(env["arrays"]))
    e = _json.loads(blob)
    digest = e["arrays"][arr]
    e["arrays"][arr] = ("0" if digest[0] != "0" else "1") + digest[1:]
    out.append((f"array hash {arr} flipped", dump(e)))
    key = rng.choice(("program_fingerprint", "artifact_fingerprint",
                      "scalars", "arrays"))
    e = _json.loads(blob)
    del e[key]
    out.append((f"key {key} dropped", dump(e)))
    e = _json.loads(blob)
    e["format"] = int(e["format"]) + 1
    out.append(("format bumped", dump(e)))
    out.append(("truncated", blob[:len(blob) // 2]))
    return out
