"""core — the paper's contribution: single-artifact SNN deployment with
bit-exact reference/accelerator agreement and scope-aware measurement."""
