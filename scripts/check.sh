#!/usr/bin/env bash
# Tier-1 gate: full test suite + the event-pipeline perf check.
#
#   scripts/check.sh            # everything
#   scripts/check.sh --fast     # skip the slow subprocess/mesh tests
#
# Fails if any test fails OR if the fused event path is slower than the
# staged event path on accelerator-scope latency (perf regression gate).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

python -m pytest "${PYTEST_ARGS[@]}"
python -m benchmarks.bench_event_pipeline --quick --check
