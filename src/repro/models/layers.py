"""Shared neural layers: norms, RoPE, chunked (flash-style) attention, MLPs.

Everything is pure-jnp and shape-static; the Pallas flash_attention kernel is
a drop-in for the chunked attention on real TPUs (kernels/flash_attention),
while this implementation is the XLA-compilable path used by the multi-pod
dry-run.
"""

from __future__ import annotations
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------- norms
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


# ------------------------------------------------------------------ RoPE
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, S, H, D), positions (B, S) or (S,) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B, S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- chunked flash attention
class _SoftmaxState(NamedTuple):
    m: jnp.ndarray    # (B, H, bq, 1) running max
    lsum: jnp.ndarray  # (B, H, bq, 1) running sum
    acc: jnp.ndarray  # (B, H, bq, D) accumulator


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int | None = None,
                      q_offset: int = 0, bq: int = 512, bk: int = 512,
                      kv_len: int | None = None,
                      gqa: str = "grouped") -> jnp.ndarray:
    """Memory-bounded online-softmax attention.

    q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    gqa="grouped": q reshaped (B, Hkv, group, Sq, D) — K/V never expanded.
    gqa="repeat" (§Perf variant): heads stay FLAT and each K/V *block* is
    repeated to Hq inside the kv loop. Under tensor parallelism the grouped
    reshape is the expensive one: Hkv (4–8) does not divide a 16-way model
    axis, so GSPMD regathers q/k/v at (B,S,H*D) size EVERY LAYER (measured
    ~12 x 1 GB per layer on yi-6b train). Flat Hq (32/64…) shards cleanly;
    the per-block repeat is device-local and costs O(bk*D) memory.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if gqa == "repeat":
        group = 1
    kv_len = Skv if kv_len is None else kv_len
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    # self-pad to block multiples; kv padding is masked via kv_len, q padding
    # is sliced off the output.
    sq_pad = (-Sq) % bq
    skv_pad = (-Skv) % bk
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if skv_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
    Sq_p, Skv_p = Sq + sq_pad, Skv + skv_pad
    scale = 1.0 / (D ** 0.5)
    rep = Hq // Hkv if gqa == "repeat" else 1
    heads = Hq if gqa == "repeat" else Hkv
    qg = q.reshape(B, heads, group, Sq_p, D)

    nq, nk = Sq_p // bq, Skv_p // bk

    def q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=3)
        qb = qb.astype(jnp.float32) * scale
        qpos = q_offset + qi * bq + jnp.arange(bq, dtype=jnp.int32)

        def kv_step(state: _SoftmaxState, ki):
            kb = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=2)
            if rep > 1:   # local per-block KV expansion (gqa="repeat")
                kb = jnp.repeat(kb, rep, axis=1)
                vb = jnp.repeat(vb, rep, axis=1)
            kpos = ki * bk + jnp.arange(bk, dtype=jnp.int32)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb.astype(jnp.float32))
            mask = kpos[None, :] < kv_len
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(state.m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(state.m - m_new)
            l_new = state.lsum * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = state.acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
            return _SoftmaxState(m_new, l_new, acc_new), None

        init = _SoftmaxState(
            m=jnp.full((B, heads, group, bq, 1), NEG_INF, jnp.float32),
            lsum=jnp.zeros((B, heads, group, bq, 1), jnp.float32),
            acc=jnp.zeros((B, heads, group, bq, D), jnp.float32))
        state, _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = state.acc / jnp.where(state.lsum == 0.0, 1.0, state.lsum)
        return out.astype(q.dtype)

    if nq == 1:
        out = q_block(0)
    else:
        outs = jax.lax.map(q_block, jnp.arange(nq))        # (nq, B, h, g, bq, D)
        out = jnp.moveaxis(outs, 0, 3).reshape(B, heads, group, Sq_p, D)
    out = out.reshape(B, Hq, Sq_p, D)
    return out[:, :, :Sq, :]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     *, cache_len: jnp.ndarray, window: int | None = None,
                     window_rotated: bool = False,
                     gqa: str = "grouped") -> jnp.ndarray:
    """Single-step decode attention against a (B, Hkv, S_max, D) cache.

    cache_len: (B,) or scalar int32 — number of valid cache entries. With
    ``window_rotated`` the cache is a ring buffer of size window (SWA decode):
    every slot is valid once full, and positions need no causal mask.
    """
    B, Hq, one, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    if gqa == "repeat":   # flat heads shard cleanly under TP (see chunked)
        k_cache = jnp.repeat(k_cache, Hq // Hkv, axis=1)
        v_cache = jnp.repeat(v_cache, Hq // Hkv, axis=1)
        Hkv = Hq
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D).astype(jnp.float32) / (D ** 0.5)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(S, dtype=jnp.int32)
    valid = kpos[None, :] < jnp.reshape(cache_len, (-1, 1))    # (B, S)
    if window is not None and not window_rotated:
        valid &= kpos[None, :] > jnp.reshape(cache_len, (-1, 1)) - 1 - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


# ------------------------------------------------------------------- MLPs
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    return jax.nn.gelu(x @ w_in + b_in, approximate=True) @ w_out + b_out
