"""AER input event queue — the board emulator's ingress stage.

The FPGA receives (neuron_id) address-event packets time-ordered by the TTFS
encoder and buffers them in a finite FIFO in front of the event router. The
emulator models exactly that:

  * events are scheduled per tick from the TTFS spike times, ordered by
    ascending neuron id within a tick (the same deterministic (time, id)
    order the host packers in ``core.events`` produce);
  * the FIFO has a finite ``depth`` (the artifact's calibrated E_max — the
    co-design analogue of the router FIFO);
  * overflow NEVER drops events: the ingress backpressures, which costs
    stall cycles in the cost model but preserves semantics bit-exactly.
    (The TPU runtime's policy for the same situation is drop-with-flag plus
    a dense-path reroute; the board's is to stall. Both are deterministic.)
"""

from __future__ import annotations

import numpy as np


class AEREventQueue:
    """Per-image event schedule with FIFO-occupancy accounting.

    ``times``: (N_in,) int spike times, T = never-spikes sentinel.
    Iterating yields ``(t, ids_t)`` for t in [0, T) where ``ids_t`` is the
    int32 array of input neurons spiking at tick t, ascending.
    """

    def __init__(self, times: np.ndarray, T: int, depth: int):
        times = np.asarray(times)
        if times.ndim != 1:
            raise ValueError(f"AER queue schedules one image; got {times.shape}")
        self.T = int(T)
        self.depth = int(depth)
        order = np.argsort(times, kind="stable")       # (time, id) ascending
        sorted_t = times[order]
        valid = sorted_t < T
        self._ids = order[valid].astype(np.int32)
        self._splits = np.searchsorted(sorted_t[valid], np.arange(1, T))
        self.total_events = int(self._ids.size)

    def events_at(self, t: int) -> np.ndarray:
        lo = 0 if t == 0 else self._splits[t - 1]
        hi = self.total_events if t == self.T - 1 else self._splits[t]
        return self._ids[lo:hi]

    def __iter__(self):
        for t in range(self.T):
            yield t, self.events_at(t)

    def stalls_at(self, t: int) -> int:
        """Backpressure: events beyond FIFO depth in one tick stall ingress."""
        return max(0, len(self.events_at(t)) - self.depth)

    def counts(self) -> np.ndarray:
        """(T,) events per tick — the cost model's per-tick load."""
        return np.asarray([len(self.events_at(t)) for t in range(self.T)],
                          dtype=np.int64)
