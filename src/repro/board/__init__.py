"""Event-driven board-runtime emulator — the paper's PL datapath in software.

The third runtime behind the single deployment artifact: an AER input event
queue feeding 16 hardware groups x 128 neurons (int8 synapse rows, int32
membranes, power-of-two leak shifts), per-tick event dispatch, grouped TTFS
first-spike decode, and a cycle/energy account against ``hw.PYNQ_COST`` at
80 MHz so the Table-3 analogue (cycles/image, us/image, nJ/image) falls out
of every run.

  * ``SNNBoard``        — readable per-image Python scheduler (the audit path)
  * ``SNNBoardBatched`` — vectorized jax fast path over the group dimension
                          (bit-exact with the scheduler, full-10k-scale)
"""

from repro.board.batched import SNNBoardBatched
from repro.board.energy import BoardTrace, account
from repro.board.event_queue import AEREventQueue
from repro.board.neuron_core import GroupedNeuronCore
from repro.board.runtime import SNNBoard

__all__ = ["SNNBoard", "SNNBoardBatched", "BoardTrace", "account",
           "AEREventQueue", "GroupedNeuronCore"]
