"""Runtime construction cost: cold lowering+compile vs the program cache.

The lowering refactor's operational claim is that runtime construction is
two-tier: a COLD build lowers the artifact and jit-compiles the family's
callable bundle, while every later build over the same (artifact, config)
comes out of the process-wide ``ProgramCache`` — the serving tier leans on
this when the watchdog replaces a hung lane mid-traffic (the rebuilt lane
must NOT pay XLA compile latency again while requests queue).

Four measurements, all system-scope (host wall clock). Every scenario runs
against a SCOPED cache (``lowering.install``) — never ``clear()`` on the
process-wide singleton, which would yank programs out from under any live
engine sharing the process:

  * per advertised family config: time-to-first-served-batch for a cold
    process-state build (fresh scoped cache — fresh bundle closures force
    real recompilation) vs a cached rebuild. ``--check`` gates cached >= 3x
    faster than cold for every jitted spec (board-py builds no jitted
    bundle and is reported ungated). Each row also records the ABSOLUTE
    cold-compile latency (``cold_compile_ms`` = cold − cached, the
    jit-trace/XLA-compile share) against a soft budget
    (``REPRO_COLD_BUILD_BUDGET_MS``, default 30 s): a watchdog replacement
    lane racing a 30 s compile is a serving incident even when the ratio
    gate passes, so ``--check`` WARNS (never fails) on budget breaches —
    the ratio gate stays the hard contract until kernel growth stabilizes
    the absolute numbers.
  * the watchdog scenario end-to-end: a one-lane scheduler whose lane hangs
    on its first batch; the replacement lane's ``runtime.build`` span must
    record ``cache_hit`` in its meta, proving lane recovery rides the cache.
  * the LRU eviction scenario: a budget sized for k of k+1 distinct
    programs; the k+1th build must evict the least-recently-used entry
    (eviction counter asserted) and re-lowering the victim must miss.
  * the follower scenario: a leader's serialized envelope deserialized
    against the local artifact, then built and served — gated >= 3x faster
    than the cold build, because the follower skips ``_lower_uncached`` and
    its reconstructed fingerprint keys straight into the compiled-bundle
    tier.

Emits ``results/bench/runtime_build.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import copy
import os
import sys
import time

import numpy as np

from benchmarks import common as CM
from repro.core.artifact import Artifact
from repro.core.lowering import ProgramCache, install, lower, program_nbytes
from repro.core.runtimes import make_runtime
from repro.telemetry import trace as ttrace
from repro.telemetry.trace import Tracer

#: one spec per distinct compiled-bundle config; board-py is the uncompiled
#: control (pure-python scheduler — nothing to jit, so no 3x gate)
SPECS = ("reference", "accelerator-batch", "accelerator-event",
         "accelerator-event-fused", "board-batched", "board-py")
UNGATED = {"board-py"}
GATE_SPEEDUP = 3.0
#: soft absolute budget for a cold build's compile share (ms) — breaches
#: warn, never fail (ROADMAP: harden once kernel depth stabilizes)
COLD_BUDGET_MS = float(os.environ.get("REPRO_COLD_BUILD_BUDGET_MS", 30000.0))


def _build_and_serve_ms(art, spec: str, images: np.ndarray) -> float:
    """Time-to-first-served-batch: construct + one forward (the forward
    triggers jit tracing/compilation, which is the cost a replacement lane
    would otherwise pay while requests queue)."""
    t0 = time.perf_counter()
    rt = make_runtime(art, spec)
    rt.forward(images)
    return 1e3 * (time.perf_counter() - t0)


def _watchdog_row(art, images: np.ndarray) -> dict:
    """Serve through a hung lane with a Tracer installed; the watchdog's
    replacement lane must be a cache hit (visible in runtime.build meta)."""
    from repro.faults.plan import FaultPlan
    from repro.serving.scheduler import ServingScheduler

    make_runtime(art, "accelerator-event").forward(images[:1])  # warm cache
    plan = FaultPlan(seed=1, hang_batches=(0,), hang_s=2.0, lanes=(0,))
    tracer = Tracer()
    prev_t = ttrace.install(tracer)
    t0 = time.perf_counter()
    try:
        with ServingScheduler(art, spec="accelerator-event", workers=1,
                              max_batch=8, max_wait_us=500.0, faults=plan,
                              resilience={"watchdog_s": 0.2,
                                          "backoff_s": 0.001}) as s:
            for img in images[:8]:
                s.submit(img)
            s.drain()
            st = s.stats()
    finally:
        ttrace.install(prev_t)
    wall_ms = 1e3 * (time.perf_counter() - t0)
    builds = [sp for sp in tracer.spans if sp.name == "runtime.build"]
    hits = [sp for sp in builds if sp.meta.get("cache_hit") is True]
    return {"config": "watchdog-replacement-lane",
            "scope": "system (serving tier, host wall clock)",
            "wall_ms": wall_ms,
            "runtime_builds": len(builds),
            "cache_hit_builds": len(hits),
            "watchdog_timeouts": int(st.get("watchdog_timeouts", 0)),
            "lane_restarts": int(st.get("lane_restarts", 0)),
            "errors": int(st.get("errors", 0)),
            "telemetry": {"span_count": len(tracer.spans)}}


def _variant(art, i: int) -> Artifact:
    """A distinct-fingerprint sibling of the artifact (same arrays, bumped
    e_max meta) — cheap distinct programs for the eviction scenario."""
    meta = copy.deepcopy(art.meta)
    meta["events"]["e_max"] = int(meta["events"]["e_max"]) + i
    return Artifact(meta, dict(art.arrays))


def _eviction_row(art, k: int = 3) -> dict:
    """Budget sized for k of k+1 programs: the k+1th build must evict the
    least-recently-used entry, and re-lowering the victim must miss."""
    per = program_nbytes(lower(art, cache=False))
    variants = [_variant(art, i) for i in range(k + 1)]
    cache = ProgramCache(max_bytes=k * per)
    prev = install(cache)
    try:
        for v in variants[:k]:
            lower(v)
        st_full = cache.stats()
        lower(variants[k])          # exceeds the budget -> evicts variants[0]
        st_evicted = cache.stats()
        lower(variants[0])          # the LRU victim: must be a fresh miss
        st_victim = cache.stats()
    finally:
        install(prev)
    return {"config": "lru-eviction",
            "scope": "system (program cache, host)",
            "budget_bytes": k * per,
            "program_bytes": per,
            "programs_built": k + 1,
            "evictions_at_budget": st_full["evictions"],
            "evictions": st_evicted["evictions"],
            "resident_programs": st_evicted["programs"],
            "resident_bytes": st_evicted["bytes"],
            "victim_remissed": int(st_victim["program_misses"]
                                   == st_evicted["program_misses"] + 1)}


def _follower_row(art, images: np.ndarray) -> dict:
    """Leader lowers + compiles + publishes; a follower-style build
    deserializes the envelope (skipping ``_lower_uncached``) and its
    reconstructed fingerprint keys into the warm compiled-bundle tier —
    gated >= 3x faster than the leader's cold build."""
    from repro.core.program_io import deserialize_program, serialize_program

    cache = ProgramCache()
    prev = install(cache)
    try:
        cold_ms = _build_and_serve_ms(art, "accelerator-event", images)
        blob = serialize_program(lower(art))

        def follower_build_ms() -> float:
            t0 = time.perf_counter()
            prog = deserialize_program(blob, art, cache=False)
            make_runtime(prog, "accelerator-event").forward(images)
            return 1e3 * (time.perf_counter() - t0)

        deser_ms = min(follower_build_ms() for _ in range(3))
    finally:
        install(prev)
    speedup = cold_ms / deser_ms if deser_ms > 0 else float("inf")
    return {"config": "follower-deserialize",
            "scope": "system (runtime construction, host wall clock)",
            "cold_build_ms": cold_ms,
            "deserialize_build_ms": deser_ms,
            "speedup": speedup,
            "envelope_bytes": len(blob),
            "gated": True}


def main(quick: bool = False, check: bool = False) -> int:
    art, xte, _ = CM.get_artifact_and_data(quick=quick)
    images = xte[:16]
    rows: list[dict] = []
    print(f"runtime build cost, cold (lower + jit compile) vs cached "
          f"({len(images)} img first batch):")
    for spec in SPECS:
        serve = images[:4] if spec == "board-py" else images
        prev = install(ProgramCache())
        try:
            cold_ms = _build_and_serve_ms(art, spec, serve)
            cached_ms = min(_build_and_serve_ms(art, spec, serve)
                            for _ in range(3))
        finally:
            install(prev)
        speedup = cold_ms / cached_ms if cached_ms > 0 else float("inf")
        rows.append({"runtime": spec,
                     "scope": "system (runtime construction, host wall "
                              "clock)",
                     "cold_build_ms": cold_ms,
                     "cached_build_ms": cached_ms,
                     # the compile share a replacement lane would pay cold:
                     # everything the cached rebuild does NOT repeat
                     "cold_compile_ms": max(0.0, cold_ms - cached_ms),
                     "cold_budget_ms": COLD_BUDGET_MS,
                     "within_cold_budget": cold_ms <= COLD_BUDGET_MS,
                     "speedup": speedup,
                     "gated": spec not in UNGATED})
        gate = "" if spec in UNGATED else f"  (gate >= {GATE_SPEEDUP}x)"
        print(f"  {spec:28s} cold {cold_ms:8.1f} ms   cached "
              f"{cached_ms:7.1f} ms   {speedup:6.1f}x{gate}")

    prev = install(ProgramCache())
    try:
        wd = _watchdog_row(art, images)
    finally:
        install(prev)
    rows.append(wd)
    print(f"watchdog scenario: {wd['runtime_builds']} lane builds, "
          f"{wd['cache_hit_builds']} cache hits, "
          f"{wd['watchdog_timeouts']} timeouts, "
          f"{wd['lane_restarts']} restarts in {wd['wall_ms']:.0f} ms")

    ev = _eviction_row(art)
    rows.append(ev)
    print(f"eviction scenario: budget {ev['budget_bytes']} B for "
          f"{ev['programs_built']} x {ev['program_bytes']} B programs -> "
          f"{ev['evictions']} evictions, {ev['resident_programs']} resident "
          f"({ev['resident_bytes']} B)")

    fo = _follower_row(art, images)
    rows.append(fo)
    print(f"follower scenario: cold {fo['cold_build_ms']:.1f} ms vs "
          f"deserialize {fo['deserialize_build_ms']:.1f} ms "
          f"({fo['speedup']:.1f}x, envelope {fo['envelope_bytes']} B)")

    CM.emit("runtime_build", rows)

    if check:
        bad = []
        for r in rows:
            if r.get("gated") and r["speedup"] < GATE_SPEEDUP:
                name = r.get("runtime") or r.get("config")
                fast = ("cached" if "cached_build_ms" in r
                        else "deserialize")
                bad.append(f"{name}: {fast} build only "
                           f"{r['speedup']:.1f}x faster than cold "
                           f"(gate {GATE_SPEEDUP}x)")
        if wd["watchdog_timeouts"] < 1:
            bad.append("watchdog never fired (timeouts == 0)")
        if wd["lane_restarts"] < 1:
            bad.append("hung lane was never replaced (lane_restarts == 0)")
        if wd["cache_hit_builds"] < 1:
            bad.append("no runtime.build span recorded cache_hit=True — "
                       "the replacement lane recompiled from scratch")
        if wd["errors"]:
            bad.append(f"{wd['errors']} requests errored during recovery")
        if ev["evictions_at_budget"] != 0:
            bad.append(f"cache evicted {ev['evictions_at_budget']} programs "
                       "while still within budget")
        if ev["evictions"] < 1:
            bad.append("k+1th build past the byte budget never evicted "
                       "(evictions == 0)")
        if ev["resident_programs"] != 3:
            bad.append(f"{ev['resident_programs']} programs resident after "
                       "eviction (expected k=3)")
        if not ev["victim_remissed"]:
            bad.append("re-lowering the LRU victim did not miss — the "
                       "eviction was not real")
        # soft absolute-latency budget: warn loudly, never fail — the
        # ratio gate above is the hard contract (ROADMAP item: make this
        # hard once fused-kernel depth stabilizes cold-compile numbers)
        over = [r for r in rows
                if "cold_build_ms" in r and not r.get("within_cold_budget",
                                                      True)]
        for r in over:
            print(f"BUDGET WARNING: {r.get('runtime') or r.get('config')} "
                  f"cold build {r['cold_build_ms']:.0f} ms exceeds the "
                  f"{COLD_BUDGET_MS:.0f} ms soft budget "
                  f"(REPRO_COLD_BUILD_BUDGET_MS)", file=sys.stderr)
        if bad:
            print("CHECK FAILED: " + "; ".join(bad), file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller eval slice (the CI configuration)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless cached builds are >= 3x faster than "
                         "cold for every jitted spec and the watchdog "
                         "replacement lane is a cache hit")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check))
