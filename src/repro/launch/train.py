"""Production training launcher: --arch <id> on the production mesh.

On a real TPU slice this is the entry point per host process (jax.distributed
handles cross-host init); on this CPU container it runs reduced configs for
validation and abstract-lowers full configs (use launch/dryrun.py for the
512-device compile).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 30 --ckpt /tmp/ck --compress-grads
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, reduced as make_reduced
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.model import LM
from repro.training import lm_step, optim as O
from repro.training.checkpoint import CheckpointManager
from repro.training.elastic import StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
    optimizer = O.get(cfg.optimizer, args.lr)
    opt_state = lm_step.make_opt_state(params, optimizer, args.compress_grads)
    step_fn = jax.jit(lm_step.make_train_step(
        lm, optimizer, grad_accum=args.grad_accum,
        compress_grads=args.compress_grads))
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    mgr = CheckpointManager(args.ckpt, keep=2) if args.ckpt else None
    mon = StragglerMonitor()

    start = 0
    if mgr and mgr.latest_step() is not None:
        start, restored = mgr.restore({"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"[resume] restored step {start}")

    for i in range(start, args.steps):
        t0 = time.perf_counter()
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        mon.record("host0", time.perf_counter() - t0)
        if (i + 1) % 5 == 0 or i == start:
            print(f"step {i + 1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{time.perf_counter() - t0:.2f}s/step")
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state},
                     meta={"loss": float(metrics["loss"]), "arch": cfg.name})
    if mon.stragglers():
        print(f"[straggler report] {mon.stragglers()}")
    print("training complete.")


if __name__ == "__main__":
    main()
