"""Software reference runner — consumes the deployment artifact unchanged.

This is the "software TTFS reference" of the paper: a straightforward dense
time-loop evaluation of the integer LIF/TTFS semantics. The accelerator
runtime must match it bit-exactly on first-spike times and decoded labels
(the paper's full-test-set, 10,000/10,000 agreement claim).

Also hosts the dense GPU/CPU-baseline analogues (Table 3 rows 2-5): dense
grouped-neuron execution of the SAME exported parameters in FP32 and INT8,
executed as plain matmuls rather than event-level TTFS runtimes.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ttfs
from repro.core.artifact import Artifact
from repro.core.lif_dynamics import lif_scan


class SNNOutput(NamedTuple):
    labels: jnp.ndarray        # (B,) int32
    first_spike: jnp.ndarray   # (B, N_out) int32 (logical neurons)
    v_final: jnp.ndarray       # (B, N_out) int32
    steps: jnp.ndarray         # (B,) int32 — timesteps consumed (T for full scan)


def _decode(art: Artifact, first, v_final):
    return ttfs.decode_labels(
        first, v_final,
        n_groups=art.m("readout", "n_groups"),
        per_group=art.m("readout", "per_group"),
        sentinel=art.m("encode", "T"),
        fallback=art.m("readout", "fallback"))


class SNNReference:
    """Reference runtime. ``forward(images)`` mirrors torch's ``model(x)``."""

    def __init__(self, artifact: Artifact):
        self.art = artifact
        self.T = int(artifact.m("encode", "T"))
        self.x_min = float(artifact.m("encode", "x_min"))
        self.leak_shift = int(artifact.m("lif", "leak_shift"))
        self.w_int8 = jnp.asarray(artifact["w_int8"])          # (N_in, N_out)
        self.thr = jnp.asarray(artifact["thresholds"])         # (N_out,) int32
        self.w_f32 = jnp.asarray(artifact["w_float"])
        self.scale = float(artifact.m("quant", "scale"))
        self._fwd = jax.jit(self._forward_impl)

    # ---------------------------------------------------------------- TTFS
    def _forward_impl(self, images: jnp.ndarray) -> SNNOutput:
        T = self.T
        times = ttfs.encode_ttfs(images, T, self.x_min)         # (B, N_in)
        raster = ttfs.frames_from_times(times, T)               # (B, T, N_in) int8
        # integer synaptic currents per step: (B, T, N_out) int32
        currents = jax.lax.dot_general(
            raster, self.w_int8,
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        currents = jnp.moveaxis(currents, 1, 0)                 # (T, B, N_out)
        res = lif_scan(currents, self.thr[None, :], self.leak_shift, T)
        labels = _decode(self.art, res.first_spike, res.v_final)
        steps = jnp.full(labels.shape, T, jnp.int32)
        return SNNOutput(labels, res.first_spike, res.v_final, steps)

    def forward(self, images) -> SNNOutput:
        return self._fwd(jnp.asarray(images, jnp.float32))

    __call__ = forward

    # ---------------------------------------------- dense baselines (Table 3)
    @functools.partial(jax.jit, static_argnums=0)
    def dense_logits_fp32(self, images):
        """Dense grouped-neuron execution, FP32 (the 'GPU FP32'/'CPU FP32' row)."""
        z = jnp.asarray(images, jnp.float32) @ self.w_f32       # (B, N_out)
        g = self.art.m("readout", "n_groups"); p = self.art.m("readout", "per_group")
        return jnp.mean(z.reshape(-1, g, p), axis=-1)           # grouped readout

    @functools.partial(jax.jit, static_argnums=0)
    def dense_logits_int8(self, images):
        """Dense INT8 execution of the same exported parameters."""
        x_q = jnp.clip(jnp.round(jnp.asarray(images, jnp.float32) * 127.0),
                       0, 127).astype(jnp.int8)
        z = jax.lax.dot_general(x_q, self.w_int8, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
        g = self.art.m("readout", "n_groups"); p = self.art.m("readout", "per_group")
        return jnp.mean(z.reshape(-1, g, p).astype(jnp.float32), axis=-1)

    def dense_labels(self, images, mode: str = "fp32"):
        logits = (self.dense_logits_fp32 if mode == "fp32"
                  else self.dense_logits_int8)(images)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
