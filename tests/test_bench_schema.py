"""Bench JSON schema: the emit() gate that keeps results/bench comparable."""

import json
import os

import pytest

from benchmarks import schema


def _row(**over):
    base = {"runtime": "x", "scope": "accelerator", "us_per_image": 1.0}
    base.update(over)
    return base


def test_valid_rows_pass():
    schema.validate_rows("t", [_row(), _row(runtime=None, path="p",
                                          extras=[1, 2.5, "s", None])])


def test_empty_and_nonlist_rejected():
    with pytest.raises(schema.SchemaError, match="non-empty"):
        schema.validate_rows("t", [])
    with pytest.raises(schema.SchemaError, match="non-empty"):
        schema.validate_rows("t", {"runtime": "x"})


def test_missing_scope_identity_metric_rejected():
    with pytest.raises(schema.SchemaError, match="scope"):
        schema.validate_rows("t", [{"runtime": "x", "us_per_image": 1.0}])
    with pytest.raises(schema.SchemaError, match="identity"):
        schema.validate_rows("t", [{"scope": "s", "us_per_image": 1.0}])
    with pytest.raises(schema.SchemaError, match="metric"):
        schema.validate_rows("t", [{"runtime": "x", "scope": "s", "n": 3}])


def test_nested_values_rejected():
    with pytest.raises(schema.SchemaError, match="scalar"):
        schema.validate_rows("t", [_row(nested={"a": 1})])


def test_metric_detection_uses_unit_tokens():
    assert schema.is_metric("us_per_image")
    assert schema.is_metric("energy_nj_img")
    assert schema.is_metric("vmem_bytes")
    assert schema.is_metric("accuracy_pct")
    assert schema.is_metric("cycles_per_image")
    assert not schema.is_metric("n_images")
    assert not schema.is_metric("limiter")
    assert not schema.is_metric("mismatches")


def test_committed_bench_files_conform():
    """Every JSON already under results/bench/ must satisfy the schema —
    the cross-PR comparability contract, checked on the committed files."""
    results = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
    found = 0
    for fn in sorted(os.listdir(results)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(results, fn)) as f:
            schema.validate_rows(fn[:-5], json.load(f))
        found += 1
    assert found >= 2          # event_pipeline.json + board_emu.json

def test_telemetry_block_is_the_one_structured_exception():
    schema.validate_rows("t", [_row(telemetry={"span_count": 12,
                                               "dropped_spans": 0,
                                               "overhead_pct": 0.3})])
    schema.validate_rows("t", [_row(telemetry={"span_count": 1})])  # subset


def test_telemetry_block_keys_are_closed():
    with pytest.raises(schema.SchemaError, match="unknown keys"):
        schema.validate_rows("t", [_row(telemetry={"span_count": 1,
                                                   "notes": "x"})])
    with pytest.raises(schema.SchemaError, match="non-empty"):
        schema.validate_rows("t", [_row(telemetry={})])
    with pytest.raises(schema.SchemaError, match="non-empty"):
        schema.validate_rows("t", [_row(telemetry=[1, 2])])


def test_telemetry_values_numeric_only():
    with pytest.raises(schema.SchemaError, match="numeric"):
        schema.validate_rows("t", [_row(telemetry={"span_count": "12"})])
    with pytest.raises(schema.SchemaError, match="numeric"):
        schema.validate_rows("t", [_row(telemetry={"dropped_spans": True})])
    import numpy as np
    schema.validate_rows("t", [_row(telemetry={"span_count": np.int64(3),
                                               "overhead_pct":
                                               np.float32(0.1)})])


def test_other_nested_dicts_still_rejected():
    with pytest.raises(schema.SchemaError, match="scalar"):
        schema.validate_rows("t", [_row(tracing={"span_count": 1})])
