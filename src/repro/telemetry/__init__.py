"""Deterministic telemetry — scope-aware tracing, metrics, and exporters.

The repo's observability tier (its seventh subsystem): structured span
traces with explicit ``accel|system`` scope tags and logical clocks
(``telemetry.trace``), a counter/gauge/histogram registry with typed events
(``telemetry.metrics``), and JSONL / Prometheus exporters
(``telemetry.export``). Instrumentation is threaded through the serving
scheduler, the board emulator, and the accelerator runtimes; it is a no-op
until a ``Tracer`` is installed.
"""

from repro.telemetry.metrics import (DEPTH_BUCKETS, LATENCY_BUCKETS_US,
                                     RECOVERY_BUCKETS_MS, Event, Histogram,
                                     MetricsRegistry)
from repro.telemetry.trace import SCOPES, NullRecorder, Span, Tracer

__all__ = ["DEPTH_BUCKETS", "LATENCY_BUCKETS_US", "RECOVERY_BUCKETS_MS",
           "Event", "Histogram", "MetricsRegistry", "SCOPES", "NullRecorder",
           "Span", "Tracer"]
