"""Continuous-batching serving scheduler — the tier behind ``SNNServeEngine``.

One scheduler owns the whole request path the paper's §2.3 discipline wants
measured: an admission queue, deadline-aware micro-batch formation, N worker
lanes each owning a runtime built from a registry spec string
(``core.runtimes.make_runtime``), and per-request latency percentiles on top
of the accelerator/system scope split. The overflow→dense reroute and the
board cycle/energy account both live HERE — every front-end (the synchronous
``SNNServeEngine`` facade, the load bench's open/closed-loop drivers) goes
through the same code path, so serving semantics cannot fork per caller.

Batch formation (the continuous-batching policy):
  * a batch OPENS when a lane picks up the oldest queued request;
  * it CLOSES at ``max_batch`` requests OR ``max_wait_us`` after opening,
    whichever comes first — bounded formation latency under light load,
    full batches under heavy load;
  * every batch is zero-padded to ``max_batch`` rows so each lane runs ONE
    compiled program regardless of traffic (the artifact's padded shapes).

Worker lanes:
  * ``workers >= 1`` — that many daemon threads, each with its OWN runtime
    instance (own compiled programs, own lazy dense-fallback runtime, own
    board trace) so lanes never contend on jax state;
  * ``workers == 0`` — inline mode: no threads; ``drain()`` forms greedy
    ``max_batch``-sized batches and serves them on the calling thread via
    lane 0. Deterministic batch count — the facade's flush() semantics.

Bit-exactness holds regardless of batching: every runtime evaluates rows
independently, and pad rows never influence real ones, so a label served at
queue depth 60 equals the label served alone — the load bench's ``--check``
gate asserts exactly this against the software reference.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

import jax
import numpy as np

from repro.core.artifact import Artifact
from repro.core.runtimes import make_runtime


@dataclasses.dataclass
class ServeRequest:
    """One admitted classification request, completed in place."""
    rid: int
    image: np.ndarray             # (N_in,) float32 in [0, 1]
    label: int | None = None      # filled at completion
    steps: int | None = None      # timesteps consumed (latency mode)
    fallback_dense: bool = False  # served via the dense reroute
    lane: int | None = None       # worker lane that served it
    t_submit: float = 0.0         # perf_counter at admission
    t_done: float = 0.0           # perf_counter at completion
    error: str | None = None      # set instead of label if serving failed

    @property
    def latency_us(self) -> float:
        return 1e6 * (self.t_done - self.t_submit)


class _Lane:
    """One worker lane: a runtime built from the spec, plus the lane-local
    serve path (event packing, overflow reroute, board accounting). Each
    lane's counters are merged into the scheduler under its lock, so lanes
    themselves stay lock-free on the hot path."""

    def __init__(self, lane_id: int, artifact: Artifact, spec: str,
                 kernel: str | None, latency_mode: bool):
        self.lane_id = lane_id
        self.art = artifact
        self.spec = spec
        self.family, _, _ = spec.partition("-")
        self.latency_mode = bool(latency_mode)
        kw = {"latency_mode": latency_mode}
        if kernel is not None:
            kw["kernel"] = kernel        # None = the family's own default
        self.runtime = make_runtime(artifact, spec, **kw)
        self._dense = None               # built lazily on first overflow
        self.T = int(artifact.m("encode", "T"))
        self.x_min = float(artifact.m("encode", "x_min"))
        self.e_max = int(artifact.m("events", "e_max"))

    # ------------------------------------------------------------- serve path
    def serve(self, images: np.ndarray, k: int) -> dict:
        """Serve a zero-padded (max_batch, N_in) buffer whose first ``k``
        rows are real traffic; returns labels/steps/fallback plus the
        lane-local stat deltas for the scheduler to merge."""
        if self.family == "accelerator" and self.runtime.mode == "event":
            return self._serve_event(images, k)
        return self._serve_forward(images, k)

    def _serve_forward(self, images: np.ndarray, k: int) -> dict:
        """board / reference / dense-accelerator path: forward(images)."""
        t0 = time.perf_counter()
        out = self.runtime.forward(images)
        jax.block_until_ready(out.labels)
        delta = {"accel_s": time.perf_counter() - t0,
                 "labels": np.asarray(out.labels),
                 "steps": np.asarray(out.steps),
                 "fallback": np.zeros(len(images), bool),
                 "overflow_fallbacks": 0}
        trace = getattr(self.runtime, "last_trace", None)
        if trace is not None:
            # board family: PL cycles / dynamic energy for the REAL rows only
            # (pad rows clock too, but they are not served traffic)
            delta["board_cycles"] = int(np.sum(trace.cycles[:k]))
            delta["board_nj"] = float(np.sum(trace.energy_nj[:k]))
            delta["board_stalls"] = int(np.sum(trace.stalls[:k]))
        return delta

    def _serve_event(self, images: np.ndarray, k: int) -> dict:
        """Packed-event accelerator path with the overflow→dense reroute."""
        from repro.core import ttfs
        from repro.core.events import pack_events_batched
        import jax.numpy as jnp

        times = np.asarray(ttfs.encode_ttfs(
            jnp.asarray(images, jnp.float32), self.T, self.x_min))
        frames = pack_events_batched(times, self.T, self.e_max)
        overflow = np.asarray(frames.overflow)  # checked ONCE, on host arrays

        t0 = time.perf_counter()
        out = self.runtime.forward(frames=frames,
                                   latency_mode=self.latency_mode,
                                   check_overflow=False)
        jax.block_until_ready(out.labels)
        accel_s = time.perf_counter() - t0
        labels = np.array(out.labels)           # writable copies (reroute
        steps = np.array(out.steps)             # rows are patched below)

        bad = np.nonzero(overflow[:k])[0]
        if bad.size:
            # overflow policy: reroute those rows through the dense
            # time-batched path (same artifact, same semantics, no E_max
            # cap). Runs on the full fixed-shape padded buffer so the dense
            # program compiles once, not per distinct overflow-row count.
            if self._dense is None:
                self._dense = make_runtime(self.art, "accelerator-batch")
            t0 = time.perf_counter()
            dense_out = self._dense.forward(images=images)
            jax.block_until_ready(dense_out.labels)
            accel_s += time.perf_counter() - t0
            labels[bad] = np.asarray(dense_out.labels)[bad]
            steps[bad] = np.asarray(dense_out.steps)[bad]
        return {"accel_s": accel_s, "labels": labels, "steps": steps,
                "fallback": overflow, "overflow_fallbacks": int(bad.size)}


class ServingScheduler:
    """Admission queue + deadline-aware micro-batching + N worker lanes.

    ``submit()`` is thread-safe and returns immediately with a request id;
    ``result(rid)`` blocks one caller until its request completes (the
    closed-loop client API); ``drain()`` blocks until the queue is empty and
    returns every completed-but-unclaimed request (the synchronous facade
    API). ``stats()`` reports both measurement scopes plus request-latency
    percentiles and queue-depth stats; ``reset_stats()`` zeroes them (e.g.
    after a warmup pass, so compile time does not pollute percentiles)."""

    def __init__(self, artifact: Artifact, *, spec: str = "accelerator-event",
                 workers: int = 0, max_batch: int = 64,
                 max_wait_us: float = 2000.0, kernel: str | None = None,
                 latency_mode: bool = False):
        if workers < 0:
            raise ValueError(f"workers must be >= 0, got {workers}")
        self.art = artifact
        self.spec = spec
        self.family = spec.partition("-")[0]
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        self.workers = int(workers)
        self.latency_mode = bool(latency_mode)
        self.n_in = int(artifact.m("model", "n_in"))
        self.lanes = [_Lane(i, artifact, spec, kernel, latency_mode)
                      for i in range(max(1, workers))]

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._admission: collections.deque[ServeRequest] = collections.deque()
        self._completed: dict[int, ServeRequest] = {}
        self._claims: set[int] = set()       # rids owned by result() waiters
        self._outstanding: set[int] = set()  # submitted, not yet completed
        self._pending = 0
        self._next_rid = 0
        self._stop = False
        self.reset_stats()
        self._threads = [
            threading.Thread(target=self._worker, args=(lane,), daemon=True,
                             name=f"serve-lane-{lane.lane_id}")
            for lane in (self.lanes if workers else [])]
        for t in self._threads:
            t.start()

    # ---------------------------------------------------------------- client
    def submit(self, image: np.ndarray) -> int:
        with self._cv:
            if self._stop:
                raise RuntimeError("scheduler is closed")
            rid = self._next_rid
            self._next_rid += 1
            req = ServeRequest(rid, np.asarray(image, np.float32),
                               t_submit=time.perf_counter())
            self._admission.append(req)
            self._outstanding.add(rid)
            self._pending += 1
            self._sample_depth()
            self._cv.notify_all()
            return rid

    def result(self, rid: int, timeout: float | None = None) -> ServeRequest:
        """Block until request ``rid`` completes; pops and returns it (the
        closed-loop client API). Inline mode serves the queue first. The
        rid is CLAIMED while waiting — a concurrent ``drain()`` will not
        return it out from under this caller — and a rid that is neither
        outstanding nor completed (already drained or returned) raises
        KeyError instead of blocking forever."""
        with self._cv:
            if rid not in self._completed and rid not in self._outstanding:
                raise KeyError(f"request {rid} is not outstanding — already "
                               "claimed by drain()/result() or never "
                               "submitted")
            self._claims.add(rid)
        try:
            if not self._threads:
                self._drain_inline()
            deadline = (None if timeout is None
                        else time.perf_counter() + timeout)
            with self._cv:
                while rid not in self._completed:
                    remaining = (None if deadline is None
                                 else deadline - time.perf_counter())
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(f"request {rid} not completed "
                                           f"within {timeout}s")
                    self._cv.wait(timeout=remaining)
                return self._completed.pop(rid)
        finally:
            with self._cv:
                self._claims.discard(rid)

    def drain(self) -> dict[int, ServeRequest]:
        """Serve/await everything queued; pop and return every completed
        request not claimed by a ``result()`` waiter."""
        if not self._threads:
            self._drain_inline()
        with self._cv:
            while self._pending:
                self._cv.wait()
            done = {rid: r for rid, r in self._completed.items()
                    if rid not in self._claims}
            for rid in done:
                del self._completed[rid]
            return done

    def close(self) -> None:
        """Stop the worker lanes. Batches in flight finish; the unserved
        backlog is NOT drained — its requests complete immediately with
        ``error="scheduler closed"`` so no waiter hangs."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join()
        with self._cv:
            now = time.perf_counter()
            while self._admission:
                r = self._admission.popleft()
                r.error = "scheduler closed"
                r.t_done = now
                self._complete_locked(r)
                self._pending -= 1
            self._cv.notify_all()

    # completed-but-unclaimed backlog bound: past this, the oldest unclaimed
    # results are abandoned (counted in stats) instead of pinning their
    # request images forever in a server whose callers never drain()
    COMPLETED_WINDOW = 65536

    def _complete_locked(self, r: ServeRequest) -> None:
        """Caller holds the lock: publish a finished request, releasing its
        outstanding slot and bounding the unclaimed backlog."""
        self._outstanding.discard(r.rid)
        self._completed[r.rid] = r
        while len(self._completed) > self.COMPLETED_WINDOW:
            victim = next((rid for rid in self._completed
                           if rid not in self._claims), None)
            if victim is None:               # everything left has a waiter
                break
            del self._completed[victim]
            self._abandoned += 1

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------- batch formation
    def _form_batch(self) -> list[ServeRequest] | None:
        """Blocking formation for worker lanes: open on the oldest queued
        request, close at max_batch OR max_wait_us — whichever first."""
        with self._cv:
            while not self._admission and not self._stop:
                self._cv.wait()
            if self._stop:                   # no NEW batches after close():
                return None                  # the backlog is failed, not served
            batch = [self._admission.popleft()]
            deadline = time.perf_counter() + self.max_wait_us * 1e-6
            while len(batch) < self.max_batch:
                if self._admission:
                    batch.append(self._admission.popleft())
                    continue
                remaining = deadline - time.perf_counter()
                if self._stop or remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
            self._sample_depth()
            return batch

    def _worker(self, lane: _Lane) -> None:
        while True:
            batch = self._form_batch()
            if batch is None:
                return
            self._serve_batch(lane, batch)

    def _drain_inline(self) -> None:
        """Inline mode: greedy max_batch-sized batches on the caller thread
        (deterministic batch count — the facade's flush() semantics)."""
        while True:
            with self._cv:
                if not self._admission:
                    return
                batch = []
                while self._admission and len(batch) < self.max_batch:
                    batch.append(self._admission.popleft())
            self._serve_batch(self.lanes[0], batch)

    # -------------------------------------------------------------- serving
    def _serve_batch(self, lane: _Lane, batch: list[ServeRequest]) -> None:
        t0 = time.perf_counter()
        k = len(batch)
        try:
            images = np.zeros((self.max_batch, self.n_in), np.float32)
            for j, r in enumerate(batch):
                images[j] = r.image          # zero-pad to the fixed shape
            delta = lane.serve(images, k)
        except Exception as e:
            # fail the batch, never strand it: requests complete with
            # .error set, _pending is released, waiters wake. Inline mode
            # re-raises so the synchronous caller still sees the exception.
            now = time.perf_counter()
            with self._cv:
                for r in batch:
                    r.error = f"{type(e).__name__}: {e}"
                    r.lane = lane.lane_id
                    r.t_done = now
                    self._complete_locked(r)
                self._pending -= k
                self.errors += k
                self._cv.notify_all()
            if not self._threads:
                raise
            return
        now = time.perf_counter()
        with self._cv:
            for j, r in enumerate(batch):
                r.label = int(delta["labels"][j])
                r.steps = int(delta["steps"][j])
                r.fallback_dense = bool(delta["fallback"][j])
                r.lane = lane.lane_id
                r.t_done = now
                self._complete_locked(r)
                self._latencies_us.append(r.latency_us)
            self._pending -= k
            self.images_out += k
            self.batches += 1
            self._batch_fill += k
            self.accel_s += delta["accel_s"]
            self.system_s += now - t0
            self.overflow_fallbacks += delta["overflow_fallbacks"]
            self.board_cycles += delta.get("board_cycles", 0)
            self.board_nj += delta.get("board_nj", 0.0)
            self.board_stalls += delta.get("board_stalls", 0)
            self._cv.notify_all()

    # ---------------------------------------------------------------- stats
    def _sample_depth(self) -> None:
        d = len(self._admission)
        self._depth_sum += d
        self._depth_samples += 1
        self._depth_peak = max(self._depth_peak, d)

    # percentile window: enough to hold any bench run exactly, bounded so a
    # long-running server cannot leak memory (percentiles become a sliding
    # window over the most recent requests past this point)
    LATENCY_WINDOW = 65536

    def reset_stats(self) -> None:
        with self._lock:
            self.accel_s = self.system_s = 0.0
            self.images_out = self.overflow_fallbacks = self.batches = 0
            self.errors = 0
            self._abandoned = 0
            self.board_cycles = 0
            self.board_nj = 0.0
            self.board_stalls = 0
            self._latencies_us: collections.deque[float] = collections.deque(
                maxlen=self.LATENCY_WINDOW)
            self._batch_fill = 0
            self._depth_sum = self._depth_samples = self._depth_peak = 0

    def stats(self) -> dict:
        with self._lock:
            n = self.images_out
            # ONE denominator guard for every per-image rate (board and
            # accelerator branches used to disagree: `if n` vs `max(1, n)`)
            per_image = lambda x: x / n if n else 0.0
            lat = np.asarray(self._latencies_us, np.float64)
            st = {
                "spec": self.spec,
                "workers": self.workers,
                "max_batch": self.max_batch,
                "max_wait_us": self.max_wait_us,
                "accelerator_s": self.accel_s,
                "system_s": self.system_s,
                "host_overhead_s": max(0.0, self.system_s - self.accel_s),
                "images_out": n,
                "overflow_fallbacks": self.overflow_fallbacks,
                "errors": self.errors,
                "abandoned_results": self._abandoned,
                "batches": self.batches,
                "accel_us_per_image": per_image(1e6 * self.accel_s),
                "system_us_per_image": per_image(1e6 * self.system_s),
                "p50_latency_us":
                    float(np.percentile(lat, 50)) if lat.size else 0.0,
                "p95_latency_us":
                    float(np.percentile(lat, 95)) if lat.size else 0.0,
                "p99_latency_us":
                    float(np.percentile(lat, 99)) if lat.size else 0.0,
                "mean_latency_us": float(np.mean(lat)) if lat.size else 0.0,
                "queue_depth_mean": (self._depth_sum / self._depth_samples
                                     if self._depth_samples else 0.0),
                "queue_depth_peak": self._depth_peak,
                "batch_fill_mean": (self._batch_fill / self.batches
                                    if self.batches else 0.0),
            }
            if self.family == "board":
                clock = self.lanes[0].runtime.cost.clock_hz
                st.update({
                    "board_cycles": self.board_cycles,
                    "board_stalls": self.board_stalls,
                    "board_cycles_per_image": per_image(self.board_cycles),
                    "board_model_us_per_image":
                        per_image(1e6 * self.board_cycles / clock),
                    "board_nj_per_image": per_image(self.board_nj),
                })
            return st
