"""Production serving launcher: --arch <id>, batched request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 16 --max-new 12

SNN multi-host mode (lower once per process group): point every process at
the same exported artifact and a transport — the leader lowers and
publishes, followers fetch + verify and never lower. ``--transport`` takes
``tcp://HOST:PORT`` (network, real multi-host) or a shared filesystem path
(``--program-envelope`` is the legacy spelling of the latter).

    # leader (port 0 = ephemeral; the chosen endpoint is printed)
    PYTHONPATH=src python -m repro.launch.serve \
        --snn-artifact out/mnist.npz --transport tcp://127.0.0.1:7070 \
        --role leader --await-fetches 1 --requests 32
    # follower, on any host that holds the same artifact
    PYTHONPATH=src python -m repro.launch.serve \
        --snn-artifact out/mnist.npz --transport tcp://LEADER:7070 \
        --role follower --requests 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced as make_reduced
from repro.models.model import LM
from repro.serving.engine import ServeEngine


def serve_snn(args) -> None:
    """The SNN leader/follower path: distribute the program, then serve."""
    from repro.core.artifact import Artifact
    from repro.core.lowering import get_cache
    from repro.launch.cluster import LeaderHandle, distribute_program
    from repro.launch.mesh import broadcast_program
    from repro.serving.snn_engine import SNNServeEngine

    art = Artifact.load(args.snn_artifact)
    transport = args.transport or args.program_envelope
    if transport:
        prog, handle = distribute_program(art, transport, role=args.role,
                                          timeout_s=args.envelope_timeout)
        if handle.endpoint is not None:
            print(f"[{args.role}] publishing program at {handle.endpoint}")
    else:
        prog = broadcast_program(art, leader=args.role == "leader")
        handle = LeaderHandle()
    engine = SNNServeEngine(art, max_batch=args.max_batch)
    rng = np.random.RandomState(0)
    images = rng.rand(args.requests, prog.n_in).astype(np.float32)
    labels = engine.classify(images)
    engine.close()
    if args.labels_out:
        np.save(args.labels_out, labels)
    if args.await_fetches > 0:
        ok = handle.await_fetches(args.await_fetches,
                                  timeout_s=args.envelope_timeout)
        state = "served" if ok else "TIMED OUT awaiting"
        print(f"[{args.role}] {state} {handle.serves}/"
              f"{args.await_fetches} follower fetch(es)")
    handle.stop()
    cs = get_cache().stats()
    print(f"[{args.role}] served {args.requests} requests; "
          f"program {prog.fingerprint[:12]}... "
          f"(cache: {cs['program_misses']} lowered, "
          f"{cs['bytes']} bytes resident)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--snn-artifact",
                    help="serve an exported SNN artifact instead of an LM")
    ap.add_argument("--program-envelope",
                    help="shared path for the serialized program envelope "
                         "(legacy spelling of --transport PATH)")
    ap.add_argument("--transport",
                    help="program distribution endpoint: tcp://HOST:PORT "
                         "or a shared filesystem path")
    ap.add_argument("--role", choices=("leader", "follower"),
                    default="leader")
    ap.add_argument("--envelope-timeout", type=float, default=30.0)
    ap.add_argument("--await-fetches", type=int, default=0,
                    help="leader: block until N followers fetched the "
                         "program before tearing the endpoint down")
    ap.add_argument("--labels-out",
                    help="save served labels to this .npy (the two-process "
                         "bit-exactness gate compares them)")
    args = ap.parse_args()

    if args.snn_artifact:
        serve_snn(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --snn-artifact is given")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(lm, params, max_batch=args.max_batch, s_max=256)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, rng.randint(4, 16)).astype(np.int32)
               for _ in range(args.requests)]
    outs = engine.generate(prompts, max_new=args.max_new)
    st = engine.stats()
    print(f"served {len(outs)} requests; "
          f"accelerator {st['accelerator_s']:.2f}s / "
          f"system {st['system_s']:.2f}s")


if __name__ == "__main__":
    main()
