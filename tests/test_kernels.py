"""Per-kernel validation: shape/dtype sweeps + hypothesis, each Pallas kernel
(interpret mode) against its pure-jnp ref.py oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.event_accum.ops import event_accum
from repro.kernels.event_accum.ref import event_accum_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.lif.ops import lif_fused
from repro.kernels.lif.ref import lif_fused_ref
from repro.kernels.spike_matmul.ops import spike_matmul
from repro.kernels.spike_matmul.ref import spike_matmul_ref
from repro.kernels.ttfs_decode.ops import ttfs_decode
from repro.kernels.ttfs_decode.ref import ttfs_decode_ref


# ------------------------------------------------------------------- LIF
@pytest.mark.parametrize("B,T,N,ls", [(1, 4, 128, 4), (3, 16, 256, 2),
                                      (2, 32, 512, 6), (5, 7, 128, 31)])
def test_lif_shapes(B, T, N, ls):
    rng = np.random.RandomState(B * 100 + T)
    cur = jnp.asarray(rng.randint(-80, 150, (B, T, N)), jnp.int32)
    thr = jnp.asarray(rng.randint(10, 500, (N,)), jnp.int32)
    f_ref, v_ref = lif_fused_ref(cur, thr, ls)
    res = lif_fused(jnp.moveaxis(cur, 1, 0), thr, ls)
    assert np.array_equal(np.asarray(f_ref), np.asarray(res.first_spike))
    assert np.array_equal(np.asarray(v_ref), np.asarray(res.v_final))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lif_property(seed):
    rng = np.random.RandomState(seed % 2**32)
    B, T, N = rng.randint(1, 4), rng.randint(1, 24), 128 * rng.randint(1, 3)
    ls = int(rng.randint(1, 10))
    cur = jnp.asarray(rng.randint(-200, 300, (B, T, N)), jnp.int32)
    thr = jnp.asarray(rng.randint(1, 800, (N,)), jnp.int32)
    f_ref, v_ref = lif_fused_ref(cur, thr, ls)
    res = lif_fused(jnp.moveaxis(cur, 1, 0), thr, ls)
    assert np.array_equal(np.asarray(f_ref), np.asarray(res.first_spike))
    assert np.array_equal(np.asarray(v_ref), np.asarray(res.v_final))
    # sentinel semantics: never-fired lanes report exactly T
    fired = np.asarray(res.first_spike) < T
    assert np.all(np.asarray(res.first_spike)[~fired] == T)


# ----------------------------------------------------------- spike matmul
@pytest.mark.parametrize("B,T,K,N", [(1, 2, 100, 128), (2, 8, 784, 256),
                                     (1, 16, 300, 384), (4, 3, 129, 128)])
def test_spike_matmul_shapes(B, T, K, N):
    rng = np.random.RandomState(K)
    raster = jnp.asarray(rng.randint(0, 2, (B, T, K)), jnp.int8)
    w = jnp.asarray(rng.randint(-127, 128, (K, N)), jnp.int8)
    assert np.array_equal(np.asarray(spike_matmul(raster, w)),
                          np.asarray(spike_matmul_ref(raster, w)))


# ------------------------------------------------------------ event accum
@pytest.mark.parametrize("T,E,K,N", [(4, 16, 100, 128), (8, 64, 784, 256),
                                     (2, 128, 300, 128)])
def test_event_accum_shapes(T, E, K, N):
    rng = np.random.RandomState(T * E)
    ids = jnp.asarray(rng.randint(-1, K, (T, E)), jnp.int32)
    w = jnp.asarray(rng.randint(-127, 128, (K, N)), jnp.int8)
    assert np.array_equal(np.asarray(event_accum(ids, w)),
                          np.asarray(event_accum_ref(ids, w)))


def test_event_accum_all_padding_is_zero():
    w = jnp.asarray(np.random.RandomState(0).randint(-127, 128, (50, 128)),
                    jnp.int8)
    ids = jnp.full((4, 16), -1, jnp.int32)
    assert np.all(np.asarray(event_accum(ids, w)) == 0)


# ------------------------------------------------------------ ttfs decode
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ttfs_decode_property(seed):
    rng = np.random.RandomState(seed % 2**32)
    G, P, T = 10, 15, int(rng.randint(2, 64))
    B = int(rng.randint(1, 8))
    first = jnp.asarray(rng.randint(0, T + 1, (B, G * P)), jnp.int32)
    v = jnp.asarray(rng.randint(-500, 500, (B, G * P)), jnp.int32)
    for fb in ("membrane", "zero"):
        a = ttfs_decode(first, v, n_groups=G, per_group=P, sentinel=T,
                        fallback=fb)
        b = ttfs_decode_ref(first, v, n_groups=G, per_group=P, sentinel=T,
                            fallback=fb)
        assert np.array_equal(np.asarray(a), np.asarray(b)), fb


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal,window,qoff", [
    (1, 4, 4, 128, 128, 64, True, None, 0),
    (2, 8, 2, 128, 256, 64, True, None, 128),      # GQA + decode-offset
    (1, 4, 1, 256, 256, 128, True, 64, 0),         # SWA
    (1, 2, 2, 128, 384, 64, False, None, 0),       # cross-attention style
    (2, 4, 4, 8, 128, 64, True, None, 120),        # short q against cache
])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, causal, window, qoff,
                               dtype):
    rng = np.random.RandomState(Sq + Skv)
    q = jnp.asarray(rng.randn(B, Hq, Sq, D), dtype)
    k = jnp.asarray(rng.randn(B, Hkv, Skv, D), dtype)
    v = jnp.asarray(rng.randn(B, Hkv, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, q_offset=qoff)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              q_offset=qoff)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_matches_chunked_layer():
    """The Pallas kernel and the jnp chunked attention (the dry-run path)
    agree — so the TPU kernel is a drop-in for the compiled model."""
    from repro.models.layers import chunked_attention
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(2, 8, 256, 64), jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 256, 64), jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 256, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, bq=128, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
