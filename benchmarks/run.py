"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

| paper artifact        | module                  |
|-----------------------|-------------------------|
| Table 1 (resources)   | bench_resources         |
| Table 3 (cross-plat)  | bench_crossplatform     |
| Fig 2 (system path)   | bench_system_breakdown  |
| Fig 3 (sparsity)      | bench_sparsity          |
| §3.3 (repeatability)  | bench_repeatability     |
| Table 3 (board model) | bench_board_emu         |
| roofline (LM zoo)     | bench_roofline (reads results/dryrun) |

Every module that writes results/bench/ JSON does so through
``benchmarks.common.emit``, which validates rows against
``benchmarks.schema`` so the files stay comparable across PRs (scope +
identity + unit-suffixed metric fields). ``bench_roofline`` only prints
(it reads results/dryrun) and emits nothing.

JSON results land in results/bench/.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller test-set slices (CI mode)")
    ap.add_argument("--only", default=None,
                    help="run a single bench (e.g. sparsity)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_board_emu, bench_conformance,
                            bench_crossplatform, bench_event_pipeline,
                            bench_repeatability, bench_resources,
                            bench_roofline, bench_sparsity,
                            bench_system_breakdown)
    suite = [
        ("resources (Table 1)", bench_resources.main),
        ("crossplatform (Table 3)", bench_crossplatform.main),
        ("board_emu (Table 3 board model)", bench_board_emu.main),
        ("system_breakdown (Fig 2)", bench_system_breakdown.main),
        ("sparsity (Fig 3)", bench_sparsity.main),
        ("repeatability (sec 3.3)", bench_repeatability.main),
        ("event_pipeline (staged vs fused)", bench_event_pipeline.main),
        ("conformance (fuzzed cross-runtime agreement)",
         bench_conformance.main),
        ("roofline (LM zoo)", bench_roofline.main),
    ]
    for name, fn in suite:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        try:
            if fn is bench_roofline.main:
                fn()
            else:
                fn(quick=args.quick)
        except FileNotFoundError as e:
            print(f"[skipped: {e}]")
        print(f"[{name}: {time.time() - t0:.1f}s]")


if __name__ == "__main__":
    main()
