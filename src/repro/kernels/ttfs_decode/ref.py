"""Pure-jnp oracle: defers to the single source of truth in core.ttfs."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.ttfs import decode_labels


def ttfs_decode_ref(first_spike: jnp.ndarray, v_final: jnp.ndarray, *,
                    n_groups: int, per_group: int, sentinel: int,
                    fallback: str = "membrane") -> jnp.ndarray:
    return decode_labels(first_spike, v_final, n_groups=n_groups,
                         per_group=per_group, sentinel=sentinel,
                         fallback=fallback)
