"""Cross-runtime differential conformance suite.

The paper's central claim is semantics preservation: ONE exported artifact,
and every runtime that consumes it — software reference, accelerator
(jnp/pallas/fused), board emulator (scheduler/batched) — produces bit-exact
labels and first-spike times. The repo's agreement harness proves that on the
single trained MNIST artifact; this package generalizes the claim to *any
valid artifact*:

  * ``fuzz``    — generates random valid deployment artifacts (topologies,
    quantization, thresholds, leak shifts, decode metadata) plus adversarial
    event streams (floods, never-spike rows, exact-E_max boundaries,
    tie-heavy spike times);
  * ``oracles`` — runs every advertised runtime spec on the same fuzzed
    artifact and asserts the full oracle stack (registry consistency,
    label/first-spike/membrane bit-exactness, scheduler<->batched trace
    equivalence, FIFO never-drops, cycle/energy cost-model consistency,
    quantization error bounds);
  * ``golden``  — pinned-seed golden traces under ``tests/golden/`` with a
    regeneration CLI, so reference-semantics drift is caught even when every
    runtime drifts together;
  * ``transport_faults`` — a fault-injecting TCP proxy (truncations, flipped
    bytes, re-framed tampering, stale replays, resets, stalls, slow-loris)
    behind the ``transport`` oracle's *detected-or-bit-exact* invariant:
    a fetched program either fails loudly naming the corruption or is
    fingerprint-identical to the leader's.

``benchmarks/bench_conformance.py --check`` and
``benchmarks/bench_transport.py --check`` are the gates wired into
``scripts/check.sh`` and CI.
"""

from repro.conformance.fuzz import FuzzedCase, fuzz_case, images_from_times
from repro.conformance.oracles import ConformanceReport, OracleOutcome, run_case
from repro.conformance.transport_faults import (SCENARIOS, FaultyProxy,
                                                Scenario, run_scenario,
                                                run_suite)
from repro.conformance import golden

__all__ = ["FuzzedCase", "fuzz_case", "images_from_times",
           "ConformanceReport", "OracleOutcome", "run_case", "golden",
           "SCENARIOS", "FaultyProxy", "Scenario", "run_scenario",
           "run_suite"]
