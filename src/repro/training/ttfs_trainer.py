"""Training for the paper's TTFS classifier (784 -> 150, 10 groups x 15).

Two trainers:

  * ``train_dense_proxy`` — the deployed path. Cross-entropy on group-mean
    logits of the dense execution W·x (exactly how the paper's GPU/CPU
    baselines execute the exported parameters). Export then quantizes and
    calibrates thresholds; TTFS accuracy lands slightly below dense accuracy,
    matching the paper's 87.40 (TTFS) vs 87.69/87.70 (dense) ordering.

  * ``train_surrogate`` — a genuinely temporal trainer: differentiable LIF
    simulation in float with a sigmoid surrogate spike gradient and a
    soft-TTFS (earliest-spike) readout. Slower; provided to demonstrate the
    framework can train in the time domain, and used by tests at small scale.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import snn
from repro.training import optim as O


@dataclasses.dataclass
class TrainResult:
    model: snn.SNN
    train_acc: float
    test_acc: float
    steps: int
    wall_s: float


def _group_logits(z: jnp.ndarray, g: int, p: int) -> jnp.ndarray:
    return jnp.mean(z.reshape(z.shape[0], g, p), axis=-1)


def train_dense_proxy(images: np.ndarray, labels: np.ndarray, *,
                      test_images: np.ndarray | None = None,
                      test_labels: np.ndarray | None = None,
                      epochs: int = 5, batch: int = 256, lr: float = 3e-3,
                      seed: int = 0, t_steps: int = 32,
                      readout: snn.ReadoutSpec | None = None) -> TrainResult:
    t0 = time.perf_counter()
    readout = readout or snn.ReadoutSpec()
    g, p = readout.n_groups, readout.per_group
    n_in = images.shape[1]
    n_out = g * p
    key = jax.random.PRNGKey(seed)
    model = snn.SNN(snn.Sequential(snn.Linear(n_in, n_out, key=key),
                                   snn.LIF(t_steps=t_steps)),
                    readout=readout, encode_t=t_steps)
    params = {"w": model.body.layers[0].params["w"]}
    opt = O.adamw(lr=lr, weight_decay=1e-4)
    state = opt.init(params)

    def loss_fn(params, x, y):
        z = x @ params["w"]
        logits = _group_logits(z, g, p)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    n = len(images)
    rng = np.random.RandomState(seed)
    steps = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, state, _ = step(params, state,
                                    jnp.asarray(images[idx]),
                                    jnp.asarray(labels[idx]))
            steps += 1

    @jax.jit
    def predict(params, x):
        return jnp.argmax(_group_logits(x @ params["w"], g, p), axis=-1)

    def acc(x, y):
        preds = np.concatenate([np.asarray(predict(params, jnp.asarray(x[i:i + 2048])))
                                for i in range(0, len(x), 2048)])
        return float(np.mean(preds == y))

    model.body.layers[0].params = {"w": params["w"]}
    model.params = model.body.params = [model.body.layers[0].params, {}]
    return TrainResult(
        model=model, train_acc=acc(images, labels),
        test_acc=acc(test_images, test_labels) if test_images is not None else -1.0,
        steps=steps, wall_s=time.perf_counter() - t0)


def train_surrogate(images: np.ndarray, labels: np.ndarray, *,
                    epochs: int = 2, batch: int = 128, lr: float = 2e-3,
                    seed: int = 0, t_steps: int = 16, tau: float = 16.0,
                    threshold: float = 1.0, beta: float = 5.0,
                    readout: snn.ReadoutSpec | None = None) -> TrainResult:
    """Temporal surrogate-gradient training of the same topology.

    Float LIF over T steps; spike surrogate sigma(beta*(v - thr)); readout
    logit per group = max over time+group of a soft spike trace weighted by
    (T - t) so EARLIER spikes score higher — a differentiable TTFS proxy."""
    t0 = time.perf_counter()
    readout = readout or snn.ReadoutSpec()
    g, p = readout.n_groups, readout.per_group
    n_in = images.shape[1]
    n_out = g * p
    key = jax.random.PRNGKey(seed)
    w0 = jax.random.normal(key, (n_in, n_out), jnp.float32) / np.sqrt(n_in)
    params = {"w": w0}
    opt = O.adamw(lr=lr, weight_decay=1e-4)
    state = opt.init(params)
    decay = float(np.exp(-1.0 / tau))

    def forward(params, x):
        # TTFS-encode in float: frame raster (B, T, n_in)
        tspike = jnp.floor((1.0 - x) * (t_steps - 1))
        frames = (tspike[:, None, :] == jnp.arange(t_steps)[None, :, None])
        frames = frames.astype(jnp.float32) * (x > 0)[:, None, :]
        cur = jnp.einsum("btn,no->bto", frames, params["w"])

        def step(v, i_t):
            v = decay * v + i_t
            s = jax.nn.sigmoid(beta * (v - threshold))   # surrogate spike
            return v, s

        _, s_t = jax.lax.scan(step, jnp.zeros((x.shape[0], n_out)),
                              jnp.moveaxis(cur, 1, 0))
        s_t = jnp.moveaxis(s_t, 0, 1)                    # (B, T, n_out)
        w_time = (t_steps - jnp.arange(t_steps, dtype=jnp.float32)) / t_steps
        score = jnp.max(s_t * w_time[None, :, None], axis=1)   # earlier => higher
        return jnp.max(score.reshape(-1, g, p), axis=-1)       # (B, G)

    def loss_fn(params, x, y):
        logits = forward(params, x) * 8.0
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    n = len(images)
    rng = np.random.RandomState(seed)
    steps = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, state, _ = step(params, state, jnp.asarray(images[idx]),
                                    jnp.asarray(labels[idx]))
            steps += 1

    @jax.jit
    def predict(params, x):
        return jnp.argmax(forward(params, x), axis=-1)

    acc = float(np.mean(np.asarray(predict(params, jnp.asarray(images[:4096])))
                        == labels[:4096]))
    model = snn.SNN(snn.Sequential(snn.Linear(n_in, n_out), snn.LIF(
        t_steps=t_steps, tau=tau)), readout=readout, encode_t=t_steps)
    model.body.layers[0].params = {"w": params["w"]}
    model.params = model.body.params = [model.body.layers[0].params, {}]
    return TrainResult(model=model, train_acc=acc, test_acc=-1.0, steps=steps,
                       wall_s=time.perf_counter() - t0)
