"""Yi-6B [arXiv:2403.04652; hf]: llama-arch, 32L, d4096, 32H GQA(kv=4),
d_ff 11008, vocab 64000."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, vocab=64000,
    n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=11008, rope_theta=5e6,
)
