"""Transport distribution gate: fault-proxy sweep + fetch latency + a real
two-process leader/follower serve over TCP.

Three measurement groups, all system-scope (host wall clock):

  * the FULL fault-proxy scenario sweep (``conformance.transport_faults``,
    >= 20 scenarios incl. the stale-envelope replay the per-case oracle
    skips): every fetch must land on the detected-or-bit-exact invariant.
    ``--check`` fails on any violation and dumps the failing verdicts to
    ``results/transport_failures/`` (uploaded by CI on failure);
  * clean-path fetch latency (p50/p95 over repeated fetches of the real
    trained-artifact envelope through a live ``ProgramServer``) plus the
    retry-counter account under transient faults — the numbers
    ``ServingScheduler.stats()`` surfaces as transport health;
  * a REAL two-process ``launch.serve`` run over ``--transport tcp://``:
    leader lowers + publishes + serves, follower fetches + verifies +
    serves without lowering (asserted from its cache stats), and both
    label streams must be bit-exact with the in-process ``SNNReference``
    labels — the paper's semantics-preservation claim, now across a
    process boundary and a network hop.

Emits ``results/bench/transport.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks import common as CM
from repro.conformance.fuzz import fuzz_case
from repro.conformance.transport_faults import SCENARIOS, run_suite
from repro.core.lowering import lower
from repro.core.program_io import serialize_program
from repro.core.runtimes import make_runtime
from repro.distributed import transport as tp

FAILURES_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                            "transport_failures")
#: scenarios below this count means the sweep itself regressed
MIN_SCENARIOS = 20


def _fault_sweep_rows(art, failures_out: str) -> tuple[list[dict], list[dict]]:
    """Every fault scenario against the real artifact's envelope; failing
    verdicts are dumped as JSON repros."""
    prog = lower(art)
    blob = serialize_program(prog)
    # the stale-replay scenario needs a VALID envelope for a different
    # artifact — a fuzzed one is cheap and definitely distinct
    stale = serialize_program(lower(fuzz_case(1).artifact, cache=False))
    t0 = time.perf_counter()
    verdicts = run_suite(blob, art, prog.fingerprint, stale_blob=stale,
                         seed=0)
    wall_ms = 1e3 * (time.perf_counter() - t0)
    bad = [v for v in verdicts if not v["ok"]]
    if bad:
        os.makedirs(failures_out, exist_ok=True)
        for v in bad:
            path = os.path.join(failures_out, f"{v['scenario']}.json")
            with open(path, "w") as f:
                json.dump(v, f, indent=1)
        print(f"  {len(bad)} scenario(s) violated detected-or-bit-exact; "
              f"verdicts dumped to {failures_out}", file=sys.stderr)
    rows = [{"config": f"fault:{v['scenario']}",
             "scope": "system (transport fault proxy, host wall clock)",
             "expect": v["expect"], "outcome": v["outcome"],
             "ok": v["ok"], "connections": v["connections"],
             "wall_ms": v["wall_ms"]} for v in verdicts]
    rows.append({"config": "fault-suite",
                 "scope": "system (transport fault proxy, host wall clock)",
                 "scenarios": len(verdicts),
                 "detected": sum(v["outcome"] == "detected"
                                 for v in verdicts),
                 "bitexact": sum(v["outcome"] == "bitexact"
                                 for v in verdicts),
                 "violations": len(bad),
                 "envelope_bytes": len(blob),
                 "wall_ms": wall_ms})
    return rows, verdicts


def _latency_rows(art, iters: int) -> list[dict]:
    """Clean-path fetch latency + the retry account under transient faults,
    read back through the same metrics surface the scheduler reports."""
    blob = serialize_program(lower(art))
    tp.reset_metrics()
    with tp.ProgramServer(blob) as srv:
        for i in range(iters):
            tp.fetch_bytes(srv.host, srv.port, seed=i)
    snap = tp.metrics_snapshot()
    clean = {"config": "tcp-fetch-clean",
             "scope": "system (transport, host wall clock)",
             "fetches": int(snap.get("fetches", 0)),
             "envelope_bytes": len(blob),
             "fetch_ms_p50": float(snap.get("fetch_ms_p50", 0.0)),
             "fetch_ms_p95": float(snap.get("fetch_ms_p95", 0.0)),
             "fetch_ms_mean": float(snap.get("fetch_ms_mean", 0.0)),
             "fetch_retries": int(snap.get("fetch_retries", 0)),
             "fetch_failures": int(snap.get("fetch_failures", 0))}
    # transient faults: first 2 connections corrupted -> exactly 2 retries
    from repro.conformance.transport_faults import run_scenario
    transient = next(s for s in SCENARIOS
                     if s.name == "transient-flip-twice")
    tp.reset_metrics()
    t0 = time.perf_counter()
    verdict = run_scenario(transient, blob=blob, artifact=art,
                           leader_fingerprint=lower(art).fingerprint)
    snap = tp.metrics_snapshot()
    retry = {"config": "tcp-fetch-transient-faults",
             "scope": "system (transport, host wall clock)",
             "outcome": verdict["outcome"],
             "fetch_attempts": int(snap.get("fetch_attempts", 0)),
             "fetch_retries": int(snap.get("fetch_retries", 0)),
             "fetch_failures": int(snap.get("fetch_failures", 0)),
             "wall_ms": 1e3 * (time.perf_counter() - t0)}
    return [clean, retry]


def _free_port() -> int:
    import socket
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _two_process_row(art, requests: int = 32) -> dict:
    """Leader/follower ``launch.serve`` over tcp://, labels compared
    bit-exact against the in-process software reference."""
    port = _free_port()
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["PYTHONUNBUFFERED"] = "1"
    leader_npy = os.path.join(CM.RESULTS, "transport_leader_labels.npy")
    follower_npy = os.path.join(CM.RESULTS, "transport_follower_labels.npy")
    art_path = os.path.abspath(CM.ART_PATH)

    def cmd(role: str, labels: str, extra: list[str]) -> list[str]:
        return [sys.executable, "-m", "repro.launch.serve",
                "--snn-artifact", art_path,
                "--transport", f"tcp://127.0.0.1:{port}",
                "--role", role, "--requests", str(requests),
                "--max-batch", "8", "--envelope-timeout", "120",
                "--labels-out", labels] + extra

    t0 = time.perf_counter()
    leader = subprocess.Popen(
        cmd("leader", leader_npy, ["--await-fetches", "1"]),
        cwd=root, env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    # hold the follower until the leader's endpoint is live — followers
    # retry, but a cold jax import outlasts any sane retry budget
    lead_out: list[str] = []
    deadline = time.monotonic() + 180.0
    for line in leader.stdout:
        lead_out.append(line)
        if "publishing program at" in line or time.monotonic() > deadline:
            break
    follower = subprocess.Popen(cmd("follower", follower_npy, []),
                                cwd=root, env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)
    fol_rest, _ = follower.communicate(timeout=300)
    lead_rest, _ = leader.communicate(timeout=300)
    wall_ms = 1e3 * (time.perf_counter() - t0)
    lead_txt = "".join(lead_out) + (lead_rest or "")
    fol_txt = fol_rest or ""

    rng = np.random.RandomState(0)              # serve.py's request stream
    images = rng.rand(requests, lower(art).n_in).astype(np.float32)
    ref_labels = np.asarray(make_runtime(art, "reference")
                            .forward(images).labels)
    lead_labels = (np.load(leader_npy) if os.path.exists(leader_npy)
                   else np.array([]))
    fol_labels = (np.load(follower_npy) if os.path.exists(follower_npy)
                  else np.array([]))
    row = {"config": "two-process-serve-tcp",
           "scope": "system (multi-host serving, host wall clock)",
           "requests": requests,
           "wall_ms": wall_ms,
           "leader_rc": leader.returncode,
           "follower_rc": follower.returncode,
           "leader_lowered": "(cache: 1 lowered" in lead_txt,
           "follower_lowered_zero": "(cache: 0 lowered" in fol_txt,
           "leader_labels_bitexact": bool(
               np.array_equal(lead_labels, ref_labels)),
           "follower_labels_bitexact": bool(
               np.array_equal(fol_labels, ref_labels)),
           "leader_follower_match": bool(
               np.array_equal(lead_labels, fol_labels))}
    if leader.returncode or follower.returncode:
        print("---- leader output ----\n" + lead_txt, file=sys.stderr)
        print("---- follower output ----\n" + fol_txt, file=sys.stderr)
    return row


def main(quick: bool = False, check: bool = False,
         failures_out: str = FAILURES_DIR) -> int:
    art, _xte, _yte = CM.get_artifact_and_data(quick=quick)
    rows: list[dict] = []

    print(f"transport fault-proxy sweep ({len(SCENARIOS)} scenarios, "
          f"detected-or-bit-exact):")
    fault_rows, verdicts = _fault_sweep_rows(art, failures_out)
    rows.extend(fault_rows)
    summary = fault_rows[-1]
    print(f"  {summary['scenarios']} scenarios: {summary['detected']} "
          f"detected, {summary['bitexact']} bit-exact, "
          f"{summary['violations']} violations "
          f"({summary['wall_ms']:.0f} ms)")

    iters = 20 if quick else 100
    lat_rows = _latency_rows(art, iters)
    rows.extend(lat_rows)
    clean, retry = lat_rows
    print(f"clean fetch: p50 {clean['fetch_ms_p50']:.2f} ms  p95 "
          f"{clean['fetch_ms_p95']:.2f} ms over {clean['fetches']} fetches "
          f"({clean['envelope_bytes']} B envelope, "
          f"{clean['fetch_retries']} retries)")
    print(f"transient faults: {retry['fetch_attempts']} attempts, "
          f"{retry['fetch_retries']} retries -> {retry['outcome']}")

    tw = _two_process_row(art)
    rows.append(tw)
    print(f"two-process tcp serve: leader rc={tw['leader_rc']} "
          f"follower rc={tw['follower_rc']}, follower lowered 0: "
          f"{tw['follower_lowered_zero']}, labels bit-exact "
          f"(leader/follower/ref): {tw['leader_follower_match']}/"
          f"{tw['leader_labels_bitexact']}/{tw['follower_labels_bitexact']} "
          f"({tw['wall_ms']:.0f} ms)")

    CM.emit("transport", rows)

    if check:
        bad = []
        if summary["scenarios"] < MIN_SCENARIOS:
            bad.append(f"only {summary['scenarios']} fault scenarios ran "
                       f"(floor {MIN_SCENARIOS})")
        for v in verdicts:
            if not v["ok"]:
                bad.append(f"{v['scenario']}: expected {v['expect']}, got "
                           f"{v['outcome']} ({v['detail']})")
        if clean["fetch_retries"] or clean["fetch_failures"]:
            bad.append(f"clean path needed {clean['fetch_retries']} retries "
                       f"/ {clean['fetch_failures']} failures")
        if retry["outcome"] != "bitexact":
            bad.append(f"transient-fault fetch ended {retry['outcome']!r}, "
                       f"not healed by retries")
        if retry["fetch_retries"] < 2:
            bad.append(f"transient scenario recorded "
                       f"{retry['fetch_retries']} retries (expected >= 2)")
        if tw["leader_rc"] or tw["follower_rc"]:
            bad.append(f"two-process serve exited "
                       f"leader={tw['leader_rc']} "
                       f"follower={tw['follower_rc']}")
        if not tw["follower_lowered_zero"]:
            bad.append("follower lowered locally instead of consuming the "
                       "leader's envelope")
        for k in ("leader_labels_bitexact", "follower_labels_bitexact",
                  "leader_follower_match"):
            if not tw[k]:
                bad.append(f"two-process serve: {k} is False — served "
                           f"labels diverged")
        if bad:
            print("CHECK FAILED: " + "; ".join(bad), file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer clean-fetch iterations (the CI config)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any detected-or-bit-exact violation, "
                         "retry-accounting drift, or two-process label "
                         "divergence")
    ap.add_argument("--failures-out", default=FAILURES_DIR,
                    help="directory for failing scenario verdict dumps")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check,
                  failures_out=a.failures_out))
