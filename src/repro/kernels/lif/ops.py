"""Jitted public wrapper for the fused LIF kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lif_dynamics import LIFResult
from repro.kernels.common import use_interpret
from repro.kernels.lif.kernel import lif_fused_kernel


@functools.partial(jax.jit, static_argnames=("leak_shift",))
def _lif_fused(currents_btn: jnp.ndarray, thresholds: jnp.ndarray,
               leak_shift: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    return lif_fused_kernel(currents_btn, thresholds, leak_shift,
                            interpret=use_interpret())


def lif_fused(currents: jnp.ndarray, thresholds: jnp.ndarray,
              leak_shift: int) -> LIFResult:
    """currents (T, B, N_pad) int32 (scan layout) -> LIFResult over (B, N_pad).

    Accepts the same layout core.lif_dynamics.lif_scan uses so the
    accelerator can swap implementations freely."""
    c = jnp.moveaxis(currents, 0, 1)  # (B, T, N)
    first, v = _lif_fused(c, thresholds, leak_shift)
    return LIFResult(first_spike=first, v_final=v)
