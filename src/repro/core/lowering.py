"""The one lowering stage: ``Artifact → LoweredProgram``.

The paper's single-artifact contract says ONE exported object carries
weights, thresholds, connectivity and grouped TTFS decode metadata unchanged
from software definition to board execution. This module is where that
contract becomes code: ``lower(artifact)`` validates and coerces the meta
ONCE into a frozen, fingerprinted ``LoweredProgram``, and every runtime
family (reference, accelerator batch/event, board-py, board-batched, the
serving scheduler's host packer, the fault detectors) consumes the program
instead of re-reading ``artifact.m(...)`` at seven-plus sites.

Two cache tiers hang off the lowering stage, both process-wide and keyed by
content, never by object identity:

  * program cache — ``artifact.fingerprint() → LoweredProgram``. The
    fingerprint is recomputed from the actual array bytes + volatile-stripped
    meta, so a fault-pass clone (different bytes) can never alias the
    pristine program. The tier is a **byte-budget LRU**: each program is
    charged the device-array bytes it pins (``program_nbytes``), a hit
    refreshes recency, and inserts past ``max_bytes`` evict from the cold
    end — bundles die with their program (bundle keys carry the program
    fingerprint at index 1).
  * bundle cache — ``(family, program fingerprint, mode/kernel/latency/cost)
    → jitted-callable bundle``. jax caches compiled executables on the
    FUNCTION OBJECT, so sharing the bundle across runtime instances (e.g.
    every serving lane, including watchdog-spawned replacements) means one
    compile per distinct config per process instead of one per lane.

The process-wide default lives in ``PROGRAM_CACHE``; call sites resolve it
through ``get_cache()`` so benches and tests can swap in a scoped cache with
``install()`` (mirroring ``telemetry.trace.install``) instead of clearing
the singleton out from under live engines.

Static fault plans are a lowering pass: ``lower_with_faults`` corrupts an
in-memory CLONE of the artifact (pristine artifact untouched — it backs the
scrub/reload recovery path) and lowers the clone; dynamic plans stay a
board-py runtime concern and never enter this module.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.core.artifact import Artifact
from repro.core.hw import PYNQ_COST, BoardCostModel
from repro.core.types import DecodePlan, EncodePlan


class LoweringError(ValueError):
    """The artifact's metadata or arrays do not lower to a valid program."""


_MISSING = object()


def _meta(art: Artifact, path: tuple[str, ...], kind: str):
    """One coercion point for every execution parameter the runtimes used to
    read ad hoc: missing paths and junk values fail HERE, at lowering time,
    with the offending meta path named — not deep inside a jitted forward."""
    val = art.m(*path, default=_MISSING)
    if val is _MISSING:
        raise LoweringError(f"artifact meta missing {'.'.join(path)!r}")
    if kind == "int":
        if isinstance(val, bool):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to int")
        if isinstance(val, (int, np.integer)):
            return int(val)
        if isinstance(val, (float, np.floating)):
            if float(val).is_integer():
                return int(val)
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to int")
        if isinstance(val, str):
            try:
                return int(val, 10)
            except ValueError:
                raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                    f"does not lower to int") from None
        raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                            f"does not lower to int")
    if kind == "float":
        if isinstance(val, bool):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to float")
        try:
            out = float(val)
        except (TypeError, ValueError):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to float") from None
        if not np.isfinite(out):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"is not finite")
        return out
    if kind == "str":
        if not isinstance(val, str):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to str")
        return val
    raise AssertionError(kind)


@dataclasses.dataclass(frozen=True, eq=False)
class LoweredProgram:
    """Frozen execution view of one deployment artifact.

    Everything a runtime needs to execute — typed scalars, device-ready
    arrays, the encode/decode plans, the cost-model binding — validated and
    coerced once. ``artifact`` is the back-reference the integrity detectors
    re-hash; runtimes keep ``self.art = program.artifact`` for exactly that.
    """

    fingerprint: str          # program identity (derives from the artifact's)
    artifact: Artifact        # back-ref for integrity re-hashing / export
    # ---- typed scalars ----
    T: int
    x_min: float
    e_max: int
    leak_shift: int
    n_in: int
    n_out: int
    n_groups: int
    per_group: int
    fallback: str
    scale: float              # quantization scale (dense int8 baseline)
    n_pad: int                # padded output width (lane-aligned)
    lane: int                 # blocked-layout lane width from the planner
    # ---- device-ready arrays ----
    w_float: jnp.ndarray      # (N_in, N_out) fp32
    w_int8: jnp.ndarray       # (N_in, N_out) int8
    thresholds: jnp.ndarray   # (N_out,) int32
    w_padded: jnp.ndarray     # (N_in, N_pad) int8 — blocked layout
    thr_padded: jnp.ndarray   # (N_pad,) int32
    # ---- stage plans + cost binding ----
    encode: EncodePlan
    decode: DecodePlan
    cost: BoardCostModel

    def host_arrays(self) -> dict[str, np.ndarray]:
        """The artifact's raw numpy arrays (host side, never device).

        Defensive: a fresh dict of read-only views. The cached program is
        shared by every fingerprint-keyed hit in the process, so handing out
        the live ``artifact.arrays`` dict would let one caller's in-place
        mutation silently poison all later hits without changing the cache
        key. Callers who need to write must copy explicitly."""
        out: dict[str, np.ndarray] = {}
        for name, arr in self.artifact.arrays.items():
            view = arr.view()
            view.setflags(write=False)
            out[name] = view
        return out


def program_fingerprint(art_fp: str, scalars: dict[str, Any]) -> str:
    h = hashlib.sha256()
    h.update(art_fp.encode())
    h.update(json.dumps(scalars, sort_keys=True).encode())
    return h.hexdigest()


REQUIRED_ARRAYS = ("w_float", "w_int8", "thresholds", "w_padded",
                   "thr_padded")


def _lower_uncached(art: Artifact) -> LoweredProgram:
    missing = [n for n in REQUIRED_ARRAYS if n not in art.arrays]
    if missing:
        raise LoweringError(f"artifact is missing arrays {missing}")
    T = _meta(art, ("encode", "T"), "int")
    if T <= 0:
        raise LoweringError(f"encode.T={T} must be positive")
    x_min = _meta(art, ("encode", "x_min"), "float")
    e_max = _meta(art, ("events", "e_max"), "int")
    leak_shift = _meta(art, ("lif", "leak_shift"), "int")
    n_in = _meta(art, ("model", "n_in"), "int")
    n_out = _meta(art, ("model", "n_out"), "int")
    n_groups = _meta(art, ("readout", "n_groups"), "int")
    per_group = _meta(art, ("readout", "per_group"), "int")
    fallback = _meta(art, ("readout", "fallback"), "str")
    scale = _meta(art, ("quant", "scale"), "float")
    lane = _meta(art, ("codesign", "lane"), "int")
    if e_max <= 0:
        raise LoweringError(f"events.e_max={e_max} must be positive")
    if per_group <= 0:
        raise LoweringError(f"readout.per_group={per_group} must be positive")
    if lane <= 0:
        raise LoweringError(f"codesign.lane={lane} must be positive")
    if scale <= 0:
        raise LoweringError(f"quant.scale={scale} must be positive")
    if fallback not in ("membrane", "zero"):
        raise LoweringError(f"readout.fallback={fallback!r} is not a known "
                            f"no-spike policy ('membrane' | 'zero')")
    if n_groups * per_group != n_out:
        raise LoweringError(
            f"readout geometry n_groups*per_group = {n_groups}*{per_group} "
            f"!= model.n_out = {n_out}")
    n_pad = int(art["thr_padded"].shape[0])
    if art["w_padded"].shape != (n_in, n_pad):
        raise LoweringError(
            f"w_padded shape {art['w_padded'].shape} != "
            f"(n_in={n_in}, n_pad={n_pad})")
    if art["w_int8"].shape != (n_in, n_out):
        raise LoweringError(
            f"w_int8 shape {art['w_int8'].shape} != "
            f"(n_in={n_in}, n_out={n_out})")
    if n_pad < n_out:
        raise LoweringError(f"padded width {n_pad} < n_out {n_out}")
    scalars = {"T": T, "x_min": x_min, "e_max": e_max,
               "leak_shift": leak_shift, "n_in": n_in, "n_out": n_out,
               "n_groups": n_groups, "per_group": per_group,
               "fallback": fallback, "scale": scale, "n_pad": n_pad,
               "lane": lane}
    return LoweredProgram(
        fingerprint=program_fingerprint(art.fingerprint(), scalars),
        artifact=art,
        T=T, x_min=x_min, e_max=e_max, leak_shift=leak_shift,
        n_in=n_in, n_out=n_out, n_groups=n_groups, per_group=per_group,
        fallback=fallback, scale=scale, n_pad=n_pad, lane=lane,
        w_float=jnp.asarray(art["w_float"]),
        w_int8=jnp.asarray(art["w_int8"]),
        thresholds=jnp.asarray(art["thresholds"]),
        w_padded=jnp.asarray(art["w_padded"]),
        thr_padded=jnp.asarray(art["thr_padded"]),
        encode=EncodePlan(T=T, x_min=x_min, e_max=e_max, n_in=n_in),
        decode=DecodePlan(n_groups=n_groups, per_group=per_group,
                          sentinel=T, fallback=fallback),
        cost=PYNQ_COST)


def program_nbytes(prog: LoweredProgram) -> int:
    """Bytes a resident program pins: the sum over its device arrays.

    The LRU budget charges device arrays only — scalars and plans are noise
    next to the weight matrices, and the host-side artifact backs the
    scrub/reload path regardless of cache residency."""
    return sum(int(getattr(prog, name).nbytes) for name in REQUIRED_ARRAYS)


#: default byte budget for the program tier (overridable per-process)
DEFAULT_MAX_BYTES = int(os.environ.get("REPRO_PROGRAM_CACHE_BYTES",
                                       1 << 30))


class ProgramCache:
    """Process-wide content-addressed caches for lowered programs and their
    compiled-callable bundles. Keys are content fingerprints plus the exact
    runtime config, never python object identity — a corrupted clone or a
    re-exported artifact gets its own entry, a watchdog-spawned replacement
    lane over the same artifact gets a hit.

    The program tier is a byte-budget LRU (``max_bytes``, ``None`` =
    unbounded): hits refresh recency, inserts past the budget evict from
    the cold end, and every bundle whose key carries the victim's program
    fingerprint (index 1 by convention) is dropped with it — a compiled
    callable over an evicted program would otherwise pin its device arrays
    forever through the closure.

    Bundles built over programs that were never cached (cache-bypassing
    ``lower(..., cache=False)`` callers that then build runtimes) pin those
    programs' device arrays through their closures all the same, so their
    bytes are charged to the SAME budget as an **orphan** entry keyed by the
    program fingerprint: one charge per distinct orphan program no matter
    how many bundles share it, refreshed on bundle hits, evicted (with its
    bundles) before any resident program — orphans are the least-trusted
    tier since nothing else can re-reach them by artifact fingerprint. If
    the program is later properly installed, the orphan charge merges into
    the resident charge (no double count) and its bundles co-evict with the
    program from then on."""

    def __init__(self, max_bytes: int | None = DEFAULT_MAX_BYTES):
        self._lock = threading.Lock()
        self._programs: OrderedDict[str, LoweredProgram] = OrderedDict()
        self._bundles: dict[tuple, Any] = {}
        #: program fingerprint → charged bytes, for bundle-only residents
        self._orphans: OrderedDict[str, int] = OrderedDict()
        self.max_bytes = max_bytes
        self.bytes = 0
        self.evictions = 0
        self.program_hits = 0
        self.program_misses = 0
        self.bundle_hits = 0
        self.bundle_misses = 0

    # -- internal (lock held) -------------------------------------------
    def _install_locked(self, key: str,
                        prog: LoweredProgram) -> tuple[LoweredProgram, bool]:
        existing = self._programs.get(key)
        if existing is not None:
            self._programs.move_to_end(key)
            return existing, False
        orphaned = self._orphans.pop(prog.fingerprint, None)
        if orphaned is not None:
            # the program's bytes were already charged via its bundles;
            # fold the orphan charge into the resident charge
            self.bytes -= orphaned
        self._programs[key] = prog
        self.bytes += program_nbytes(prog)
        self._evict_locked()
        return prog, True

    def _drop_bundles_locked(self, prog_fp: str) -> None:
        dead = [k for k in self._bundles
                if len(k) > 1 and k[1] == prog_fp]
        for k in dead:
            del self._bundles[k]

    def _evict_locked(self) -> None:
        if self.max_bytes is None:
            return
        while self.bytes > self.max_bytes and self._orphans:
            fp, nbytes = self._orphans.popitem(last=False)
            self.bytes -= nbytes
            self.evictions += 1
            self._drop_bundles_locked(fp)
        while self.bytes > self.max_bytes and len(self._programs) > 1:
            victim_key, victim = next(iter(self._programs.items()))
            del self._programs[victim_key]
            self.bytes -= program_nbytes(victim)
            self.evictions += 1
            self._drop_bundles_locked(victim.fingerprint)

    # -- program tier ---------------------------------------------------
    def program(self, art: Artifact) -> tuple[LoweredProgram, bool]:
        key = art.fingerprint()
        with self._lock:
            prog = self._programs.get(key)
            if prog is not None:
                self._programs.move_to_end(key)
                self.program_hits += 1
                return prog, True
        prog = _lower_uncached(art)
        with self._lock:
            # first lowering wins (two racing lowers of the same artifact
            # produce equal programs anyway — determinism is the oracle).
            # Only the installing thread counts a miss: the loser's build is
            # discarded, so counting it would over-count distinct builds.
            cached, installed = self._install_locked(key, prog)
            if installed:
                self.program_misses += 1
            else:
                self.program_hits += 1
        return cached, not installed

    def seed(self, art_fp: str, prog: LoweredProgram) -> LoweredProgram:
        """Install an externally-derived program (the ``deserialize`` path)
        under its artifact fingerprint. First installer wins, same as a
        racing lower; returns the resident program."""
        with self._lock:
            cached, _ = self._install_locked(art_fp, prog)
            return cached

    def peek(self, art_fp: str) -> LoweredProgram | None:
        """The resident program for an artifact fingerprint, or ``None`` —
        NEVER lowers. The broadcast follower's pre-warm check: a follower
        whose cache already holds the program must not touch the transport.
        A resident peek counts as a hit and refreshes recency (it is a use
        like any other)."""
        with self._lock:
            prog = self._programs.get(art_fp)
            if prog is not None:
                self._programs.move_to_end(art_fp)
                self.program_hits += 1
            return prog

    # -- bundle tier ----------------------------------------------------
    def bundle(self, key: tuple, build: Callable[[], Any],
               nbytes: int = 0) -> tuple[Any, bool]:
        """Get-or-build a compiled bundle. ``nbytes`` is the device-array
        bytes the bundle's program pins (``program_nbytes``); when the
        program is not cache-resident, that charge enters the LRU budget as
        an orphan so cache-bypassing callers cannot pin unbounded device
        memory invisibly."""
        with self._lock:
            if key in self._bundles:
                self.bundle_hits += 1
                fp = key[1] if len(key) > 1 else None
                if fp in self._orphans:
                    self._orphans.move_to_end(fp)
                return self._bundles[key], True
        built = build()
        with self._lock:
            if key in self._bundles:
                # a racing build won the install; this thread's compile is
                # discarded and counts as a hit, not a second miss
                self.bundle_hits += 1
                return self._bundles[key], True
            self._bundles[key] = built
            self.bundle_misses += 1
            fp = key[1] if len(key) > 1 else None
            if (fp is not None and nbytes > 0 and fp not in self._orphans
                    and not any(p.fingerprint == fp
                                for p in self._programs.values())):
                self._orphans[fp] = int(nbytes)
                self.bytes += int(nbytes)
                self._evict_locked()
        return built, False

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self._bundles.clear()
            self._orphans.clear()
            self.bytes = 0
            self.evictions = 0
            self.program_hits = self.program_misses = 0
            self.bundle_hits = self.bundle_misses = 0

    def stats(self) -> dict:
        with self._lock:
            return {"programs": len(self._programs),
                    "bundles": len(self._bundles),
                    "bytes": self.bytes,
                    "max_bytes": self.max_bytes,
                    "evictions": self.evictions,
                    "program_hits": self.program_hits,
                    "program_misses": self.program_misses,
                    "bundle_hits": self.bundle_hits,
                    "bundle_misses": self.bundle_misses,
                    "orphan_programs": len(self._orphans),
                    "orphan_bundle_bytes": sum(self._orphans.values())}


#: the process-wide default cache every ``make_runtime`` / serving lane shares
PROGRAM_CACHE = ProgramCache()

_cache: ProgramCache = PROGRAM_CACHE


def get_cache() -> ProgramCache:
    """The cache in effect for this process (the swap scope's, else the
    process-wide ``PROGRAM_CACHE``)."""
    return _cache


def install(cache: ProgramCache | None) -> ProgramCache:
    """Swap the active program cache, returning the previous one (mirrors
    ``telemetry.trace.install``). ``install(None)`` restores the process-wide
    default. Benches and tests scope their cache churn this way instead of
    calling ``clear()`` on the shared singleton, which would yank programs
    out from under any live engine in the process."""
    global _cache
    prev = _cache
    _cache = PROGRAM_CACHE if cache is None else cache
    return prev


def lower(artifact: Artifact | LoweredProgram, *,
          cache: bool = True) -> LoweredProgram:
    """Lower an artifact to its frozen execution program.

    Idempotent: passing an already-lowered program returns it unchanged.
    ``cache=False`` forces a fresh lowering (the determinism oracle compares
    two independent lowers; export-time validation avoids caching a program
    whose artifact ``save()`` is about to re-stamp)."""
    if isinstance(artifact, LoweredProgram):
        return artifact
    if not isinstance(artifact, Artifact):
        raise TypeError(f"cannot lower {type(artifact).__name__} "
                        f"(expected Artifact or LoweredProgram)")
    if cache:
        prog, _ = get_cache().program(artifact)
        return prog
    return _lower_uncached(artifact)


def lower_with_faults(artifact: Artifact | LoweredProgram,
                      plan) -> LoweredProgram:
    """The static-fault lowering pass: corrupt an in-memory CLONE of the
    artifact per the plan's seeded SEU fields, then lower the clone. The
    pristine artifact (and its cached program) are untouched; the corrupted
    program gets its own content fingerprint, so cache entries never alias."""
    from repro.faults.models import corrupt_artifact
    art = artifact.artifact if isinstance(artifact, LoweredProgram) \
        else artifact
    return lower(corrupt_artifact(art, plan))
