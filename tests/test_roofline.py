"""Roofline machinery: HLO collective parsing with while-trip scaling, and
the analytic cost model's sanity."""

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.distributed import analytic as AN
from repro.distributed import hloparse as HP

HLO = """\
HloModule jit_f, is_scheduled=true

%add.clone (x: f32[], y: f32[]) -> f32[] {
  ROOT %add = f32[] add(%x, %y)
}

%cond (arg: (s32[], f32[4,16])) -> pred[] {
  %c = s32[] constant(5)
  %i = s32[] get-tuple-element(%arg), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (arg: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
  %x = f32[4,16]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[4,16]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add.clone
  %ag = f32[8,16]{1,0} all-gather(%ar), dimensions={0}
  ROOT %t = (s32[], f32[4,16]) tuple(%i2, %ar)
}

ENTRY %main (p: f32[4,16]) -> f32[4,16] {
  %ag0 = f32[16,16]{1,0} all-gather(%p), dimensions={0}
  %w = (s32[], f32[4,16]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[4,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_while_scaled():
    coll = HP.collective_bytes_scaled(HLO)
    # entry all-gather: 16*16*4 = 1024 B; body per trip: AR 4*16*4=256,
    # AG 8*16*4=512; trip = 5
    assert coll["all-gather"] == 1024 + 5 * 512
    assert coll["all-reduce"] == 5 * 256
    wire = HP.wire_bytes(coll)
    assert wire == (1024 + 5 * 512) + 2 * 5 * 256


def test_parse_module_structure():
    comps, entry = HP.parse_module(HLO)
    assert entry == "main"
    assert comps["cond"].max_s32_const == 5
    assert comps["main"].whiles == [("cond", "body")]


def test_analytic_train_flops_close_to_6nd():
    """For a dense arch, analytic total flops ~= remat_factor/6 * 6*N*D plus
    attention — within a 2x band of the MODEL_FLOPS yardstick."""
    cfg = get_config("yi-6b")
    cell = SHAPES["train_4k"]
    est = AN.estimate(cfg, cell, chips=256)
    model = 6.0 * cfg.active_param_count() * cell.global_batch * cell.seq_len
    assert 0.8 * model < est["flops_global"] < 2.5 * model


def test_analytic_decode_is_memory_dominated():
    cfg = get_config("yi-6b")
    est = AN.estimate(cfg, SHAPES["decode_32k"], chips=256)
    from repro.core.hw import TPU_V5E
    c = est["flops_per_chip"] / TPU_V5E.peak_bf16_flops
    m = est["bytes_per_chip"] / TPU_V5E.hbm_bandwidth
    assert m > c          # single-token decode must be bandwidth-bound


def test_analytic_swa_caps_attention():
    """Mixtral's SWA must make long-context attention flops window-bounded."""
    cfg = get_config("mixtral-8x7b")
    est_sw = AN._attn_flops(cfg, SHAPES["prefill_32k"])
    import dataclasses
    cfg_full = dataclasses.replace(cfg, attn_window=None)
    est_full = AN._attn_flops(cfg_full, SHAPES["prefill_32k"])
    assert est_sw < est_full / 3
