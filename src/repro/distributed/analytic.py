"""Analytic per-chip FLOPs / HBM-bytes model for the roofline.

Why analytic: XLA's cost_analysis does not multiply while-body costs by trip
counts, so any scan-over-layers model is undercounted by ~n_layers. Rather
than unrolling 94-layer stacks (compile-time explosion), we count the costs
the compiled program actually executes from the architecture config — the
standard napkin-math roofline, kept in one auditable place. The dry-run
records BOTH this model and the raw cost_analysis numbers (the latter tagged
with its scan caveat).

FLOPs (global, then /chips):
    matmul params:  2 * (N_active - embed_gather_params) * tokens
    attention:      4 * B * Hq * Dh * sum_ctx   (QK^T + PV, causal/window aware)
    SSD (mamba2):   ~= 2*B*S*H*(Q*N + Q*P + 2*N*P + 3*N*P/chunk-amortized)
    train factor:   fwd * (4 with remat: 1 fwd + 1 remat-fwd + 2 bwd; else 3)
    optimizer:      ~12 flops/param (adam) or ~8 (adafactor)

HBM bytes per chip (first-order traffic, not footprint):
    weights:        N_bytes/chips * passes (fwd, remat, bwd-grad, bwd-wgrad)
    grads+opt:      adam: read+write m,v (f32) + grad + param  ~ 20B/param
    activations:    layer-boundary saves + recompute reads ~ 4 * L * B*S*d*2
    KV/state reads: decode: full cache read per step; prefill: KV stream
    logits path:    B*S*V*2 (+ f32 softmax pass for train)
"""

from __future__ import annotations

from repro.models.config import ArchConfig


def _attn_layers(cfg: ArchConfig) -> int:
    return sum(1 for k in cfg.period for _ in [k] if k == "attn") * cfg.n_periods


def _mamba_layers(cfg: ArchConfig) -> int:
    return sum(1 for k in cfg.period if k == "mamba") * cfg.n_periods


def _tokens(cfg: ArchConfig, cell) -> int:
    if cfg.family == "audio" and cell.kind != "decode":
        return cell.global_batch * cfg.dec_max_len
    if cell.kind == "decode":
        return cell.global_batch
    return cell.global_batch * cell.seq_len


def _attn_flops(cfg: ArchConfig, cell) -> float:
    """4*B*Hq*Dh*sum_over_queries(ctx)."""
    nl = _attn_layers(cfg)
    if nl == 0:
        return 0.0
    B = cell.global_batch
    if cell.kind == "decode":
        ctx = min(cell.seq_len, cfg.attn_window or cell.seq_len)
        per_layer = 4.0 * B * cfg.n_heads * cfg.d_head * ctx
        f = nl * per_layer
    else:
        S = cfg.dec_max_len if cfg.family == "audio" else cell.seq_len
        if cfg.attn_window and cfg.attn_window < S:
            sum_ctx = S * cfg.attn_window  # window-bounded
        else:
            sum_ctx = S * (S + 1) / 2      # causal triangle
        f = nl * 4.0 * B * cfg.n_heads * cfg.d_head * sum_ctx
        if cfg.enc_layers:  # whisper: encoder self (full) + decoder cross
            Senc = cell.seq_len
            f += cfg.enc_layers * 4.0 * B * cfg.n_heads * cfg.d_head * Senc * Senc
            f += cfg.n_layers * 4.0 * B * cfg.n_heads * cfg.d_head * \
                cfg.dec_max_len * cfg.cross_len
    return f


def _ssd_flops(cfg: ArchConfig, cell) -> float:
    nl = _mamba_layers(cfg)
    if nl == 0:
        return 0.0
    B = cell.global_batch
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_d_state
    if cell.kind == "decode":
        # state update + readout: ~4*H*N*P per token
        return nl * 4.0 * B * H * N * P
    S = cell.seq_len
    Q = cfg.ssm_chunk
    # scores (Q*N) + y_diag (Q*P) + states/y_off (2*N*P) per position
    return nl * 2.0 * B * S * H * (Q * N + Q * P + 2 * N * P)


def estimate(cfg: ArchConfig, cell, chips: int) -> dict:
    toks = _tokens(cfg, cell)
    n_active = cfg.active_param_count()
    # input-embedding gather does no flops — but with tied embeddings the
    # same table still performs the logits matmul, so nothing is subtracted.
    embed_gather = 0 if cfg.tie_embeddings else cfg.vocab * cfg.d_model
    n_matmul = max(n_active - embed_gather, 0)
    fwd = 2.0 * n_matmul * toks + _attn_flops(cfg, cell) + _ssd_flops(cfg, cell)

    if cell.kind == "train":
        # fwd(1) + bwd(2) + remat recompute: full policy re-runs the whole
        # forward (+1); dots policy recomputes only non-matmul ops (~+0.15)
        policy = cfg.remat_policy if cfg.remat else "none"
        factor = {"full": 4.0, "dots": 3.15, "none": 3.0}[policy]
        opt = (12.0 if cfg.optimizer == "adamw" else 8.0) * cfg.param_count()
        flops = fwd * factor + opt
    else:
        flops = fwd

    # ---------------- bytes (per-chip HBM traffic) -----------------------
    P_bytes = 2.0 * cfg.param_count()                 # bf16 at rest
    B = cell.global_batch
    S = cfg.dec_max_len if cfg.family == "audio" and cell.kind != "decode" \
        else cell.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    act_unit = B * S * d * 2.0                        # one boundary, bf16
    if cell.kind == "train":
        policy = cfg.remat_policy if cfg.remat else "none"
        wb = P_bytes * {"full": 4.0, "dots": 3.3, "none": 3.0}[policy]
        opt_b = (20.0 if cfg.optimizer == "adamw" else 8.0) * cfg.param_count()
        # dots policy saves ~6 boundary tensors per layer instead of 1
        act_b = {"full": 4.0, "dots": 14.0, "none": 10.0}[policy] * L * act_unit
        logit_b = 2.0 * B * S * cfg.vocab * 2.0 + 4.0 * B * S * cfg.vocab
        byts = wb + opt_b + act_b + logit_b
    elif cell.kind == "prefill":
        byts = P_bytes + 2.0 * L * act_unit + B * S * cfg.vocab * 2.0
    else:  # decode: weight-read + cache-read bound
        n_read = 2.0 * n_active                        # active params, bf16
        kv = 0.0
        nl = _attn_layers(cfg)
        if nl:
            ctx = min(cell.seq_len, cfg.attn_window or cell.seq_len)
            kv += nl * 2.0 * B * cfg.n_kv_heads * ctx * cfg.d_head * 2.0
        nm = _mamba_layers(cfg)
        if nm:
            kv += nm * 2.0 * B * cfg.ssm_heads * cfg.ssm_d_state * \
                cfg.ssm_head_dim * 4.0                 # f32 state r+w
        byts = n_read + kv + B * cfg.vocab * 2.0
    return {
        "flops_per_chip": flops / chips,
        "bytes_per_chip": byts / chips,
        "flops_global": flops,
        "bytes_global": byts,
        "fwd_flops_global": fwd,
        "tokens": toks,
    }
