"""Vectorized board emulator — the full-test-set fast path.

Same microarchitectural semantics as ``board.runtime.SNNBoard`` (the per-image
scheduler), evaluated batched in jax with the hardware group dimension
explicit: currents are shaped (T, B, G, lane) and the integer LIF recurrence
runs over per-group lanes exactly as the grouped neuron core does — so a
full-10k three-way agreement run finishes in seconds, not hours.

Bit-exactness contract (asserted by tests and the bench ``--check`` gate):
labels, first-spike times, membranes, steps, AND the cycle/energy traces are
identical to the per-image scheduler in both modes. The cycle/energy account
is computed from the same per-tick event counts through the same
``board.energy.account`` function; in latency mode the membrane reported is
the membrane AT THE EXIT TICK (gathered from the scan's v history), matching
the scheduler's early stop.

``kernel="pallas"`` routes the full-T LIF recurrence through the fused
Pallas kernel (grid over 128-lane group blocks, interpret mode on CPU);
``kernel="jnp"`` is the default jnp mirror. Both are bit-exact.

Execution parameters come from the lowered program (``core.lowering``); the
jitted device core is cached process-wide per (program, kernel,
latency_mode, cost), so serving lanes over the same artifact share one
compiled core.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.board.energy import BoardTrace, account, span_attrs
from repro.core import ttfs
from repro.core.artifact import Artifact
from repro.core.events import step_counts
from repro.core.hw import BoardCostModel, PYNQ_COST
from repro.core.lowering import (LoweredProgram, get_cache, lower,
                                 program_nbytes)
from repro.core.types import SNNOutput, decode_output
from repro.telemetry import trace as ttrace


def _build_core(prog: LoweredProgram, kernel: str, latency_mode: bool,
                cost: BoardCostModel):
    """The jitted device core for one (program, kernel, mode, cost) config —
    a module-level closure over program fields, shared via the program cache
    (jax caches executables on the function object)."""
    T, lane = prog.T, cost.lane
    n_pad, n_out = prog.n_pad, prog.n_out
    leak_shift = prog.leak_shift
    groups_used = n_pad // lane
    w_padded = prog.w_padded                                    # (N_in, n_pad)
    thr_grouped = prog.thr_padded.reshape(groups_used, lane)
    plan = prog.decode

    def lif_grouped(currents: jnp.ndarray, want_history: bool):
        """currents (T, B, G, lane) -> (LIFResult over (B, G, lane), vs|None)."""
        from repro.core.lif_dynamics import lif_scan
        if want_history:
            return lif_scan(currents, thr_grouped, leak_shift, T,
                            return_v_history=True)
        if kernel == "pallas":
            from repro.kernels.lif import ops as lif_ops
            Tc, B = currents.shape[:2]
            res = lif_ops.lif_fused(currents.reshape(Tc, B, n_pad),
                                    thr_grouped.reshape(n_pad),
                                    leak_shift)
            def shaped(a):
                return a.reshape(B, groups_used, lane)
            return res._replace(first_spike=shaped(res.first_spike),
                                v_final=shaped(res.v_final)), None
        return lif_scan(currents, thr_grouped, leak_shift, T), None

    def core_impl(times: jnp.ndarray):
        """times (B, N_in) int32 -> (labels, first_l, v_l, steps)."""
        B = times.shape[0]
        raster = ttfs.frames_from_times(times, T)               # (B, T, N_in)
        cur = jax.lax.dot_general(raster, w_padded,
                                  (((2,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        cur = jnp.moveaxis(cur, 1, 0).reshape(T, B, groups_used, lane)
        res, vs = lif_grouped(cur, want_history=latency_mode)
        first = res.first_spike.reshape(B, n_pad)
        first_l = first[:, :n_out]
        if latency_mode:
            # TTFS decision point: stop at the first output spike. Gather the
            # membrane at each row's exit tick and mask spikes the scheduler
            # never saw — identical to the per-image early stop.
            t_first = jnp.min(first_l, axis=1)                  # (B,)
            steps = jnp.where(t_first < T, t_first + 1, T).astype(jnp.int32)
            v_exit = jnp.take_along_axis(
                jnp.moveaxis(vs.reshape(T, B, n_pad), 0, 1),
                (steps - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            first_l = jnp.where(first_l <= t_first[:, None], first_l, T)
            v_l = v_exit[:, :n_out]
        else:
            steps = jnp.full((B,), T, jnp.int32)
            v_l = res.v_final.reshape(B, n_pad)[:, :n_out]
        labels = decode_output(first_l, v_l, plan)
        return labels, first_l, v_l, steps

    return jax.jit(core_impl)


class SNNBoardBatched:
    def __init__(self, artifact: Artifact | LoweredProgram, *,
                 latency_mode: bool = False,
                 kernel: str = "jnp", cost: BoardCostModel = PYNQ_COST):
        if kernel not in ("jnp", "pallas"):
            raise ValueError(
                f"board kernel {kernel!r} not supported (use 'jnp' or "
                f"'pallas' — registry specs 'board-batched-jnp' / "
                f"'board-batched-pallas'; 'fused' is an accelerator-family "
                f"kernel)")
        prog = lower(artifact)
        self.program = prog
        self.art = prog.artifact
        self.cost = cost
        self.kernel = kernel
        self.latency_mode = bool(latency_mode)
        self.T = prog.T
        self.x_min = prog.x_min
        self.n_out = prog.n_out
        self.depth = prog.e_max
        n_pad = prog.n_pad
        if n_pad % cost.lane:
            raise ValueError(f"n_pad {n_pad} not lane-aligned ({cost.lane})")
        self.groups_used = n_pad // cost.lane
        if self.groups_used > cost.groups:
            raise ValueError(f"network needs {self.groups_used} groups; the "
                             f"board has {cost.groups}")
        self.n_pad = n_pad
        self.w_padded = prog.w_padded                           # (N_in, n_pad)
        self.thr_grouped = prog.thr_padded.reshape(self.groups_used,
                                                   cost.lane)
        self._core, self.cache_hit = get_cache().bundle(
            ("board-batched", prog.fingerprint, kernel,
             self.latency_mode, cost),
            lambda: _build_core(prog, kernel, self.latency_mode, cost),
            nbytes=program_nbytes(prog))
        self.last_trace: BoardTrace | None = None
        # per-forward (B, T) dispatch histogram — the trace detector's input
        self.last_tick_counts: np.ndarray | None = None

    # ------------------------------------------------------------- host front
    def forward(self, images) -> SNNOutput:
        # telemetry: same canonical span tree as the per-image scheduler
        # (board.forward -> encode / run [/ image x B] / decode) — decode is
        # fused into the jitted core here, so its span is a zero-wall marker;
        # the canonical form (names, scopes, logical-clock attrs) is
        # bit-identical because both paths project the same trace account
        rec = ttrace.get()
        images = np.atleast_2d(np.asarray(images, np.float32))
        fwd = rec.begin("board.forward", "system",
                        attrs={"batch": int(images.shape[0]), "T": self.T},
                        meta={"impl": "board-batched"}) if rec.enabled else None
        enc = rec.begin("board.encode", "system", trace=fwd.trace,
                        parent=fwd.sid,
                        attrs={"n_in": int(images.shape[1])}) \
            if fwd is not None else None
        times = np.asarray(ttfs.encode_ttfs(jnp.asarray(images), self.T,
                                            self.x_min))
        rec.end(enc)
        run = rec.begin("board.run", "accel", trace=fwd.trace,
                        parent=fwd.sid) if fwd is not None else None
        labels, first_l, v_l, steps = self._core(jnp.asarray(times))
        steps_np = np.asarray(steps, np.int64)
        counts = step_counts(times, self.T)[:, :self.T].astype(np.int64)
        self.last_tick_counts = counts
        cum = np.zeros((counts.shape[0], self.T + 1), np.int64)
        np.cumsum(counts, axis=1, out=cum[:, 1:])
        excess = np.maximum(counts - self.depth, 0)
        cum_x = np.zeros_like(cum)
        np.cumsum(excess, axis=1, out=cum_x[:, 1:])
        idx = np.arange(counts.shape[0])
        self.last_trace = account(cum[idx, steps_np], steps_np,
                                  cum_x[idx, steps_np], self.n_pad, self.cost)
        if run is not None:
            totals, per = span_attrs(self.last_trace)
            rec.end(run, attrs=totals)
            for a in per:
                rec.emit("board.image", "accel", trace=run.trace,
                         parent=run.sid, attrs=a)
            rec.emit("board.decode", "accel", trace=fwd.trace,
                     parent=fwd.sid, attrs={"n_out": self.n_out})
        rec.end(fwd)
        return SNNOutput(labels=labels, first_spike=first_l, v_final=v_l,
                         steps=steps)

    __call__ = forward
