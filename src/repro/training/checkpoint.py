"""Fault-tolerant checkpoint manager.

Properties (tested in tests/training/test_checkpoint.py):
  * atomic AND durable: writes to ``step_XXXX.tmp``, fsyncs every file and
    the tmp directory entry, then ``os.replace``, then fsyncs the parent
    directory — a crash mid-save never corrupts the latest checkpoint, and
    a power loss right after ``save()`` returns cannot roll it back (the
    rename is only durable once the parent directory entry is on disk);
  * integrity-verified: per-array SHA-256 manifest, verified on restore
    (the same discipline the deployment artifact uses);
  * resumable: restore() is bit-exact — tests assert identical training
    trajectories after a kill/restore;
  * elastic: arrays are stored unsharded (host numpy); ``restore`` takes an
    optional ``sharding_fn(path, array) -> Sharding`` so the same checkpoint
    re-shards onto a different mesh (scale up/down between runs);
  * bounded: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Callable

import jax
import numpy as np


def _fsync_path(path: str) -> None:
    """fsync a file or directory by fd. Directory fsync pins the ENTRY
    (the name -> inode mapping) — required after create/rename for the
    operation itself to be durable, not just the bytes."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(pytree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(pytree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten_into(target, arrays: dict[str, np.ndarray],
                    sharding_fn: Callable | None = None):
    flat, tdef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {a.shape} != target {leaf.shape}")
        a = a.astype(leaf.dtype)
        if sharding_fn is not None:
            a = jax.device_put(a, sharding_fn(key, a))
        leaves.append(a)
    return jax.tree_util.tree_unflatten(tdef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, pytree: Any, meta: dict | None = None) -> str:
        arrays = _flatten(pytree)
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for key, a in arrays.items():
            fn = hashlib.sha256(key.encode()).hexdigest()[:24] + ".npy"
            path = os.path.join(tmp, fn)
            with open(path, "wb") as f:
                np.save(f, a)
                f.flush()
                os.fsync(f.fileno())    # array bytes durable before publish
            manifest[key] = {
                "file": fn, "dtype": str(a.dtype), "shape": list(a.shape),
                "sha256": hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest(),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "meta": meta or {}, "arrays": manifest},
                      f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())        # manifest durable before publish
        _fsync_path(tmp)                # the tmp dir's entries themselves
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        _fsync_path(self.dir)           # …and durable: pin the rename
        self._prune()
        return final

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: int | None = None,
                sharding_fn: Callable | None = None,
                verify: bool = True) -> tuple[int, Any]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {}
        for key, info in manifest["arrays"].items():
            a = np.load(os.path.join(d, info["file"]))
            if verify:
                dig = hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()
                if dig != info["sha256"]:
                    raise IOError(f"checkpoint array {key!r} is corrupt")
            arrays[key] = a
        return manifest["step"], _unflatten_into(target, arrays, sharding_fn)

    def meta(self, step: int) -> dict:
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["meta"]
