"""Pure-jnp oracle for the fused LIF kernel (defers to the single source of
truth in core.lif_dynamics)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.lif_dynamics import lif_scan


def lif_fused_ref(currents: jnp.ndarray, thresholds: jnp.ndarray,
                  leak_shift: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """currents (B, T, N) int32 -> (first_spike (B, N), v_final (B, N))."""
    T = currents.shape[1]
    res = lif_scan(jnp.moveaxis(currents, 1, 0), thresholds[None, :],
                   leak_shift, T)
    return res.first_spike, res.v_final
