"""Telemetry subsystem: deterministic span trees (scope tags, logical
clocks, bit-identical canonical form across seeded runs), the metrics
registry (consistent snapshots, fixed-bucket histograms, typed events), and
the JSONL / Prometheus exporters."""

import json
import threading

import numpy as np
import pytest

from repro.telemetry import export as texport
from repro.telemetry import trace as ttrace
from repro.telemetry.metrics import (DEPTH_BUCKETS, Histogram,
                                     MetricsRegistry)
from repro.telemetry.trace import SCOPES, NullRecorder, Tracer


# ------------------------------------------------------------------- tracing
def test_scope_tag_is_mandatory_and_closed():
    t = Tracer()
    with pytest.raises(ValueError, match="accel"):
        t.begin("x", "device")
    with pytest.raises(ValueError, match="scope"):
        t.emit("x", "host")
    assert SCOPES == ("accel", "system")


def test_context_manager_nesting_builds_the_tree():
    t = Tracer()
    with t.span("a", "system") as a:
        with t.span("b", "accel"):
            t.emit("c", "accel")
        t.emit("d", "system")
    spans = {s.name: s for s in t.sorted_spans()}
    assert spans["a"].parent is None
    assert spans["b"].parent == spans["a"].sid
    assert spans["c"].parent == spans["b"].sid          # nests under inner
    assert spans["d"].parent == spans["a"].sid          # inner already popped
    assert len({s.trace for s in spans.values()}) == 1  # one auto trace
    assert [spans[n].sid for n in "abcd"] == [0, 1, 2, 3]


def test_begin_end_crosses_threads_and_merges_attrs():
    t = Tracer()
    sp = t.begin("request", "system", trace="req-00000001",
                 attrs={"rid": 1})

    def closer():
        t.end(sp, attrs={"label": 7})

    th = threading.Thread(target=closer)
    th.start()
    th.join()
    assert sp.attrs == {"rid": 1, "label": 7}
    assert sp.wall_ns_end >= sp.wall_ns_start
    # begin() does not touch the nesting stack
    assert t.current() is None


def test_sids_are_sequential_per_trace():
    t = Tracer()
    t.begin("a", "system", trace="x")
    t.begin("b", "system", trace="y")
    t.begin("c", "system", trace="x")
    sids = {(s.trace, s.name): s.sid for s in t.sorted_spans()}
    assert sids[("x", "a")] == 0 and sids[("x", "c")] == 1
    assert sids[("y", "b")] == 0


def test_emit_is_zero_wall_duration():
    t = Tracer()
    s = t.emit("board.image", "accel", attrs={"cycles": 42})
    assert s.wall_ns_start == s.wall_ns_end
    assert s.attrs == {"cycles": 42}


def test_canonical_excludes_wall_and_meta():
    t = Tracer()
    with t.span("a", "system", attrs={"k": 1}, meta={"lane": 3}):
        pass
    (c,) = t.canonical()
    assert c == {"trace": c["trace"], "sid": 0, "parent": None, "name": "a",
                 "scope": "system", "attrs": {"k": 1}}
    (f,) = [s.full() for s in t.sorted_spans()]
    assert f["meta"] == {"lane": 3}
    assert "wall_ns_start" in f and "wall_ns_end" in f


def test_fingerprint_bit_identical_across_runs():
    def run():
        t = Tracer()
        with t.span("forward", "system", trace="t0", attrs={"batch": 4}):
            for i in range(4):
                t.emit("image", "accel", attrs={"i": i, "cycles": 10 * i})
        return t

    t1, t2 = run(), run()
    assert t1.fingerprint() == t2.fingerprint()
    assert t1.canonical() == t2.canonical()
    t3 = run()
    t3.emit("extra", "system", trace="t0")
    assert t3.fingerprint() != t1.fingerprint()


def test_max_spans_bound_drops_and_counts():
    t = Tracer(max_spans=3)
    got = [t.emit("e", "system", trace="t0") for _ in range(5)]
    assert len(t.spans) == 3 and t.dropped == 2
    assert got[3] is None and got[4] is None
    t.end(got[4])                                       # end(None) is safe


def test_roots_children_find():
    t = Tracer()
    r = t.begin("batch", "system", trace="b0")
    t.emit("lane", "system", trace="b0", parent=r.sid)
    t.emit("lane", "system", trace="b1")
    assert [s.trace for s in t.roots("batch")] == ["b0"]
    assert [s.name for s in t.children(r)] == ["lane"]
    assert len(t.find("lane")) == 2
    assert len(t.find("lane", trace="b0")) == 1


def test_module_recorder_disabled_by_default():
    rec = ttrace.get()
    assert isinstance(rec, NullRecorder) and not rec.enabled
    assert not ttrace.enabled()
    # zero-allocation singletons on the disabled path
    assert rec.span("a", "system") is rec.span("b", "accel")
    assert rec.begin("a", "system") is None
    assert rec.emit("a", "system") is None
    rec.end(None, attrs={"x": 1})                       # no-op, no raise
    with ttrace.span("a", "system") as s:
        assert s is None


def test_install_swaps_and_restores():
    t = Tracer()
    prev = ttrace.install(t)
    try:
        assert ttrace.get() is t and ttrace.enabled()
        ttrace.emit("e", "system", trace="t0")
        assert len(t.spans) == 1
    finally:
        assert ttrace.install(prev) is t
    assert not ttrace.enabled()


# ------------------------------------------------------------------- metrics
def test_counter_gauge_peak():
    m = MetricsRegistry()
    m.inc("images_out", 4)
    m.inc("images_out")
    m.set_gauge("depth", 3.0)
    m.set_max("peak", 5.0)
    m.set_max("peak", 2.0)                              # lower: ignored
    snap = m.snapshot()
    assert snap["images_out"] == 5
    assert snap["depth"] == 3.0 and snap["peak"] == 5.0


def test_histogram_fixed_buckets_and_exact_percentiles():
    rng = np.random.RandomState(0)
    vals = rng.exponential(100.0, size=500)
    h = Histogram("lat", (50.0, 100.0, 250.0))
    for v in vals:
        h.observe(v)
    assert h.count == 500 and h.sum == pytest.approx(vals.sum())
    assert sum(h.counts) == 500
    assert h.counts[0] == int((vals <= 50.0).sum())
    assert h.counts[-1] == int((vals > 250.0).sum())    # +inf bucket
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q))
    assert h.mean() == pytest.approx(vals.mean())
    assert Histogram("e", (1.0,)).percentile(50) == 0.0  # empty -> 0


def test_histogram_boundaries_are_pinned():
    m = MetricsRegistry()
    m.histogram("lat", DEPTH_BUCKETS)
    m.histogram("lat", DEPTH_BUCKETS)                   # idempotent
    with pytest.raises(ValueError, match="already registered"):
        m.histogram("lat", (1.0, 2.0))
    with pytest.raises(ValueError, match="sorted"):
        Histogram("bad", (2.0, 1.0))


def test_histogram_window_is_bounded_but_totals_exact():
    m = MetricsRegistry()
    m.histogram("lat", DEPTH_BUCKETS, window=8)
    for v in range(100):
        m.observe("lat", float(v), DEPTH_BUCKETS)
    snap = m.snapshot()
    assert snap["lat_count"] == 100                     # totals: exact
    assert snap["lat_sum"] == pytest.approx(sum(range(100)))
    assert snap["lat_p50"] == pytest.approx(95.5)       # window: last 8


def test_typed_events_and_bounded_ring():
    class Tiny(MetricsRegistry):
        EVENT_WINDOW = 4

    m = Tiny()
    for i in range(6):
        m.event("lane_transition", lane=0, frm="healthy", to="suspect",
                reason=f"r{i}")
    m.event("breaker_trip", lane=1)
    snap = m.snapshot()
    assert snap["events_lane_transition"] == 6          # counter survives ring
    assert snap["events_breaker_trip"] == 1
    assert snap["events_total"] == 7 and snap["events_dropped"] == 3
    evs = m.events_for("lane_transition")
    assert len(evs) == 3                                # ring kept newest
    assert evs[-1].fields["reason"] == "r5"
    assert [e.seq for e in evs] == sorted(e.seq for e in evs)


def test_snapshot_is_consistent_under_concurrent_writers():
    """Counters bumped together must never tear apart in a snapshot: a
    writer increments a and b back to back under contention; every snapshot
    must see a >= b (a is bumped first) and both monotone."""
    m = MetricsRegistry()
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            m.inc("a")
            m.inc("b")

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for th in threads:
        th.start()
    last_a = last_b = 0
    try:
        for _ in range(300):
            snap = m.snapshot()
            assert snap["a"] >= snap["b"] >= 0
            assert snap["a"] >= last_a and snap["b"] >= last_b
            last_a, last_b = snap["a"], snap["b"]
    finally:
        stop.set()
        for th in threads:
            th.join()


def test_reset_zeroes_in_place_keeping_objects():
    m = MetricsRegistry()
    c = m.counter("x")
    h = m.histogram("lat", DEPTH_BUCKETS)
    m.inc("x", 3)
    m.observe("lat", 2.0, DEPTH_BUCKETS)
    m.event("detector", kind="ecc")
    m.reset()
    snap = m.snapshot()
    assert snap["x"] == 0 and snap["lat_count"] == 0
    assert snap["events_total"] == 0 and snap["events_detector"] == 0
    assert m.counter("x") is c and m.histogram("lat", DEPTH_BUCKETS) is h
    m.inc("x")
    assert c.value == 1                                 # old handle still live


# ----------------------------------------------------------------- exporters
def test_jsonl_roundtrip_and_canonical_projection(tmp_path):
    t = Tracer()
    with t.span("forward", "system", trace="t0", meta={"impl": "py"}):
        t.emit("image", "accel", attrs={"cycles": 11})
    path = str(tmp_path / "dump" / "run.trace.jsonl")
    assert texport.write_jsonl(t, path) == 2
    back = texport.read_jsonl(path)
    assert [d["name"] for d in back] == ["forward", "image"]
    assert back[0]["meta"] == {"impl": "py"}
    assert texport.canonical_lines(path) == t.canonical()
    with open(path) as f:                               # one object per line
        assert all(json.loads(line) for line in f)


def test_prometheus_exposition_format():
    m = MetricsRegistry()
    m.inc("lane_faults", 3)
    m.set_gauge("queue_depth_peak", 7)
    m.histogram("lat", (1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        m.observe("lat", v, (1.0, 10.0))
    text = texport.prometheus_text(m, prefix="repro")
    lines = text.strip().splitlines()
    assert "# TYPE repro_lane_faults counter" in lines
    assert "repro_lane_faults 3" in lines
    assert "# TYPE repro_queue_depth_peak gauge" in lines
    assert "# TYPE repro_lat histogram" in lines
    assert 'repro_lat_bucket{le="1.0"} 1' in lines      # cumulative
    assert 'repro_lat_bucket{le="10.0"} 2' in lines
    assert 'repro_lat_bucket{le="+Inf"} 3' in lines
    assert "repro_lat_count 3" in lines
    assert text.endswith("\n")


# ------------------------------------------- end-to-end determinism (boards)
def _traced_forward(art, spec, images):
    from repro.core.runtimes import make_runtime
    t = Tracer()
    prev = ttrace.install(t)
    try:
        rt = make_runtime(art, spec)
        rt.forward(images)
    finally:
        ttrace.install(prev)
    return t


def test_board_span_tree_seeded_runs_bit_identical(trained_artifact):
    art, _, (xte, _) = trained_artifact
    t1 = _traced_forward(art, "board", xte[:4])
    t2 = _traced_forward(art, "board", xte[:4])
    assert t1.fingerprint() == t2.fingerprint()
    assert t1.canonical() == t2.canonical()


def test_board_py_and_batched_span_trees_agree(trained_artifact):
    """The batched fast path must project the SAME canonical span tree as
    the per-image scheduler — impl differences live in meta only."""
    art, _, (xte, _) = trained_artifact
    tp = _traced_forward(art, "board-py", xte[:4])
    tb = _traced_forward(art, "board-batched", xte[:4])
    assert tp.canonical() == tb.canonical()
    assert tp.fingerprint() == tb.fingerprint()
    names = [s.name for s in tp.sorted_spans() if s.name == "board.image"]
    assert len(names) == 4                              # one span per image
    impls = {s.meta.get("impl") for s in tp.sorted_spans()
             if s.name == "board.forward"}
    assert impls != {s.meta.get("impl") for s in tb.sorted_spans()
                     if s.name == "board.forward"}


def test_board_image_spans_carry_logical_clocks(trained_artifact):
    art, _, (xte, _) = trained_artifact
    t = _traced_forward(art, "board", xte[:3])
    run = t.find("board.run")[0]
    imgs = t.find("board.image")
    assert run.scope == "accel"
    for s in imgs:
        assert s.scope == "accel"
        assert s.parent == run.sid and s.trace == run.trace
        assert s.attrs["cycles"] > 0 and s.attrs["events"] > 0
    assert sum(s.attrs["cycles"] for s in imgs) == run.attrs["cycles"]


# ------------------------------------------------- scheduler span determinism
def test_scheduler_inline_spans_deterministic_and_causal(trained_artifact):
    art, _, (xte, _) = trained_artifact
    from repro.serving.scheduler import ServingScheduler

    def run():
        t = Tracer()
        prev = ttrace.install(t)
        try:
            s = ServingScheduler(art, spec="accelerator-event",
                                 kernel="fused", max_batch=4)
            rids = [s.submit(x) for x in xte[:6]]
            done = s.drain()
        finally:
            ttrace.install(prev)
        return t, rids, done

    t1, rids, done = run()
    t2, _, _ = run()
    assert t1.fingerprint() == t2.fingerprint()

    # request tree: request -> admission / batch-form / complete
    req = t1.traces()[f"req-{rids[0]:08d}"]
    root = req[0]
    assert root.name == "request" and root.parent is None
    kids = [s.name for s in req if s.parent == root.sid]
    assert kids == ["admission", "batch-form", "complete"]
    comp = next(s for s in req if s.name == "complete")
    assert comp.attrs["label"] == int(done[rids[0]].label)

    # batch tree: batch -> lane -> runtime -> accel.forward -> accel.kernel
    batches = t1.roots("batch")
    assert len(batches) == 2                            # 4 + 2
    lane = t1.children(batches[0])[0]
    assert lane.name == "lane"
    (runtime,) = t1.children(lane)
    assert runtime.name == "runtime"
    (fwd,) = t1.children(runtime)
    assert fwd.name == "accel.forward" and fwd.scope == "system"
    assert any(s.name == "accel.kernel" and s.scope == "accel"
               for s in t1.children(fwd))
