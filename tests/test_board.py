"""Board-runtime emulator: three-way agreement, scheduler<->batched
bit-exactness (outputs AND cycle/energy traces), cost-model sanity, and the
serving-engine board backend."""

import numpy as np
import pytest

from repro.board import SNNBoard, SNNBoardBatched
from repro.board.energy import account
from repro.board.event_queue import AEREventQueue
from repro.board.neuron_core import GroupedNeuronCore
from repro.core.agreement import full_agreement
from repro.core.hw import PYNQ_COST, BoardCostModel
from repro.core.reference import SNNReference


def test_three_way_agreement_1k_images(trained_artifact):
    """The acceptance bar: reference / accelerator / board over >= 1,000
    images, labels AND first-spike times bit-exact."""
    art, _, (xte, yte) = trained_artifact
    rep = full_agreement(art, xte[:1024], yte[:1024],
                         runtimes=("accelerator-batch", "accelerator-event",
                                   "board"),
                         chunk=512)
    assert rep.n_images >= 1000
    assert rep.exact_match, rep.summary()
    assert rep.label_mismatches["board"] == 0
    assert rep.spike_time_mismatches["board"] == 0


def test_scheduler_matches_batched_full_mode(trained_artifact):
    """Per-image Python scheduler == vectorized fast path: labels, spike
    times, membranes, steps, and the full cycle/energy trace."""
    art, _, (xte, _) = trained_artifact
    py, bb = SNNBoard(art), SNNBoardBatched(art)
    o_py, o_bb = py.forward(xte[:24]), bb.forward(xte[:24])
    assert np.array_equal(np.asarray(o_py.labels), np.asarray(o_bb.labels))
    assert np.array_equal(np.asarray(o_py.first_spike),
                          np.asarray(o_bb.first_spike))
    assert np.array_equal(np.asarray(o_py.v_final), np.asarray(o_bb.v_final))
    assert np.array_equal(np.asarray(o_py.steps), np.asarray(o_bb.steps))
    for field in ("ticks", "events", "stalls", "synops", "cycles",
                  "energy_nj"):
        assert np.array_equal(getattr(py.last_trace, field),
                              getattr(bb.last_trace, field)), field


def test_scheduler_matches_batched_latency_mode(trained_artifact):
    """Latency mode (stop at the TTFS decision): same equality, including
    the exit-tick membrane the batched path gathers from the scan history."""
    art, _, (xte, _) = trained_artifact
    py = SNNBoard(art, latency_mode=True)
    bb = SNNBoardBatched(art, latency_mode=True)
    o_py, o_bb = py.forward(xte[:24]), bb.forward(xte[:24])
    assert np.array_equal(np.asarray(o_py.labels), np.asarray(o_bb.labels))
    assert np.array_equal(np.asarray(o_py.first_spike),
                          np.asarray(o_bb.first_spike))
    assert np.array_equal(np.asarray(o_py.v_final), np.asarray(o_bb.v_final))
    assert np.array_equal(np.asarray(o_py.steps), np.asarray(o_bb.steps))
    for field in ("ticks", "events", "stalls", "cycles", "energy_nj"):
        assert np.array_equal(getattr(py.last_trace, field),
                              getattr(bb.last_trace, field)), field
    # early exit never exceeds the window and labels match the full run
    full = SNNBoardBatched(art).forward(xte[:24])
    assert np.all(np.asarray(o_bb.steps) <= art.m("encode", "T"))
    assert np.array_equal(np.asarray(o_bb.labels), np.asarray(full.labels))


def test_board_pallas_kernel_agrees(trained_artifact):
    art, _, (xte, _) = trained_artifact
    ref = SNNReference(art).forward(xte[:24])
    out = SNNBoardBatched(art, kernel="pallas").forward(xte[:24])
    assert np.array_equal(np.asarray(out.labels), np.asarray(ref.labels))
    assert np.array_equal(np.asarray(out.first_spike),
                          np.asarray(ref.first_spike))


def test_aer_queue_schedule_and_backpressure():
    T = 4
    times = np.array([0, 2, 0, 3, 4, 1, 0], np.int32)   # time 4 == never (T)
    q = AEREventQueue(times, T, depth=2)
    assert q.total_events == 6
    assert np.array_equal(q.events_at(0), [0, 2, 6])    # ascending ids
    assert np.array_equal(q.events_at(1), [5])
    assert np.array_equal(q.events_at(3), [3])
    assert np.array_equal(q.counts(), [3, 1, 1, 1])
    # 3 events into a depth-2 FIFO: 1 stall; no events are ever dropped
    assert q.stalls_at(0) == 1 and q.stalls_at(1) == 0


def test_cost_model_account_terms():
    cost = BoardCostModel()
    tr = account(events=10, ticks=5, stalls=2, n_pad=256, cost=cost)
    assert int(tr.cycles) == (cost.cycles_fixed + 10 * cost.cycles_per_event
                              + 5 * cost.cycles_per_tick
                              + 2 * cost.cycles_per_stall + cost.cycles_decode)
    assert int(tr.synops) == 10 * 256
    expect_nj = (10 * cost.pj_per_event + 10 * 256 * cost.pj_per_synop
                 + 5 * 256 * cost.pj_per_neuron_tick + cost.pj_per_decode) / 1e3
    assert float(tr.energy_nj) == pytest.approx(expect_nj)
    # zero-work floor is the paper-calibrated service overhead
    floor = account(events=0, ticks=0, stalls=0, n_pad=256, cost=cost)
    assert int(floor.cycles) == cost.cycles_fixed + cost.cycles_decode == 11


def test_neuron_core_rejects_oversized_network():
    cost = PYNQ_COST
    n_pad = cost.neurons_direct + cost.lane          # one group too many
    w = np.zeros((8, n_pad), np.int8)
    thr = np.ones((n_pad,), np.int32)
    with pytest.raises(ValueError, match="directly addressable"):
        GroupedNeuronCore(w, thr, leak_shift=4, T=8, cost=cost)


def test_serving_engine_board_backend(trained_artifact):
    from repro.serving.snn_engine import SNNServeEngine
    art, _, (xte, _) = trained_artifact
    eng = SNNServeEngine(art, max_batch=32, backend="board")
    ref_labels = np.asarray(SNNReference(art).forward(xte[:48]).labels)
    got = eng.classify(xte[:48])
    assert np.array_equal(got, ref_labels)
    st = eng.stats()
    assert st["backend"] == "board"
    assert st["images_out"] == 48
    assert st["board_cycles"] > 0
    assert st["board_nj_per_image"] > 0
    assert st["board_model_us_per_image"] == pytest.approx(
        1e6 * st["board_cycles_per_image"] / PYNQ_COST.clock_hz)
    assert st["overflow_fallbacks"] == 0    # board backpressures, never drops
