#!/usr/bin/env bash
# Tier-1 gate: full test suite + the event-pipeline perf check.
#
#   scripts/check.sh                 # everything
#   scripts/check.sh --fast          # skip the slow subprocess/mesh tests
#   scripts/check.sh --benches-only  # just the bench gates (CI runs pytest
#                                    # as its own step already)
#
# Fails if any test fails, OR if the fused event path is slower than the
# staged event path on accelerator-scope latency (perf regression gate), OR
# if the board-runtime emulator disagrees with the software reference /
# its batched fast path drifts from the per-image scheduler, OR if the
# continuous-batching serving tier serves a single label that is not
# bit-exact with the software reference under open/closed-loop load, OR if
# any advertised runtime spec disagrees with the reference on ANY fuzzed
# artifact / the pinned golden traces drift (conformance gate), OR if any
# injected-fault chaos case violates the detected-or-correct serving
# invariant (fault-tolerance gate), OR if the telemetry subsystem costs
# more than its budget (disabled < 2%, enabled < 10% — overhead gate), OR
# if the program cache stops paying (cached runtime builds must be >= 3x
# faster than cold and the watchdog's replacement lane must be a cache
# hit — runtime-build gate), OR if the TCP program-distribution transport
# violates detected-or-bit-exact on any fault-proxy scenario / a
# two-process leader/follower pair drifts from the software reference
# (transport gate).
#
# The serving and chaos gates run with --trace-out so any failing scenario
# leaves its telemetry span tree (JSONL) next to the JSON failure report.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# lint first when available (CI installs ruff; the dev container may not
# have it — the gate is advisory there, never silently different). Rule
# set lives in pyproject: error classes + F401/F811/F841 + E7.
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks
else
    echo "check.sh: ruff not installed, skipping lint (CI runs it)" >&2
fi

if [[ "${1:-}" != "--benches-only" ]]; then
    PYTEST_ARGS=(-q)
    if [[ "${1:-}" == "--fast" ]]; then
        PYTEST_ARGS+=(-m "not slow")
    fi
    python -m pytest "${PYTEST_ARGS[@]}"
fi

python -m benchmarks.bench_event_pipeline --quick --check
python -m benchmarks.bench_board_emu --quick --check
python -m benchmarks.bench_serving_load --quick --check \
    --trace-out results/serving_failures
python -m benchmarks.bench_conformance --quick --check
python -m benchmarks.bench_fault_tolerance --quick --check \
    --trace-out results/fault_failures
python -m benchmarks.bench_telemetry_overhead --quick --check
python -m benchmarks.bench_runtime_build --quick --check
python -m benchmarks.bench_transport --quick --check \
    --failures-out results/transport_failures
