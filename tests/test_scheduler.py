"""Continuous-batching scheduler: batch formation (size and deadline close),
worker lanes, latency percentiles, result()/drain() APIs, and the
single-code-path overflow reroute / board accounting."""

import copy
import threading
import time

import numpy as np
import pytest

from repro.core.artifact import Artifact
from repro.core.reference import SNNReference
from repro.serving.scheduler import ServingError, ServingScheduler


def _tiny_emax_artifact(art: Artifact, e_max: int = 8) -> Artifact:
    clone = Artifact(copy.deepcopy(art.meta), dict(art.arrays))
    clone.meta["events"]["e_max"] = e_max
    return clone


def test_inline_mode_greedy_deterministic_batches(trained_artifact):
    art, _, (xte, _) = trained_artifact
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         max_batch=4)
    rids = [s.submit(x) for x in xte[:10]]
    done = s.drain()
    assert sorted(done) == rids
    st = s.stats()
    assert st["batches"] == 3 and st["images_out"] == 10   # 4 + 4 + 2
    assert st["batch_fill_mean"] == pytest.approx(10 / 3)
    assert st["system_s"] >= st["accelerator_s"] > 0
    assert s.drain() == {}                                 # queue drained


def test_threaded_lanes_bitexact_with_reference(trained_artifact):
    """Labels served through 2 continuous-batching lanes (whatever batches
    form) are bit-exact with the reference — padding and batch composition
    must not change an answer."""
    art, _, (xte, _) = trained_artifact
    want = np.asarray(SNNReference(art).forward(xte[:48]).labels)
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=2, max_batch=8, max_wait_us=500.0) as s:
        rids = [s.submit(x) for x in xte[:48]]
        done = s.drain()
        got = np.asarray([done[r].label for r in rids])
        assert np.array_equal(got, want)
        assert {done[r].lane for r in rids} <= {0, 1}
        st = s.stats()
        assert (0 < st["p50_latency_us"] <= st["p95_latency_us"]
                <= st["p99_latency_us"])
        assert st["queue_depth_peak"] >= 0
        assert st["images_out"] == 48


def test_deadline_closes_partial_batch(trained_artifact):
    """Under light load a batch must close at max_wait_us, not wait for
    max_batch requests that will never come."""
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=64, max_wait_us=1000.0) as s:
        req = s.result(s.submit(xte[0]), timeout=120.0)
        assert req.label is not None and req.lane == 0
        st = s.stats()
        assert st["batches"] == 1
        assert st["batch_fill_mean"] <= 2                  # closed near-empty


def test_closed_loop_result_api(trained_artifact):
    """Concurrent closed-loop clients each block on their own request."""
    art, _, (xte, _) = trained_artifact
    want = np.asarray(SNNReference(art).forward(xte[:24]).labels)
    errs = []
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=2, max_batch=8, max_wait_us=500.0) as s:
        def client(c):
            for i in range(c, 24, 3):
                r = s.result(s.submit(xte[i]), timeout=120.0)
                if r.label != want[i]:
                    errs.append((i, r.label))
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errs == []
        assert s.stats()["images_out"] == 24
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(xte[0])


def test_overflow_reroute_lives_in_scheduler(trained_artifact):
    """The overflow→dense reroute is scheduler-side: rows beyond E_max are
    served through the dense path in ANY mode, labels still exact."""
    art, _, (xte, _) = trained_artifact
    tiny = _tiny_emax_artifact(art, e_max=8)
    want = np.asarray(SNNReference(art).forward(xte[:24]).labels)
    with ServingScheduler(tiny, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=8, max_wait_us=500.0) as s:
        rids = [s.submit(x) for x in xte[:24]]
        done = s.drain()
        got = np.asarray([done[r].label for r in rids])
        assert np.array_equal(got, want)
        st = s.stats()
        assert st["overflow_fallbacks"] > 0
        assert any(done[r].fallback_dense for r in rids)


def test_board_accounting_and_denominators(trained_artifact):
    art, _, (xte, _) = trained_artifact
    s = ServingScheduler(art, spec="board-batched", max_batch=16)
    # empty stats: every per-image rate uses the SAME zero-traffic guard
    st0 = s.stats()
    assert st0["accel_us_per_image"] == 0.0
    assert st0["board_model_us_per_image"] == 0.0
    assert st0["board_nj_per_image"] == 0.0
    rids = [s.submit(x) for x in xte[:20]]
    done = s.drain()
    want = np.asarray(SNNReference(art).forward(xte[:20]).labels)
    assert np.array_equal(np.asarray([done[r].label for r in rids]), want)
    st = s.stats()
    assert st["board_cycles"] > 0 and st["board_nj_per_image"] > 0
    clock = s.lanes[0].runtime.cost.clock_hz
    assert st["board_model_us_per_image"] == pytest.approx(
        1e6 * st["board_cycles_per_image"] / clock)
    assert st["overflow_fallbacks"] == 0   # board backpressures, never drops


def test_malformed_image_rejected_at_admission(trained_artifact):
    """A bad shape must never reach a lane where it would poison a whole
    batch — submit() rejects it synchronously."""
    art, _, _ = trained_artifact
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         max_batch=4)
    with pytest.raises(ValueError, match="shape"):
        s.submit(np.zeros(3, np.float32))      # wrong width: (3,) vs (N_in,)
    assert s.drain() == {}                     # nothing was admitted


def test_failed_batch_never_strands_waiters(trained_artifact):
    """A worker-lane exception mid-batch must not vanish: the request
    completes with .error set, result() raises a descriptive ServingError,
    drain()/result() never hang, and later traffic is still served (the
    lane is scrubbed and rebuilt). Inline mode re-raises to the synchronous
    caller after error-completing the batch."""
    art, _, (xte, _) = trained_artifact

    def boom(images, k, probe=False):
        raise RuntimeError("injected mid-batch explosion")

    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=4, max_wait_us=500.0,
                          resilience={"max_retries": 0, "backoff_s": 0.001},
                          ) as s:
        s.lanes[0].serve = boom                # this lane throws mid-batch
        rid = s.submit(xte[0])
        with pytest.raises(ServingError, match="explosion") as ei:
            s.result(rid, timeout=120.0)       # raises instead of hanging
        req = ei.value.request
        assert req.rid == rid and req.label is None
        assert "injected mid-batch explosion" in req.error
        st = s.stats()
        assert st["errors"] == 1 and st["lane_faults"] >= 1
        ok = s.result(s.submit(xte[0]), timeout=120.0)   # rebuilt lane serves
        assert ok.error is None and ok.label is not None
        assert s.stats()["lane_restarts"] >= 1

    s2 = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          max_batch=4)
    s2.lanes[0].serve = boom
    rid2 = s2.submit(xte[0])
    with pytest.raises(RuntimeError, match="explosion"):
        s2.drain()                             # inline mode surfaces it
    done = s2.drain()                          # ...but nothing is stranded
    assert done[rid2].error is not None and s2.stats()["errors"] == 1


def test_drain_does_not_steal_claimed_result(trained_artifact):
    """A rid a result() caller is blocked on must not be swept up by a
    concurrent drain() — the claim protects it."""
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=4, max_wait_us=500.0) as s:
        got = {}
        rid = s.submit(xte[0])
        t = threading.Thread(
            target=lambda: got.update(r=s.result(rid, timeout=120.0)))
        t.start()
        deadline = time.time() + 30
        while rid not in s._claims:            # wait for the claim to land
            assert time.time() < deadline
            time.sleep(0.001)
        drained = s.drain()
        t.join(timeout=120.0)
        assert not t.is_alive()
        assert got["r"].rid == rid and got["r"].label is not None
        assert rid not in drained


def test_close_fails_backlog_instead_of_draining_it(trained_artifact):
    """close() finishes the batch in flight but does NOT serve the backlog:
    unserved requests complete with error='scheduler closed'."""
    art, _, (xte, _) = trained_artifact
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         workers=1, max_batch=4, max_wait_us=10_000_000.0)
    rids = [s.submit(x) for x in xte[:64]]     # far more than one batch
    s.close()
    done = s.drain()
    assert sorted(done) == rids
    failed = [r for r in done.values() if r.error == "scheduler closed"]
    served = [r for r in done.values() if r.error is None]
    assert len(failed) + len(served) == 64 and failed


def test_result_unknown_or_already_claimed_rid_raises(trained_artifact):
    """result() on a rid that is neither outstanding nor completed fails
    loudly (KeyError) instead of blocking forever — the already-drained /
    already-returned / never-submitted cases."""
    art, _, (xte, _) = trained_artifact
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         max_batch=4)
    with pytest.raises(KeyError):
        s.result(999)                          # never submitted
    rid = s.result(s.submit(xte[0]), timeout=120.0).rid
    with pytest.raises(KeyError):
        s.result(rid)                          # already returned
    rid2 = s.submit(xte[1])
    s.drain()
    with pytest.raises(KeyError):
        s.result(rid2)                         # swept by a drain()


def test_stats_snapshot_consistent_under_concurrent_chaos(trained_artifact):
    """stats() is one consistent registry snapshot, not a field-by-field
    read of live counters: submitter threads and a crashing lane mutate the
    account while readers hammer stats(). Every successive snapshot must be
    monotone in the counter totals, never show more completions than
    admissions, and the final account must be exact."""
    art, _, (xte, _) = trained_artifact
    n, n_threads = 48, 3
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         workers=2, max_batch=8, max_wait_us=500.0,
                         faults="crash=0,seed=12",
                         resilience={"backoff_s": 0.001})
    submitted = []
    sub_lock = threading.Lock()
    stop = threading.Event()
    violations: list[str] = []

    def submitter(k):
        for i in range(k, n, n_threads):
            rid = s.submit(xte[i % len(xte)])
            with sub_lock:
                submitted.append(rid)

    def reader():
        monotone = ("images_out", "batches", "requeued", "lane_faults",
                    "lane_restarts", "errors")
        last = {k: 0 for k in monotone}
        while not stop.is_set():
            st = s.stats()
            with sub_lock:
                n_sub = len(submitted)
            if st["images_out"] > n_sub:
                violations.append(f"torn read: images_out "
                                  f"{st['images_out']} > submitted {n_sub}")
            for k in monotone:
                if st[k] < last[k]:
                    violations.append(f"counter {k} went backwards: "
                                      f"{st[k]} < {last[k]}")
                last[k] = st[k]
            if st["batches"] and st["images_out"] < st["batches"]:
                violations.append("more batches than completed images")

    with s:
        readers = [threading.Thread(target=reader) for _ in range(2)]
        subs = [threading.Thread(target=submitter, args=(k,))
                for k in range(n_threads)]
        for t in readers + subs:
            t.start()
        for t in subs:
            t.join(timeout=120.0)
        done = s.drain()
        stop.set()
        for t in readers:
            t.join(timeout=30.0)
        st = s.stats()
    assert not violations, violations[:5]
    assert sorted(done) == sorted(submitted)
    assert st["images_out"] == n and st["lane_faults"] >= 1
    assert all(r.error is None for r in done.values())
