"""Board-runtime emulator benchmark — the Table-3 analogue from the run itself.

Runs the board emulator (``repro.board``) over the test split in both modes:

  * full-T    — the agreement configuration: all T ticks, first-spike times
    bit-exact with the software reference on every neuron;
  * latency   — the paper's service configuration: stop at the TTFS decision
    (first output spike); this is what the 0.1375 us/image measures.

and reports what the paper's Table 3 reports — cycles/image, us/image at the
80 MHz PL clock, and nJ/image of dynamic energy — from the emulator's own
cycle/energy account (model constants: ``hw.PYNQ_COST``). Also cross-checks
the vectorized batched fast path against the per-image Python scheduler on a
slice: outputs AND traces must be identical.

``--check`` (wired into scripts/check.sh) exits non-zero unless
  1. board labels AND first-spike times match the software reference
     bit-exactly on the slice, and
  2. the batched fast path agrees with the per-image scheduler on labels,
     first-spike times, steps, cycles, and energy.

Emits ``results/bench/board_emu.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import common as CM
from repro.board import SNNBoard, SNNBoardBatched
from repro.core.hw import PYNQ_COST, PYNQ_Z2
from repro.core.reference import SNNReference


def _mode_row(name: str, trace, n: int, steps) -> dict:
    clock = PYNQ_COST.clock_hz
    return {
        "runtime": name,
        "scope": "board (cycle/energy model, PL datapath analogue)",
        "clock_mhz": clock / 1e6,
        "n_images": n,
        "cycles_per_image": float(np.mean(trace.cycles)),
        "us_per_image": float(np.mean(trace.us(clock))),
        "nj_per_image": float(np.mean(trace.energy_nj)),
        "events_per_image": float(np.mean(trace.events)),
        "ticks_per_image": float(np.mean(trace.ticks)),
        "stalls_per_image": float(np.mean(trace.stalls)),
        "steps_mean": float(np.mean(steps)),
    }


def main(quick: bool = False, check: bool = False) -> int:
    art, xte, yte = CM.get_artifact_and_data(quick=quick)
    n = 512 if quick else 2000
    n_py = 16 if quick else 48
    imgs = xte[:n]

    ref = SNNReference(art)
    out_ref = ref.forward(imgs)

    rows, ok = [], True

    # ---- full-T: the agreement configuration -----------------------------
    full = SNNBoardBatched(art)
    out_full = full.forward(imgs)
    labels_ok = np.array_equal(np.asarray(out_full.labels),
                               np.asarray(out_ref.labels))
    first_ok = np.array_equal(np.asarray(out_full.first_spike),
                              np.asarray(out_ref.first_spike))
    ok &= labels_ok and first_ok
    acc = float(np.mean(np.asarray(out_full.labels) == yte[:n]))
    r = _mode_row("board-emu-full", full.last_trace, n, out_full.steps)
    r.update({"accuracy_pct": 100 * acc,
              "ref_label_match": labels_ok, "ref_first_spike_match": first_ok})
    rows.append(r)

    # ---- latency: the TTFS service configuration -------------------------
    lat = SNNBoardBatched(art, latency_mode=True)
    out_lat = lat.forward(imgs)
    lat_labels_ok = np.array_equal(np.asarray(out_lat.labels),
                                   np.asarray(out_ref.labels))
    ok &= lat_labels_ok
    r = _mode_row("board-emu-latency", lat.last_trace, n, out_lat.steps)
    r.update({"ref_label_match": lat_labels_ok})
    rows.append(r)

    # ---- per-image scheduler cross-check (both modes) --------------------
    for mode_name, batched, latency in (("full", full, False),
                                        ("latency", lat, True)):
        py = SNNBoard(art, latency_mode=latency)
        out_py = py.forward(imgs[:n_py])
        out_b = batched.forward(imgs[:n_py])
        tb, tp = batched.last_trace, py.last_trace
        agree = (np.array_equal(np.asarray(out_py.labels), np.asarray(out_b.labels))
                 and np.array_equal(np.asarray(out_py.first_spike),
                                    np.asarray(out_b.first_spike))
                 and np.array_equal(np.asarray(out_py.steps), np.asarray(out_b.steps))
                 and np.array_equal(tp.cycles, tb.cycles)
                 and np.array_equal(tp.energy_nj, tb.energy_nj))
        ok &= agree
        rows.append({
            "runtime": f"board-emu-py-{mode_name}",
            "scope": "board (per-image scheduler cross-check)",
            "n_images": n_py,
            "cycles_per_image": float(np.mean(tp.cycles)),
            "nj_per_image": float(np.mean(tp.energy_nj)),
            "batched_scheduler_exact": agree,
        })

    # ---- the paper's measured design point, for side-by-side -------------
    rows.append({
        "runtime": "fpga-paper-reference",
        "scope": "paper Table 3 row (PYNQ-Z2 PL, reported; real MNIST)",
        "clock_mhz": PYNQ_Z2.clock_hz / 1e6,
        "cycles_per_image": float(PYNQ_Z2.service_cycles),
        "us_per_image": PYNQ_Z2.service_latency_us,
        "nj_per_image": PYNQ_Z2.dynamic_energy_nj,
        "accuracy_pct": PYNQ_Z2.accuracy_pct,
    })
    CM.emit("board_emu", rows)

    for r in rows:
        cyc = r.get("cycles_per_image")
        us = r.get("us_per_image")
        nj = r.get("nj_per_image")
        print(f"{r['runtime']:<24} "
              f"cycles/img {cyc:10.1f}  "
              + (f"us/img {us:8.4f}  " if us is not None else " " * 17)
              + (f"nJ/img {nj:8.1f}" if nj is not None else ""))
    print(f"agreement+cross-check: {'OK' if ok else 'FAILED'}")

    if check and not ok:
        print("CHECK FAILED: board emulator disagrees with the reference "
              "or the batched fast path drifted from the scheduler",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small test split + fewer scheduler images")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless board==reference and batched==scheduler")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check))
