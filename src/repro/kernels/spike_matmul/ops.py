"""Jitted public wrapper: pads to MXU tiles, flattens (B, T) -> M rows."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_dim, use_interpret
from repro.kernels.spike_matmul.kernel import spike_matmul_kernel


@jax.jit
def spike_matmul(raster_btn: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """raster (B, T, N_in) int8, w (N_in, N_pad) int8 -> (B, T, N_pad) int32."""
    B, T, K = raster_btn.shape
    N = w.shape[1]
    x = raster_btn.reshape(B * T, K)
    x = pad_dim(x, 0, 128)
    x = pad_dim(x, 1, 128)
    wp = pad_dim(pad_dim(w, 0, 128), 1, 128)
    out = spike_matmul_kernel(x, wp, interpret=use_interpret())
    return out[:B * T, :N].reshape(B, T, N)
