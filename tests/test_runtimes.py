"""Runtime registry: spec parsing, construction, and error reporting."""

import numpy as np
import pytest

from repro.board import SNNBoard, SNNBoardBatched
from repro.core.accelerator import SNNAccelerator
from repro.core.reference import SNNReference
from repro.core.runtimes import ADVERTISED_SPECS, available, make_runtime


def test_available_families():
    assert available() == ["accelerator", "board", "reference"]


def test_spec_construction(trained_artifact):
    art, _, _ = trained_artifact
    assert isinstance(make_runtime(art, "reference"), SNNReference)
    acc = make_runtime(art, "accelerator-event-fused")
    assert isinstance(acc, SNNAccelerator)
    assert acc.mode == "event" and acc.kernel == "fused"
    acc = make_runtime(art, "accelerator-batch")
    assert acc.mode == "batch" and acc.kernel == "jnp"
    # harness-level kernel default applies when the spec doesn't pin one
    acc = make_runtime(art, "accelerator-event", kernel="pallas")
    assert acc.mode == "event" and acc.kernel == "pallas"
    assert isinstance(make_runtime(art, "board"), SNNBoardBatched)
    assert isinstance(make_runtime(art, "board-batched"), SNNBoardBatched)
    board_py = make_runtime(art, "board-py", latency_mode=True)
    assert isinstance(board_py, SNNBoard) and board_py.latency_mode
    # kernel= is forwarded to the batched board, not swallowed
    assert make_runtime(art, "board", kernel="pallas").kernel == "pallas"
    with pytest.raises(ValueError, match="accelerator-family"):
        make_runtime(art, "board", kernel="fused")


@pytest.mark.parametrize("spec", ADVERTISED_SPECS)
def test_every_advertised_spec_constructs(trained_artifact, spec):
    """The grammar roundtrip: every spec the module docstring advertises
    constructs, and the suffix really selects mode/kernel (board-batched-
    pallas used to raise `unknown board option 'batched-pallas'`)."""
    art, _, _ = trained_artifact
    rt = make_runtime(art, spec)
    parts = spec.split("-")
    if spec == "reference":
        assert isinstance(rt, SNNReference)
    elif spec == "board-py":
        assert isinstance(rt, SNNBoard)
    elif parts[0] == "board":
        assert isinstance(rt, SNNBoardBatched)
        assert rt.kernel == (parts[2] if len(parts) == 3 else "jnp")
    else:
        assert isinstance(rt, SNNAccelerator)
        # bare "accelerator" is the advertised family-default alias (batch)
        assert rt.mode == (parts[1] if len(parts) > 1 else "batch")
        assert rt.kernel == (parts[2] if len(parts) == 3 else "jnp")


def test_board_kernel_suffix_parses_uniformly(trained_artifact):
    art, _, _ = trained_artifact
    assert make_runtime(art, "board-batched-pallas").kernel == "pallas"
    # explicit suffix beats the harness-level keyword default
    assert make_runtime(art, "board-batched-pallas", kernel="jnp").kernel \
        == "pallas"
    with pytest.raises(ValueError, match="no kernel suffix"):
        make_runtime(art, "board-py-pallas")
    with pytest.raises(ValueError, match="accelerator-family"):
        make_runtime(art, "board-batched-fused")


def test_unknown_specs_fail_loudly(trained_artifact):
    art, _, _ = trained_artifact
    with pytest.raises(ValueError, match="unknown runtime family"):
        make_runtime(art, "fpga")
    with pytest.raises(ValueError, match="board option"):
        make_runtime(art, "board-verilog")
    with pytest.raises(ValueError, match="no options"):
        make_runtime(art, "reference-fast")


def test_all_registered_runtimes_run_the_same_artifact(trained_artifact):
    """Every registry family produces a runner whose forward() agrees with
    the reference on labels — the single-artifact discipline, registry-wide."""
    art, _, (xte, _) = trained_artifact
    ref = np.asarray(make_runtime(art, "reference").forward(xte[:16]).labels)
    for spec in ("accelerator-batch", "accelerator-event", "board",
                 "board-py"):
        out = make_runtime(art, spec).forward(xte[:16])
        assert np.array_equal(np.asarray(out.labels), ref), spec
