"""Packed event buffers — the TPU-native replacement for AER packets.

The FPGA routes (time, neuron) events through an event router into neuron
groups. TPUs have no dynamic dataflow, so we keep the *event-driven* property
(only active spikes cause work) in a shape-static form XLA/Pallas can compile:

    EventFrames:  ids   (T, E_max) int32   neuron ids spiking at step t,
                                            padded with PAD (= -1)
                  count (T,)       int32   number of valid events per step

E_max is part of the deployment artifact (the co-design analogue of the event
router's FIFO depth): the exporter calibrates it from data and rounds up to a
lane multiple, and the runtime asserts the input respects it. Overflow policy
is deterministic drop-with-flag (the hardware would backpressure; we surface
the flag so the caller can fall back to the dense path).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

PAD = -1


@dataclasses.dataclass
class EventFrames:
    ids: jnp.ndarray     # (B, T, E_max) int32, PAD-padded
    count: jnp.ndarray   # (B, T) int32
    overflow: jnp.ndarray  # (B,) bool — any step dropped events

    @property
    def e_max(self) -> int:
        return self.ids.shape[-1]


def pack_events(times: np.ndarray, T: int, e_max: int) -> EventFrames:
    """times (B, N_in) int spike times (T = never) -> packed frames.

    Host-side packing (numpy): this is the "spike packing" stage the paper
    measures separately in the system-path breakdown (Fig 2)."""
    times = np.asarray(times)
    B, N = times.shape
    ids = np.full((B, T, e_max), PAD, dtype=np.int32)
    count = np.zeros((B, T), dtype=np.int32)
    overflow = np.zeros((B,), dtype=bool)
    for b in range(B):
        for t in range(T):
            (idx,) = np.nonzero(times[b] == t)
            k = len(idx)
            if k > e_max:
                overflow[b] = True
                idx = idx[:e_max]
                k = e_max
            ids[b, t, :k] = idx
            count[b, t] = k
    return EventFrames(jnp.asarray(ids), jnp.asarray(count), jnp.asarray(overflow))


def step_counts(times: np.ndarray, T: int) -> np.ndarray:
    """(B, N) int spike times -> (B, T+1) events per step (bin T absorbs the
    never-spikes sentinel). One flat bincount: O(B*N), no python loop over T."""
    B, N = times.shape
    clipped = np.minimum(times, T).astype(np.int64)
    flat = np.arange(B, dtype=np.int64)[:, None] * (T + 1) + clipped
    return np.bincount(flat.ravel(), minlength=B * (T + 1)).reshape(B, T + 1)


def pack_events_batched(times: np.ndarray, T: int, e_max: int) -> EventFrames:
    """Vectorized packing (no python loop over batch OR time) — the optimized
    host path: O(B*N log N) from the argsort, everything else O(B*N).

    Uses an argsort by (time, id): stable ordering makes packing deterministic."""
    times = np.asarray(times)
    B, N = times.shape
    order = np.argsort(times, axis=1, kind="stable")          # (B, N) ids sorted by time
    sorted_t = np.take_along_axis(times, order, axis=1)       # (B, N)
    # position of each event within its timestep: exclusive cumsum of per-step
    # counts gives step_start[:, t] = #events with time < t
    counts = step_counts(times, T)
    step_start = np.zeros((B, T + 1), dtype=np.int64)
    np.cumsum(counts[:, :T], axis=1, out=step_start[:, 1:])
    ids = np.full((B, T, e_max), PAD, dtype=np.int32)
    count = np.minimum(counts[:, :T], e_max).astype(np.int32)
    overflow = np.any(counts[:, :T] > e_max, axis=1)
    pos_in_step = np.arange(N)[None, :] - np.take_along_axis(
        step_start, np.minimum(sorted_t, T).astype(np.int64), axis=1)
    valid = (sorted_t < T) & (pos_in_step < e_max)
    b_idx, n_idx = np.nonzero(valid)
    t_idx = sorted_t[b_idx, n_idx]
    e_idx = pos_in_step[b_idx, n_idx]
    ids[b_idx, t_idx, e_idx] = order[b_idx, n_idx].astype(np.int32)
    return EventFrames(jnp.asarray(ids), jnp.asarray(count), jnp.asarray(overflow))


def calibrate_e_max(times: np.ndarray, T: int, lane: int = 128,
                    headroom: float = 1.0) -> int:
    """Pick E_max from calibration data: max simultaneous events per step,
    scaled by headroom, rounded up to a lane multiple. Stored in the artifact."""
    times = np.asarray(times)
    peak = int(step_counts(times, T)[:, :T].max()) if T > 0 else 0
    e = int(np.ceil(peak * headroom))
    return max(lane, ((e + lane - 1) // lane) * lane)


def unpack_to_raster(frames: EventFrames, n_in: int) -> jnp.ndarray:
    """Inverse of packing: frames -> (B, T, N_in) int8 raster (for testing)."""
    B, T, E = frames.ids.shape
    raster = jnp.zeros((B, T, n_in + 1), dtype=jnp.int8)  # +1 slot absorbs PAD
    ids = jnp.where(frames.ids == PAD, n_in, frames.ids)
    raster = raster.at[
        jnp.arange(B)[:, None, None], jnp.arange(T)[None, :, None], ids
    ].set(1)
    return raster[..., :n_in]
