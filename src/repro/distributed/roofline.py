"""Three-term roofline analysis from the compiled dry-run artifact.

No real TPU in this container, so the "profile" is the compiled program:

    compute term    = FLOPs_per_chip      / peak_bf16_FLOPs        [s]
    memory term     = HBM_bytes_per_chip  / HBM_bandwidth          [s]
    collective term = wire_bytes_per_chip / ICI_link_bandwidth     [s]

Sources:
  * FLOPs / HBM bytes: the analytic model (distributed/analytic.py). XLA's
    cost_analysis counts while bodies once — useless under scan-over-layers —
    so its raw values are recorded as cross-checks (`raw_*`), not used.
  * Collective bytes: post-optimization HLO parsed with while-trip-count
    scaling (distributed/hloparse.py); shapes in the partitioned module are
    per-device, so bytes are per-chip. Wire model: all-reduce 2x, rest 1x.

MODEL_FLOPS (the "useful compute" yardstick):
    train:   6 * N_active * tokens;  prefill: 2 * N_active * tokens;
    decode:  2 * N_active * batch.
The ratio MODEL_FLOPS / total FLOPs exposes remat recompute, attention
overhead and dispatch waste.
"""

from __future__ import annotations

import dataclasses

from repro.core.hw import TPU_V5E
from repro.distributed import analytic as AN
from repro.distributed import hloparse as HP


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes: float            # per chip (wire model)
    coll_by_kind: dict
    model_flops: float           # global useful FLOPs
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float
    step_s: float                # max of the three terms (overlap-optimistic)
    mfu: float                   # model_flops / (chips * peak * step_s)
    raw_hlo_flops: float = 0.0   # cost_analysis (scan bodies counted once)
    raw_hlo_bytes: float = 0.0

    def row(self) -> str:
        return (f"{self.arch:<22} {self.shape:<12} {self.mesh:<7} "
                f"c={self.compute_s:9.3e} m={self.memory_s:9.3e} "
                f"n={self.collective_s:9.3e} -> {self.bottleneck:<10} "
                f"useful={self.useful_ratio:6.1%} MFU={self.mfu:6.2%}")


def model_flops(cfg, cell) -> float:
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * (cfg.dec_max_len if cfg.family == "audio"
                                      else cell.seq_len)
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * (cfg.dec_max_len if cfg.family == "audio"
                                      else cell.seq_len)
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch      # decode: one token per row


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, cfg, cell, **_) -> Roofline:
    hw = TPU_V5E
    est = AN.estimate(cfg, cell, chips)
    coll = HP.collective_bytes_scaled(hlo_text)
    cw = HP.wire_bytes(coll)
    c_s = est["flops_per_chip"] / hw.peak_bf16_flops
    m_s = est["bytes_per_chip"] / hw.hbm_bandwidth
    n_s = cw / hw.ici_link_bandwidth
    terms = {"compute": c_s, "memory": m_s, "collective": n_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    useful = mf / est["flops_global"] if est["flops_global"] else 0.0
    step = max(terms.values())
    mfu = mf / (chips * hw.peak_bf16_flops * step) if step > 0 else 0.0
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    flops_per_chip=est["flops_per_chip"],
                    bytes_per_chip=est["bytes_per_chip"],
                    coll_bytes=cw, coll_by_kind=coll, model_flops=mf,
                    compute_s=c_s, memory_s=m_s, collective_s=n_s,
                    bottleneck=bottleneck, useful_ratio=useful,
                    step_s=step, mfu=mfu,
                    raw_hlo_flops=float(cost.get("flops", 0.0)),
                    raw_hlo_bytes=float(cost.get("bytes accessed", 0.0)))
