"""Mistral-Nemo 12B [hf:mistralai/Mistral-Nemo-Base-2407; hf]: 40L, d5120,
32H GQA(kv=8) head_dim 128, d_ff 14336, vocab 131072, 128k ctx (full
attention — long_500k skipped per assignment rule)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, vocab=131072,
    n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, rope_theta=1e6,
)
