"""Paper Fig 2 analogue: system-path latency decomposition.

The paper splits end-to-end per-image time into: software reference
evaluation, spike packing, hardware run + orchestration, sync/readback. We
measure the same stages of our deployment path on this host and report them
per image, keeping the accelerator-scope number in the callout exactly as
the figure does."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as CM
from repro.core import ttfs
from repro.core.accelerator import SNNAccelerator
from repro.core.events import pack_events_batched
from repro.core.reference import SNNReference


def run(quick: bool = False) -> list[dict]:
    art, xte, yte = CM.get_artifact_and_data(quick)
    B = 1024 if not quick else 512
    imgs = xte[:B]
    T = art.m("encode", "T")

    ref = SNNReference(art)
    acc = SNNAccelerator(art, mode="event")

    # stage 1: software reference evaluation
    t0 = time.perf_counter()
    out_ref = ref.forward(imgs)
    jax.block_until_ready(out_ref.labels)
    t_ref = time.perf_counter() - t0

    # stage 2: spike packing (host)
    t0 = time.perf_counter()
    times = np.asarray(ttfs.encode_ttfs(jnp.asarray(imgs), T,
                                        art.m("encode", "x_min")))
    frames = pack_events_batched(times, T, art.m("events", "e_max"))
    t_pack = time.perf_counter() - t0

    # stage 3: hardware run + orchestration (jitted event path)
    _ = acc._fwd_event(frames.ids, frames.count)   # warmup compile
    t0 = time.perf_counter()
    out_hw = acc._fwd_event(frames.ids, frames.count)
    jax.block_until_ready(out_hw.labels)
    t_hw = time.perf_counter() - t0

    # stage 4: sync/readback + prediction compare (host)
    t0 = time.perf_counter()
    hw_labels = np.asarray(out_hw.labels)
    match = bool(np.array_equal(hw_labels, np.asarray(out_ref.labels)))
    t_read = time.perf_counter() - t0

    proj = CM.snn_event_cost_per_image(art, imgs)
    rows = [{
        "stage": s, "scope": "system", "ms_per_image": 1e3 * t / B,
        "share_pct": 100 * t / (t_ref + t_pack + t_hw + t_read)}
        for s, t in [("software reference evaluation", t_ref),
                     ("spike packing", t_pack),
                     ("hardware run + orchestration", t_hw),
                     ("sync/readback + compare", t_read)]]
    rows.append({"stage": "END-TO-END", "scope": "system", "ms_per_image":
                 1e3 * (t_ref + t_pack + t_hw + t_read) / B,
                 "share_pct": 100.0})
    rows.append({"stage": "CALLOUT accelerator-scope (projected TPU)",
                 "scope": "accelerator (projected)",
                 "ms_per_image": proj["proj_latency_us"] / 1e3,
                 "share_pct": None, "prediction_match": match})
    CM.emit("system_breakdown", rows)
    return rows


def main(quick: bool = False):
    for r in run(quick):
        share = "" if r["share_pct"] is None else f"{r['share_pct']:6.1f}%"
        print(f"{r['stage']:<46} {r['ms_per_image']:>12.5f} ms/img {share}")


if __name__ == "__main__":
    main()
