"""Synthetic LM token pipeline with deterministic per-host sharding.

Determinism is the fault-tolerance primitive: batch ``(step, host)`` is a
pure function of ``(seed, step, host_id, n_hosts)``, so a restarted or
re-joined host regenerates exactly its shard (straggler/elastic story,
DESIGN.md §5) and a restore-from-checkpoint replays the identical stream.

The generator is a mixture of Zipfian unigrams and repeated n-gram motifs so
models have learnable structure (loss decreases) without any external data.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 17
    n_hosts: int = 1
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_count: int = 64


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab
        # motif table shared by all hosts (part of the pipeline "schema")
        self.motifs = rng.randint(0, v, (cfg.motif_count, cfg.motif_len))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.probs = (p / p.sum()).astype(np.float64)

    def _host_rng(self, step: int, host: int) -> np.random.RandomState:
        # stable 32-bit mix of (seed, step, host)
        mix = (self.cfg.seed * 1_000_003 + step * 8191 + host * 131) % (2**31 - 1)
        return np.random.RandomState(mix)

    def host_batch(self, step: int, host: int) -> dict[str, np.ndarray]:
        """-> {"tokens": (per_host, S), "labels": (per_host, S)} int32."""
        c = self.cfg
        rng = self._host_rng(step, host)
        toks = rng.choice(c.vocab, size=(self.per_host, c.seq_len + 1),
                          p=self.probs).astype(np.int32)
        # plant motifs: ~25% of positions covered by repeated n-grams
        n_plant = (c.seq_len // c.motif_len) // 4
        for b in range(self.per_host):
            ids = rng.randint(0, c.motif_count, n_plant)
            pos = rng.randint(0, c.seq_len + 1 - c.motif_len, n_plant)
            for i, p0 in zip(ids, pos):
                toks[b, p0:p0 + c.motif_len] = self.motifs[i]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        parts = [self.host_batch(step, h) for h in range(self.cfg.n_hosts)]
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}
