"""Model zoo: per-arch smoke (reduced configs, forward+train+decode, shape
and finiteness asserts) + cross-implementation consistency oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, reduced
from repro.models.mamba2 import ssd_chunked, ssd_naive_ref
from repro.models.model import LM
from repro.models.moe import moe_ffn, moe_ffn_dense_oracle
from repro.training import lm_step, optim as O


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    S_dec = 16 if cfg.family == "audio" else S
    b = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S_dec))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, S_dec)))}
    if cfg.frontend == "vision":
        b["patch_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        b["enc_frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_train_decode(arch):
    cfg = reduced(get_config(arch))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    B = batch["tokens"].shape[0]

    logits, aux = lm.forward(params, batch["tokens"],
                             patch_embeds=batch.get("patch_embeds"),
                             enc_frames=batch.get("enc_frames"))
    assert logits.shape == (B, batch["tokens"].shape[1], cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    optimizer = O.get(cfg.optimizer, 1e-3)
    step = jax.jit(lm_step.make_train_step(lm, optimizer))
    opt_state = optimizer.init(params)
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0

    cache = lm.init_cache(B, 64, dtype=jnp.float32,
                          enc_len=24 if cfg.enc_layers else None)
    lg, cache = lm.decode_step(params, cache, batch["tokens"][:, :1])
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))
    assert int(cache["len"]) == 1


@pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x7b", "mamba2-780m",
                                  "jamba-1.5-large-398b", "qwen3-8b"])
def test_incremental_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(1), jnp.float32)
    toks = jnp.asarray(np.random.RandomState(2).randint(0, cfg.vocab, (2, 24)))
    full_logits, _ = lm.forward(params, toks)
    logits, _ = lm.prefill(params, toks, s_max=32)
    err = float(jnp.max(jnp.abs(full_logits[:, -1] - logits[:, 0])))
    assert err < 2e-3, (arch, err)


def test_swa_ring_buffer_decode_matches_forward():
    """Mixtral-style SWA: decoding past the window with a ring cache must
    equal the windowed full forward."""
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                              attn_window=8)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(3), jnp.float32)
    S = 24                                          # 3x the window
    toks = jnp.asarray(np.random.RandomState(4).randint(0, cfg.vocab, (1, S)))
    full_logits, _ = lm.forward(params, toks)
    logits, _ = lm.prefill(params, toks, s_max=64)  # cache clamps to window
    err = float(jnp.max(jnp.abs(full_logits[:, -1] - logits[:, 0])))
    assert err < 2e-3, err


def test_ssd_chunked_vs_naive():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 60, 4, 8), jnp.float32)
    a = jnp.asarray(-np.abs(rng.randn(2, 60, 4)) * 0.5, jnp.float32)
    B_ = jnp.asarray(rng.randn(2, 60, 1, 16) * 0.3, jnp.float32)
    C_ = jnp.asarray(rng.randn(2, 60, 1, 16) * 0.3, jnp.float32)
    y1, _ = ssd_chunked(x, a, B_, C_, chunk=16)
    y2 = ssd_naive_ref(x, a, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_dispatch_vs_dense_oracle():
    rng = np.random.RandomState(5)
    p = {"router": jnp.asarray(rng.randn(16, 4) * 0.1, jnp.float32),
         "w_gate": jnp.asarray(rng.randn(4, 16, 32) * 0.1, jnp.float32),
         "w_up": jnp.asarray(rng.randn(4, 16, 32) * 0.1, jnp.float32),
         "w_down": jnp.asarray(rng.randn(4, 32, 16) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.randn(2, 8, 16), jnp.float32)
    y1, aux = moe_ffn(x, p, n_experts=4, top_k=2, capacity_factor=8.0)
    y2 = moe_ffn_dense_oracle(x, p, n_experts=4, top_k=2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0.0          # load-balance loss is live


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop, but output stays finite and
    the drop only ever ZEROES an expert contribution (never corrupts)."""
    rng = np.random.RandomState(6)
    E, k = 4, 2
    p = {"router": jnp.asarray(rng.randn(16, E) * 2.0, jnp.float32),  # skewed
         "w_gate": jnp.asarray(rng.randn(E, 16, 32) * 0.1, jnp.float32),
         "w_up": jnp.asarray(rng.randn(E, 16, 32) * 0.1, jnp.float32),
         "w_down": jnp.asarray(rng.randn(E, 32, 16) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.randn(2, 32, 16), jnp.float32)
    y_cap, _ = moe_ffn(x, p, n_experts=E, top_k=k, capacity_factor=1.0)
    y_full = moe_ffn_dense_oracle(x, p, n_experts=E, top_k=k)
    assert np.all(np.isfinite(np.asarray(y_cap)))
    # dropped-token rows differ from dense, but norm never exceeds dense's
    assert float(jnp.max(jnp.abs(y_cap))) <= float(jnp.max(jnp.abs(y_full))) * 4


def test_param_counts_match_published():
    expect = {"mixtral-8x7b": 46.7e9, "qwen3-moe-235b-a22b": 235e9,
              "mistral-nemo-12b": 12.2e9, "qwen2.5-32b": 32.8e9,
              "yi-6b": 6.1e9, "qwen3-8b": 8.2e9, "mamba2-780m": 0.78e9,
              "jamba-1.5-large-398b": 398e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)
