"""End-to-end driver — the paper's main experiment, full scale.

Trains the 784->150 grouped-TTFS classifier on procedural MNIST (60k),
exports the deployment artifact, and reproduces the paper's validation
protocol on the full 10,000-image test set:

  * full-test-set reference<->accelerator prediction agreement (bit-exact),
  * 5-run repeatability (0 mismatches expected),
  * input-sparsity stress sweep (graceful degradation),
  * deployment resource report (the Table-1 analogue).

    PYTHONPATH=src python examples/train_ttfs_mnist.py [--quick]
"""

import argparse

import numpy as np

from repro.core import codesign, deploy
from repro.core.agreement import full_agreement, repeatability
from repro.data import mnist
from repro.training.ttfs_trainer import train_dense_proxy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--epochs", type=int, default=3)
    args = ap.parse_args()

    print("== data: procedural MNIST (offline container; DESIGN.md §6)")
    xtr, ytr = mnist.load("train")
    xte, yte = mnist.load("test")
    if args.quick:
        xtr, ytr, xte, yte = xtr[:8192], ytr[:8192], xte[:2000], yte[:2000]

    print("== train (dense proxy of grouped readout)")
    res = train_dense_proxy(xtr, ytr, test_images=xte, test_labels=yte,
                            epochs=args.epochs)
    print(f"   dense test acc {res.test_acc:.4%} "
          f"({res.steps} steps, {res.wall_s:.0f}s)")

    print("== export single deployment artifact")
    art = deploy.export(res.model, "/tmp/ttfs_mnist_artifact.npz",
                        calib_images=xtr[:8192], calib_labels=ytr[:8192])

    print("== full-test-set agreement (the paper's headline claim)")
    rep = full_agreement(art, xte, yte, chunk=2048)
    print(rep.summary())
    assert rep.exact_match

    print("== repeatability (paper §3.3)")
    r = repeatability(art, xte[:2000] if args.quick else xte,
                      yte[:2000] if args.quick else yte, runs=5, chunk=2048)
    print(f"   {r['image_run_pairs']} image-run pairs, "
          f"{r['mismatches']} mismatches, stable={r['accuracy_stable']}")
    assert r["mismatches"] == 0

    print("== sparsity stress (paper Fig 3)")
    from benchmarks.bench_sparsity import drop_spikes
    from repro.core.accelerator import SNNAccelerator
    acc = SNNAccelerator(art, mode="batch")
    for ratio in (0.0, 0.25, 0.5, 0.75):
        x = drop_spikes(xte[:4000], ratio)
        a = float(np.mean(np.asarray(acc.forward(x).labels) == yte[:4000]))
        print(f"   drop {ratio:4.0%}: hw TTFS accuracy {a:.4%}")

    print("== deployment resource report (Table-1 analogue)")
    print(codesign.plan(784, 150).table())


if __name__ == "__main__":
    main()
