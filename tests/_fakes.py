"""Shared test fakes for the runtime registry.

``DivergentRuntime`` wraps the software reference and silently flips one
label and one first-spike time — the exact drift the agreement harness and
the conformance oracles exist to catch. ``registered_family`` temporarily
installs a factory in ``runtimes._REGISTRY`` and guarantees cleanup, so a
test cannot leak a fake family into the rest of the suite (which would fail
the registry-consistency oracle everywhere else).
"""

from __future__ import annotations

import contextlib

import numpy as np

from repro.core import runtimes
from repro.core.reference import SNNOutput, SNNReference


class DivergentRuntime:
    def __init__(self, art):
        self._ref = SNNReference(art)

    def forward(self, images):
        out = self._ref.forward(images)
        labels = np.asarray(out.labels).copy()
        labels[0] = (labels[0] + 1) % max(2, int(labels.max()) + 1)
        first = np.asarray(out.first_spike).copy()
        first[0, 0] += 1
        return SNNOutput(labels, first, np.asarray(out.v_final),
                         np.asarray(out.steps))


@contextlib.contextmanager
def registered_family(name: str, factory):
    runtimes._REGISTRY[name] = factory
    try:
        yield
    finally:
        del runtimes._REGISTRY[name]


@contextlib.contextmanager
def divergent_family(name: str = "divergent"):
    with registered_family(name, lambda art, opts, **kw: DivergentRuntime(art)):
        yield
