"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The container image does not ship hypothesis, and installing packages is not
an option; without this stub six test modules die at collection time. The
stub implements exactly the surface the suite uses — ``strategies.integers``,
``@given`` with positional strategies, and ``@settings(max_examples=...,
deadline=...)`` — drawing a fixed pseudo-random example sequence so runs are
reproducible. When the real package is available, ``conftest.py`` never
registers this module.
"""

from __future__ import annotations

import random


class _IntegersStrategy:
    def __init__(self, min_value: int, max_value: int):
        self.min_value, self.max_value = int(min_value), int(max_value)

    def example(self, rng: random.Random, index: int) -> int:
        # lead with the bounds (the classic hypothesis shrink targets),
        # then draw uniformly
        if index == 0:
            return self.min_value
        if index == 1:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntegersStrategy:
        return _IntegersStrategy(min_value, max_value)


def given(*strats: _IntegersStrategy):
    def decorate(fn):
        def runner():
            n = getattr(runner, "_stub_max_examples", 10)
            rng = random.Random(0xC0FFEE)
            for i in range(n):
                fn(*(s.example(rng, i) for s in strats))

        # plain __name__ copy only: functools.wraps would expose the wrapped
        # signature and make pytest treat the strategy args as fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner._stub_max_examples = 10
        return runner

    return decorate


def settings(max_examples: int = 10, deadline=None, **_kwargs):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
