"""Roofline table for the LM zoo — renders EXPERIMENTS.md §Roofline from the
dry-run result JSONs (results/dryrun/*.json). Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load() -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    if not rows:
        raise FileNotFoundError(
            f"no dry-run results in {DRYRUN_DIR}; run repro.launch.dryrun --all")
    return rows


def render(rows: list[dict], mesh: str = "single") -> str:
    out = [f"{'arch':<22} {'shape':<12} {'compute_s':>11} {'memory_s':>11} "
           f"{'collect_s':>11} {'bottleneck':<11} {'useful':>7} {'MFU':>7}"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"{r['arch']:<22} {r['shape']:<12} "
                       f"{'— skipped: ' + r['reason'][:58]}")
            continue
        out.append(
            f"{r['arch']:<22} {r['shape']:<12} {r['compute_s']:>11.3e} "
            f"{r['memory_s']:>11.3e} {r['collective_s']:>11.3e} "
            f"{r['bottleneck']:<11} {r['useful_ratio']:>7.1%} {r['mfu']:>7.2%}")
    return "\n".join(out)


def main():
    rows = load()
    ok = [r for r in rows if r.get("status") == "ok"]
    print(f"loaded {len(rows)} cells ({len(ok)} compiled)")
    print("\n--- single-pod (16x16 = 256 chips) ---")
    print(render(rows, "single"))
    print("\n--- multi-pod (2x16x16 = 512 chips) ---")
    print(render(rows, "multi"))


if __name__ == "__main__":
    main()
