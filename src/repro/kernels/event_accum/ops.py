"""Jitted public wrapper for event accumulation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.event_accum.kernel import event_accum_kernel


@jax.jit
def event_accum(ids: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """ids (T, E_max) int32, w (N_in, N_pad) int8 -> (T, N_pad) int32."""
    return event_accum_kernel(ids, w, interpret=use_interpret())
