"""Quantization: roundtrip bounds and leak mapping."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import quant


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.RandomState(seed % 2**32)
    w = rng.randn(32, 16).astype(np.float32)
    q, scale = quant.quantize_weights(w)
    assert q.dtype == np.int8
    err = np.max(np.abs(quant.dequantize(q, scale) - w))
    assert err <= scale / 2 + 1e-7          # round-to-nearest bound


def test_quantize_zero_weights():
    q, scale = quant.quantize_weights(np.zeros((4, 4), np.float32))
    assert np.all(q == 0) and scale == 1.0


def test_leak_shift_monotone():
    shifts = [quant.leak_shift_from_tau(t) for t in (2.0, 8.0, 32.0, 128.0)]
    assert shifts == sorted(shifts)          # longer tau -> weaker leak
    assert quant.leak_shift_from_tau(np.inf) == 31
