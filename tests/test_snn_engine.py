"""Batched SNN serving engine: queueing, micro-batching, overflow fallback,
scope-aware stats — plus the event-path edge cases the engine relies on."""

import copy

import numpy as np
import pytest

from repro.core import events
from repro.core.accelerator import SNNAccelerator
from repro.core.artifact import Artifact
from repro.core.reference import SNNReference
from repro.serving.snn_engine import SNNServeEngine


def _tiny_emax_artifact(art: Artifact, e_max: int = 8) -> Artifact:
    """In-memory clone whose calibrated event-buffer depth is far too small —
    forces the overflow → dense-fallback path."""
    clone = Artifact(copy.deepcopy(art.meta), dict(art.arrays))
    clone.meta["events"]["e_max"] = e_max
    return clone


# ----------------------------------------------------------------- serving
def test_engine_matches_reference_labels(trained_artifact):
    art, _, (xte, yte) = trained_artifact
    ref = SNNReference(art)
    want = np.asarray(ref.forward(xte[:96]).labels)
    for kernel in ("jnp", "fused"):
        eng = SNNServeEngine(art, max_batch=32, kernel=kernel)
        got = eng.classify(xte[:96])
        assert np.array_equal(got, want), kernel


def test_engine_micro_batches_and_stats(trained_artifact):
    art, _, (xte, _) = trained_artifact
    eng = SNNServeEngine(art, max_batch=4, kernel="fused")
    rids = [eng.submit(x) for x in xte[:10]]
    done = eng.flush()
    assert sorted(done) == rids
    assert all(done[r].label is not None for r in rids)
    st = eng.stats()
    assert st["images_out"] == 10
    assert st["batches"] == 3                      # 4 + 4 + 2 (padded)
    assert st["system_s"] >= st["accelerator_s"] > 0
    assert st["host_overhead_s"] >= 0
    assert st["overflow_fallbacks"] == 0


def test_engine_latency_mode_matches_full(trained_artifact):
    art, _, (xte, _) = trained_artifact
    full = SNNServeEngine(art, max_batch=16, kernel="fused")
    lat = SNNServeEngine(art, max_batch=16, kernel="fused", latency_mode=True)
    want = full.classify(xte[:32])
    got = lat.classify(xte[:32])
    assert np.array_equal(got, want)
    T = int(art.m("encode", "T"))
    done = lat.flush()                             # empty queue -> no-op
    assert done == {}
    rid = lat.submit(xte[0])
    steps = lat.flush()[rid].steps
    assert 0 < steps <= T


def test_engine_overflow_falls_back_to_dense(trained_artifact):
    """Rows whose frames exceed E_max must be served via the dense batch
    path, not dropped — labels still match the reference exactly."""
    art, _, (xte, _) = trained_artifact
    tiny = _tiny_emax_artifact(art, e_max=8)
    eng = SNNServeEngine(tiny, max_batch=16, kernel="fused")
    got = eng.classify(xte[:32])
    want = np.asarray(SNNReference(art).forward(xte[:32]).labels)
    assert np.array_equal(got, want)
    st = eng.stats()
    assert st["overflow_fallbacks"] > 0
    done_flags = [r.fallback_dense for r in eng.flush().values()]
    assert done_flags == []                        # queue drained


def test_engine_board_backend_honors_kernel(trained_artifact):
    """backend="board" used to silently drop kernel= (a requested Pallas
    board path quietly ran jnp); the requested kernel must be the one
    constructed — and an impossible one must fail loudly."""
    art, _, _ = trained_artifact
    assert SNNServeEngine(art, backend="board").accel.kernel == "jnp"
    eng = SNNServeEngine(art, backend="board", kernel="pallas")
    assert eng.accel.kernel == "pallas"
    with pytest.raises(ValueError, match="accelerator-family"):
        SNNServeEngine(art, backend="board", kernel="fused")
    # accelerator backend: kernel=None means its own default, "fused"
    assert SNNServeEngine(art).accel.kernel == "fused"
    assert SNNServeEngine(art, kernel="jnp").accel.kernel == "jnp"


def test_classify_preserves_unclaimed_submits(trained_artifact):
    """classify() drains the whole queue but must NOT discard results of
    requests submit()ed earlier by other callers — they stay claimable by
    the next flush()."""
    art, _, (xte, _) = trained_artifact
    ref = SNNReference(art)
    eng = SNNServeEngine(art, max_batch=8, kernel="fused")
    rid_early = eng.submit(xte[0])
    got = eng.classify(xte[1:5])
    want = np.asarray(ref.forward(xte[:5]).labels)
    assert np.array_equal(got, want[1:5])      # classify sees only its own
    done = eng.flush()                         # earlier submit still claimable
    assert list(done) == [rid_early]
    assert done[rid_early].label == want[0]
    assert eng.flush() == {}                   # claimed exactly once


def test_engine_stats_percentiles_and_workers(trained_artifact):
    """The facade surfaces the scheduler's latency percentiles, and
    workers>=1 turns on continuous batching behind the same API."""
    art, _, (xte, _) = trained_artifact
    eng = SNNServeEngine(art, max_batch=8, kernel="fused")
    eng.classify(xte[:16])
    st = eng.stats()
    assert (0 < st["p50_latency_us"] <= st["p95_latency_us"]
            <= st["p99_latency_us"])
    assert st["backend"] == "accelerator" and st["workers"] == 0

    want = np.asarray(SNNReference(art).forward(xte[:16]).labels)
    eng2 = SNNServeEngine(art, max_batch=8, kernel="fused", workers=2,
                          max_wait_us=500.0)
    try:
        assert np.array_equal(eng2.classify(xte[:16]), want)
        assert eng2.stats()["workers"] == 2
    finally:
        eng2.close()


# ------------------------------------------------------- event path edges
def test_accelerator_overflow_raises_and_opt_out(trained_artifact):
    art, _, (xte, _) = trained_artifact
    tiny = _tiny_emax_artifact(art, e_max=8)
    acc = SNNAccelerator(tiny, mode="event", kernel="fused")
    with pytest.raises(OverflowError):
        acc.forward(xte[:8])
    # pre-validated callers may skip the host overflow read; the forward
    # then runs on the (deterministically truncated) frames without raising
    out = acc.forward(xte[:8], check_overflow=False)
    assert out.labels.shape == (8,)


def test_calibrate_e_max_headroom_and_rounding():
    times = np.zeros((2, 100), np.int32)           # 100 events at t=0
    e = events.calibrate_e_max(times, T=4, lane=128)
    assert e == 128                                # rounded up to one lane
    e2 = events.calibrate_e_max(times, T=4, lane=128, headroom=1.5)
    assert e2 == 256                               # 150 -> two lanes
    assert events.calibrate_e_max(times, T=4, lane=8) == 104  # 100 -> 8*13


def test_packing_vectorized_equals_loop_large():
    """The bincount/cumsum packer agrees with the O(B*T) loop packer on a
    big ragged case (the host 'spike packing' stage of the system path)."""
    rng = np.random.RandomState(3)
    times = rng.randint(0, 33, (16, 784)).astype(np.int32)
    a = events.pack_events(times, 32, 128)
    b = events.pack_events_batched(times, 32, 128)
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))
    assert np.array_equal(np.asarray(a.overflow), np.asarray(b.overflow))
