"""Grouped TTFS decode kernel — the RTL comparator tree, lane-parallel.

The FPGA decodes the label with a comparator tree over class-group first-spike
registers. The TPU version evaluates the same deterministic rule in one
kernel invocation per batch row: pack (time, neuron_index) into a single
monotone int32 key so that one min-reduction implements both the earliest-
time rule AND the lowest-index tie-break exactly:

    key(n) = first_spike[n] * NPAD + n        (fits int32 for T*NPAD < 2^31)

Group min over keys, then arg-min over groups (first-index tie-break), with
the artifact's membrane fallback when nothing fired. Bit-identical to
core.ttfs.decode_labels by construction; tests assert it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(first_ref, v_ref, out_ref, *, n_groups: int, per_group: int,
                   sentinel: int, fallback: str):
    n = n_groups * per_group
    first = first_ref[0, :].astype(jnp.int32)
    v = v_ref[0, :].astype(jnp.int32)
    key = first * n + jax.lax.iota(jnp.int32, n)
    gkey = jnp.min(key.reshape(n_groups, per_group), axis=1)       # (G,)
    ttfs_label = jnp.argmin(gkey).astype(jnp.int32)
    any_spike = jnp.min(first) < sentinel
    if fallback == "membrane":
        gv = jnp.max(v.reshape(n_groups, per_group), axis=1)
        fb_label = jnp.argmax(gv).astype(jnp.int32)
    else:
        fb_label = jnp.int32(0)
    out_ref[0] = jnp.where(any_spike, ttfs_label, fb_label)


def ttfs_decode_kernel(first_spike: jnp.ndarray, v_final: jnp.ndarray, *,
                       n_groups: int, per_group: int, sentinel: int,
                       fallback: str = "membrane",
                       interpret: bool = True) -> jnp.ndarray:
    """first_spike/v_final (B, G*P) int32 -> labels (B,) int32."""
    B, N = first_spike.shape
    assert N == n_groups * per_group
    kernel = functools.partial(_decode_kernel, n_groups=n_groups,
                               per_group=per_group, sentinel=sentinel,
                               fallback=fallback)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(first_spike, v_final)
