"""Fused event→LIF→decode megakernel: bit-exactness against the staged
pipeline and the software reference, in full-T and early-exit latency mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ttfs
from repro.core.accelerator import SNNAccelerator
from repro.core.agreement import full_agreement
from repro.core import events
from repro.core.events import pack_events_batched
from repro.core.lif_dynamics import lif_scan, lif_scan_early_exit
from repro.core.reference import SNNReference
from repro.kernels.event_accum.ref import event_accum_ref
from repro.kernels.fused_event_lif import ops as fused


def _random_case(rng, B, T, N_in, N, e_max=None):
    times = rng.randint(0, T + 1, (B, N_in)).astype(np.int32)
    if e_max is None:
        e_max = events.calibrate_e_max(times, T, lane=8)
    frames = pack_events_batched(times, T, e_max)
    assert not np.any(np.asarray(frames.overflow))
    w = jnp.asarray(rng.randint(-127, 128, (N_in, N)), jnp.int8)
    thr = jnp.asarray(rng.randint(20, 1500, (N,)), jnp.int32)
    return frames, w, thr


def _staged_oracle(frames, w, thr, ls, T):
    cur = jax.vmap(lambda ids: event_accum_ref(ids, w))(frames.ids)
    return lif_scan(jnp.moveaxis(cur, 1, 0), thr, ls, T)


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("B,T,N_in,N,ls", [(1, 4, 50, 128, 4),
                                           (3, 16, 100, 256, 2),
                                           (2, 8, 784, 256, 6)])
def test_fused_kernel_matches_staged(B, T, N_in, N, ls):
    rng = np.random.RandomState(B * 10 + T)
    frames, w, thr = _random_case(rng, B, T, N_in, N)
    ref = _staged_oracle(frames, w, thr, ls, T)
    for backend in ("ref", "pallas"):
        res = fused.fused_event_lif(frames.ids, frames.count, w, thr, ls,
                                    backend=backend)
        assert np.array_equal(np.asarray(res.first_spike),
                              np.asarray(ref.first_spike)), backend
        assert np.array_equal(np.asarray(res.v_final),
                              np.asarray(ref.v_final)), backend


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fused_kernel_property(seed):
    rng = np.random.RandomState(seed % 2**32)
    B, T = int(rng.randint(1, 4)), int(rng.randint(1, 20))
    N_in, N = int(rng.randint(10, 200)), 128 * int(rng.randint(1, 3))
    ls = int(rng.randint(1, 10))
    frames, w, thr = _random_case(rng, B, T, N_in, N)
    ref = _staged_oracle(frames, w, thr, ls, T)
    got = np.asarray(fused.fused_event_lif(
        frames.ids, frames.count, w, thr, ls, backend="pallas").first_spike)
    assert np.array_equal(got, np.asarray(ref.first_spike))
    # sentinel semantics preserved: never-fired lanes report exactly T
    assert np.all(got[got >= T] == T)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_fused_early_exit_matches_scan_early_exit(backend):
    rng = np.random.RandomState(7)
    B, T, N_in, N, ls = 5, 12, 100, 256, 4
    frames, w, thr = _random_case(rng, B, T, N_in, N)
    cur = jax.vmap(lambda ids: event_accum_ref(ids, w))(frames.ids)
    res, steps = fused.fused_event_lif_early_exit(
        frames.ids, frames.count, w, thr, ls, backend=backend)
    for b in range(B):
        r, s = lif_scan_early_exit(cur[b], thr, ls, T)
        assert np.array_equal(np.asarray(res.first_spike[b]),
                              np.asarray(r.first_spike))
        assert np.array_equal(np.asarray(res.v_final[b]),
                              np.asarray(r.v_final))
        assert int(steps[b]) == int(s)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("fallback", ["membrane", "zero"])
def test_fused_decode_matches_decode_labels(backend, fallback):
    rng = np.random.RandomState(11)
    B, T, N_in, N, ls = 4, 10, 120, 256, 3
    G, P = 10, 15
    n_out = G * P
    frames, w, thr = _random_case(rng, B, T, N_in, N)
    ref = _staged_oracle(frames, w, thr, ls, T)
    want = ttfs.decode_labels(ref.first_spike[:, :n_out],
                              ref.v_final[:, :n_out], n_groups=G,
                              per_group=P, sentinel=T, fallback=fallback)
    _, labels = fused.fused_event_lif_decode(
        frames.ids, frames.count, w, thr, ls, n_out=n_out, n_groups=G,
        per_group=P, fallback=fallback, backend=backend)
    assert np.array_equal(np.asarray(labels), np.asarray(want))


# ------------------------------------------------------- accelerator level
def test_fused_requires_event_mode(trained_artifact):
    art, _, _ = trained_artifact
    with pytest.raises(ValueError):
        SNNAccelerator(art, mode="batch", kernel="fused")


def test_fused_accelerator_agrees_with_reference(trained_artifact):
    art, _, (xte, yte) = trained_artifact
    ref = SNNReference(art)
    out_ref = ref.forward(xte[:128])
    acc = SNNAccelerator(art, mode="event", kernel="fused")
    out = acc.forward(xte[:128])
    assert np.array_equal(np.asarray(out.labels), np.asarray(out_ref.labels))
    assert np.array_equal(np.asarray(out.first_spike),
                          np.asarray(out_ref.first_spike))
    assert np.array_equal(np.asarray(out.v_final),
                          np.asarray(out_ref.v_final))


def test_fused_full_agreement_suite(trained_artifact):
    """The 10k-path invariant, fused kernel edition: decoded labels AND
    first-spike times match the reference elementwise."""
    art, _, (xte, yte) = trained_artifact
    rep = full_agreement(art, xte[:512], yte[:512], kernel="fused",
                         runtimes=("accelerator-event",), chunk=256)
    assert rep.exact_match, rep.summary()


def test_fused_early_exit_labels_match_full_run(trained_artifact):
    art, _, (xte, _) = trained_artifact
    acc = SNNAccelerator(art, mode="event", kernel="fused")
    full = acc.forward(xte[:64])
    lat = acc.forward(xte[:64], latency_mode=True)
    assert np.array_equal(np.asarray(full.labels), np.asarray(lat.labels))
    assert np.all(np.asarray(lat.steps) <= art.m("encode", "T"))
    # staged latency mode and fused latency mode agree on steps too
    staged = SNNAccelerator(art, mode="event", kernel="jnp")
    lat_staged = staged.forward(xte[:64], latency_mode=True)
    assert np.array_equal(np.asarray(lat.steps), np.asarray(lat_staged.steps))
    assert np.array_equal(np.asarray(lat.labels),
                          np.asarray(lat_staged.labels))


def test_fused_ref_mirror_is_oracle_for_pallas(trained_artifact):
    """ops backend dispatch: both backends produce identical results on real
    artifact data (the mirror IS the oracle for the TPU kernel)."""
    art, _, (xte, _) = trained_artifact
    acc = SNNAccelerator(art, mode="event", kernel="fused")
    T = int(art.m("encode", "T"))
    times = np.asarray(ttfs.encode_ttfs(
        jnp.asarray(xte[:32], jnp.float32), T, float(art.m("encode", "x_min"))))
    frames = pack_events_batched(times, T, int(art.m("events", "e_max")))
    a = fused.fused_event_lif(frames.ids, frames.count, acc.w_padded,
                              acc.thr_padded, acc.leak_shift, backend="ref")
    b = fused.fused_event_lif(frames.ids, frames.count, acc.w_padded,
                              acc.thr_padded, acc.leak_shift, backend="pallas")
    assert np.array_equal(np.asarray(a.first_spike), np.asarray(b.first_spike))
    assert np.array_equal(np.asarray(a.v_final), np.asarray(b.v_final))
