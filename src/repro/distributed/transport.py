"""TCP program-distribution transport — the network leg of ``broadcast_program``.

The shared-file transport in ``launch.mesh`` covers single-host multi-process
serving; this module is the multi-host leg the ROADMAP called for: the leader
serves the canonical-JSON program envelope (``core.program_io``) over a
length-prefixed socket protocol, followers fetch it with bounded retries and
re-verify every fingerprint through ``deserialize_program`` before the
program may enter the local ``ProgramCache``.

Wire frame (one per connection, leader → follower, then close)::

    MAGIC(4) | VERSION(1) | LENGTH(8, big-endian) | SHA256(payload)(32) | payload

Design rules, each load-bearing for the conformance suite's
*detected-or-bit-exact* invariant:

  * every frame carries its own checksum — a flipped byte anywhere in the
    payload fails loudly naming the checksum, never reconstructs a program;
  * the checksum authenticates the FRAME, not the program: a tamperer who
    re-frames a modified envelope with a fresh checksum still fails inside
    ``deserialize_program`` (artifact/array/program fingerprints) — transport
    integrity and program integrity are independent layers, and the fault
    proxy exercises both;
  * fetches are bounded: connect and read timeouts, ``retries`` re-attempts
    with exponential backoff whose jitter comes from a SEEDED rng
    (``backoff_schedule`` is reproducible — chaos tests replay exact retry
    timing), and a hard envelope byte cap so a lying length field cannot
    balloon memory;
  * every failure is a typed ``TransportError`` subclass whose message names
    the corruption (truncation point, bad magic, checksum mismatch, timeout
    site) — a fetch NEVER returns bytes it could not verify.

Telemetry follows the PR-6 conventions: ``transport.publish`` /
``transport.fetch`` spans carry logical counters (bytes, attempts, retries)
in canonical ``attrs`` and host specifics (endpoint) in non-canonical
``meta``; the module-level ``METRICS`` registry feeds transport health into
``ServingScheduler.stats()``.
"""

from __future__ import annotations

import hashlib
import random
import socket
import struct
import threading
import time

from repro.telemetry import trace as ttrace
from repro.telemetry.metrics import RECOVERY_BUCKETS_MS, MetricsRegistry

MAGIC = b"RPRG"
WIRE_VERSION = 1
#: MAGIC + version byte + u64 length + sha256 digest
HEADER_LEN = len(MAGIC) + 1 + 8 + 32
#: hard cap on envelope size — a lying length field must not balloon memory
MAX_ENVELOPE_BYTES = 16 << 20


class TransportError(RuntimeError):
    """Program distribution over the transport failed; message names why."""


class FrameError(TransportError):
    """The wire frame is corrupt (truncation, bad magic/version/length,
    checksum mismatch) — names the exact corruption."""


class TransportTimeout(TransportError):
    """A connect or read deadline elapsed; names which and where."""


class FetchRetriesExhausted(TransportError):
    """Every fetch attempt failed; carries the attempt count and last error."""

    def __init__(self, endpoint: str, attempts: int, last: Exception):
        super().__init__(
            f"fetch from {endpoint} failed after {attempts} attempt(s); "
            f"last error: {type(last).__name__}: {last}")
        self.endpoint = endpoint
        self.attempts = attempts
        self.last = last


# ------------------------------------------------------------------ metrics
#: process-wide transport health — merged into ``ServingScheduler.stats()``
METRICS = MetricsRegistry()


def metrics_snapshot() -> dict:
    return METRICS.snapshot()


def reset_metrics() -> None:
    METRICS.reset()


# ------------------------------------------------------------------- frames
def encode_frame(payload: bytes) -> bytes:
    """Frame an envelope for the wire: magic, version, length, checksum."""
    if len(payload) > MAX_ENVELOPE_BYTES:
        raise FrameError(f"envelope of {len(payload)} bytes exceeds the "
                         f"{MAX_ENVELOPE_BYTES}-byte transport cap")
    return (MAGIC + bytes([WIRE_VERSION]) + struct.pack(">Q", len(payload))
            + hashlib.sha256(payload).digest() + payload)


def decode_header(header: bytes) -> tuple[int, bytes]:
    """Validate a frame header; returns (payload length, expected digest)."""
    if len(header) != HEADER_LEN:
        raise FrameError(f"frame header is {len(header)} bytes, "
                         f"expected {HEADER_LEN}")
    if header[:4] != MAGIC:
        raise FrameError(f"bad frame magic {header[:4]!r} != {MAGIC!r} — "
                         f"not a program envelope stream")
    version = header[4]
    if version != WIRE_VERSION:
        raise FrameError(f"unsupported wire version {version} "
                         f"(this build speaks {WIRE_VERSION})")
    (length,) = struct.unpack(">Q", header[5:13])
    if length <= 0:
        raise FrameError(f"frame declares a non-positive payload length "
                         f"{length}")
    if length > MAX_ENVELOPE_BYTES:
        raise FrameError(f"frame declares {length} payload bytes, over the "
                         f"{MAX_ENVELOPE_BYTES}-byte transport cap")
    return int(length), header[13:13 + 32]


def _read_exact(sock: socket.socket, n: int, what: str) -> bytes:
    """Read exactly ``n`` bytes or fail naming the truncation/stall point."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        try:
            chunk = sock.recv(min(65536, n - got))
        except socket.timeout:
            raise TransportTimeout(
                f"read timed out after {got}/{n} bytes of {what} — "
                f"stalled sender") from None
        if not chunk:
            raise FrameError(f"connection closed after {got}/{n} bytes of "
                             f"{what} — truncated frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_frame(sock: socket.socket) -> bytes:
    """Read and verify one frame; returns the payload or raises naming the
    corruption (truncation, bad header, checksum mismatch)."""
    length, want = decode_header(_read_exact(sock, HEADER_LEN,
                                             "the frame header"))
    payload = _read_exact(sock, length, "the envelope payload")
    digest = hashlib.sha256(payload).digest()
    if digest != want:
        raise FrameError(
            f"frame checksum mismatch: payload sha256 {digest.hex()[:12]}... "
            f"!= header's {want.hex()[:12]}... — bytes were corrupted in "
            f"transit")
    return payload


# ------------------------------------------------------------------- server
class ProgramServer:
    """Leader-side envelope server: every accepted connection receives one
    framed copy of the published envelope, then the connection closes.

    Push-only by design — there is nothing to request (the envelope is the
    whole catalog), so the protocol has no client→server bytes at all and a
    malicious client cannot make the leader parse anything. Each connection
    is served on its own daemon thread so one slow (or slow-loris) follower
    never blocks the accept loop."""

    def __init__(self, blob: bytes, host: str = "127.0.0.1", port: int = 0,
                 send_timeout_s: float = 10.0):
        self._frame = encode_frame(blob)
        self.blob_bytes = len(blob)
        self.host = host
        self._requested_port = port
        self.port: int | None = None
        self.send_timeout_s = float(send_timeout_s)
        self.serves = 0
        self._lock = threading.Lock()
        self._served_cv = threading.Condition(self._lock)
        self._stop = False
        self._sock: socket.socket | None = None
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "ProgramServer":
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, self._requested_port))
        sock.listen(16)
        sock.settimeout(0.1)              # poll the stop flag
        self._sock = sock
        self.port = sock.getsockname()[1]
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True,
                                        name=f"program-server-{self.port}")
        self._thread.start()
        return self

    def stop(self) -> None:
        with self._lock:
            self._stop = True
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "ProgramServer":
        return self.start() if self.port is None else self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    @property
    def endpoint(self) -> str:
        return f"tcp://{self.host}:{self.port}"

    def await_serves(self, n: int, timeout_s: float = 30.0) -> bool:
        """Block until ``n`` envelope fetches have completed (the leader's
        barrier before exiting a launch) or the timeout elapses."""
        deadline = time.monotonic() + timeout_s
        with self._served_cv:
            while self.serves < n:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._served_cv.wait(timeout=remaining)
            return True

    # ------------------------------------------------------------ serving
    def _accept_loop(self) -> None:
        while True:
            with self._lock:
                if self._stop:
                    return
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                     # listener closed by stop()
            threading.Thread(target=self._serve_one, args=(conn,),
                             daemon=True).start()

    def _serve_one(self, conn: socket.socket) -> None:
        try:
            conn.settimeout(self.send_timeout_s)
            conn.sendall(self._frame)
            with self._served_cv:
                self.serves += 1
                self._served_cv.notify_all()
            METRICS.inc("serves")
        except OSError:
            METRICS.inc("serve_failures")  # follower vanished mid-send
        finally:
            try:
                conn.close()
            except OSError:
                pass


def tcp_publisher(host: str = "127.0.0.1", port: int = 0):
    """A ``broadcast_program``-compatible publish hook: publishing starts a
    ``ProgramServer`` for the envelope and parks it on ``publish.server`` so
    the caller can ``await_serves``/``stop`` it (the server outlives the
    publish call on purpose — followers fetch later)."""

    def publish(blob: bytes) -> None:
        with ttrace.span("transport.publish", "system",
                         attrs={"bytes": len(blob)},
                         meta={"endpoint": f"tcp://{host}:{port}"}):
            server = ProgramServer(blob, host=host, port=port).start()
        publish.server = server
        METRICS.inc("publishes")
        METRICS.inc("publish_bytes", len(blob))

    publish.server = None
    return publish


# ------------------------------------------------------------------ fetcher
def backoff_schedule(retries: int, base_s: float, seed: int) -> list[float]:
    """The exact sleep before each re-attempt: exponential in the attempt
    index with multiplicative jitter in [1, 2) from a seeded rng. A pure
    function of (retries, base_s, seed) — chaos tests replay retry timing
    bit-for-bit, and two followers with different seeds never thundering-herd
    the leader in lockstep."""
    rng = random.Random(seed)
    return [base_s * (2 ** i) * (1.0 + rng.random()) for i in range(retries)]


def fetch_bytes(host: str, port: int, *, connect_timeout_s: float = 5.0,
                read_timeout_s: float = 5.0, retries: int = 3,
                backoff_s: float = 0.05, seed: int = 0) -> bytes:
    """Fetch one verified envelope from a leader's ``ProgramServer``.

    Bounded everywhere: connect timeout, read timeout, ``retries``
    re-attempts with seeded-jitter exponential backoff, and the frame length
    cap. Returns the checksum-verified payload bytes or raises
    ``FetchRetriesExhausted`` wrapping the last typed failure — never returns
    bytes it could not verify, never hangs."""
    endpoint = f"tcp://{host}:{port}"
    sleeps = backoff_schedule(retries, backoff_s, seed)
    attempts = retries + 1
    rec = ttrace.get()
    span = rec.begin("transport.fetch", "system",
                     meta={"endpoint": endpoint})
    last: Exception | None = None
    for attempt in range(attempts):
        METRICS.inc("fetch_attempts")
        if attempt:
            METRICS.inc("fetch_retries")
        t0 = time.perf_counter()
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            sock.settimeout(connect_timeout_s)
            try:
                sock.connect((host, port))
            except socket.timeout:
                raise TransportTimeout(
                    f"connect to {endpoint} timed out after "
                    f"{connect_timeout_s}s") from None
            sock.settimeout(read_timeout_s)
            payload = read_frame(sock)
            METRICS.inc("fetches")
            METRICS.inc("fetch_bytes", len(payload))
            METRICS.observe("fetch_ms", 1e3 * (time.perf_counter() - t0),
                            RECOVERY_BUCKETS_MS)
            rec.end(span, attrs={"bytes": len(payload),
                                 "attempts": attempt + 1,
                                 "retries": attempt})
            return payload
        except (TransportError, OSError) as e:
            last = e
        finally:
            try:
                sock.close()
            except OSError:
                pass
        if attempt < retries:
            time.sleep(sleeps[attempt])
    METRICS.inc("fetch_failures")
    exhausted = FetchRetriesExhausted(endpoint, attempts, last)
    rec.end(span, attrs={"attempts": attempts, "retries": retries,
                         "error": type(last).__name__})
    raise exhausted


def tcp_fetcher(host: str, port: int, **kw):
    """A ``broadcast_program``-compatible fetch hook over ``fetch_bytes``."""

    def fetch() -> bytes:
        return fetch_bytes(host, port, **kw)

    return fetch


def fetch_program(host: str, port: int, artifact, *, cache: bool = True,
                  **kw):
    """Fetch + MANDATORY fingerprint re-verification: the envelope goes
    through ``deserialize_program`` (artifact fingerprint, per-array hashes,
    recomputed program fingerprint) before the program may enter the local
    ``ProgramCache`` — transport checksums alone never admit a program."""
    from repro.core.program_io import deserialize_program

    blob = fetch_bytes(host, port, **kw)
    return deserialize_program(blob, artifact, cache=cache)
