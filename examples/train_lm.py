"""LM training driver: synthetic-token pretraining with checkpoints,
gradient compression, and fault-tolerant restart.

The paper's kind is deployment/inference, so the mandated e2e driver is
train_ttfs_mnist.py; this driver exercises the framework's *training*
substrate on the LM zoo. Default config is CPU-sized; --size 100m selects a
~100M-param model (12L x d768, GQA 12/4) for a few hundred steps on real
hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300
    # kill it mid-run, then re-run with the same args: it resumes.
"""

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.model import LM
from repro.training import lm_step, optim as O
from repro.training.checkpoint import CheckpointManager


def pick_config(size: str):
    base = get_config("qwen3-8b")
    if size == "tiny":
        return dataclasses.replace(reduced(base), name="lm-tiny")
    if size == "100m":
        return dataclasses.replace(
            base, name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab=32000, remat=False)
    raise ValueError(size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = pick_config(args.size)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0),
                            jnp.float32 if args.size == "tiny" else jnp.bfloat16)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    optimizer = O.get(cfg.optimizer, 3e-4)
    opt_state = lm_step.make_opt_state(params, optimizer, args.compress_grads)
    step_fn = jax.jit(lm_step.make_train_step(
        lm, optimizer, compress_grads=args.compress_grads))

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    if mgr.latest_step() is not None:
        start, restored = mgr.restore({"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start} (fault-tolerant path)")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % 10 == 0 or i == start:
            tok_s = args.batch * args.seq * (i + 1 - start) / (time.time() - t0)
            print(f"step {i + 1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {tok_s:,.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0:
            path = mgr.save(i + 1, {"params": params, "opt": opt_state},
                            meta={"loss": float(metrics["loss"])})
            print(f"  checkpoint -> {os.path.basename(path)}")
    print("done.")


if __name__ == "__main__":
    main()
