"""Neutral output/plan types shared by every runtime family.

This module is the dependency floor of the runtime stack: it may import
``core.ttfs`` (pure functions) and nothing else from the runtime families,
so reference / accelerator / board / serving can all consume the same
``SNNOutput`` contract and the same public ``decode_output`` without the
cross-module private imports that used to tie the accelerator to
``reference._decode``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import ttfs


class SNNOutput(NamedTuple):
    labels: jnp.ndarray        # (B,) int32
    first_spike: jnp.ndarray   # (B, N_out) int32 (logical neurons)
    v_final: jnp.ndarray       # (B, N_out) int32
    steps: jnp.ndarray         # (B,) int32 — timesteps consumed (T for full scan)


@dataclasses.dataclass(frozen=True)
class EncodePlan:
    """The lowered TTFS encode stage: everything the host packer needs."""

    T: int          # time window; also the never-spiked sentinel
    x_min: float    # encoder intensity threshold
    e_max: int      # calibrated event-buffer depth (FIFO depth analogue)
    n_in: int       # input neurons (admission-time shape contract)


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """The lowered grouped-TTFS readout stage (paper §2.3)."""

    n_groups: int   # class groups
    per_group: int  # neurons per group (n_groups * per_group == n_out)
    sentinel: int   # first-spike sentinel (== T)
    fallback: str   # "membrane" | "zero" no-spike policy


def decode_output(first_spike: jnp.ndarray, v_final: jnp.ndarray,
                  plan: DecodePlan) -> jnp.ndarray:
    """Public grouped readout: (…, n_out) first-spike/membrane -> labels."""
    return ttfs.decode_labels(
        first_spike, v_final,
        n_groups=plan.n_groups, per_group=plan.per_group,
        sentinel=plan.sentinel, fallback=plan.fallback)
