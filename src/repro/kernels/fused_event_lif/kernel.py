"""Fused event→LIF→decode megakernel — the whole event pipeline in one pass.

The staged TPU event path launches three kernels and round-trips the full
(T, N_pad) int32 currents tensor through HBM between them:

    event_accum  -> HBM currents -> lif_fused -> HBM first/v -> ttfs_decode

The FPGA does none of that: event routing, membrane update, and the TTFS
decision happen in ONE pass with all state resident on-chip. This kernel is
the TPU-native equivalent: grid ``(B, N_pad // bn)``, the packed event frames
stream through the fused T-loop, weight rows are gathered straight out of the
VMEM-resident synapse block (the BRAM analogue), the membrane updates and the
first-spike latch happen in registers, and the (T, N_pad) currents tensor is
NEVER materialized. Per grid step:

    ids block  (1, T, E_max)  int32  VMEM   event frames for one batch row
    count      (1, T)         int32  VMEM   active events per step (bounds
                                            the gather loop — work scales
                                            with ACTIVE events)
    w block    (N_in, bn)     int8   VMEM   synapse block, resident across T
    thr        (bn,)          int32  VMEM
    out        first (1, bn), v_final (1, bn) int32

Integer semantics are identical to ``core.lif_dynamics.lif_scan`` fed by
``event_accum``: integer addition is associative, so summing gathered rows
event-by-event inside the T-loop is bit-exact with the staged path.

Two additional variants complete the megakernel story:

* ``fused_event_lif_decode_kernel`` — single neuron block per batch row
  (bn = N_pad), appends the grouped-TTFS comparator tree so the kernel emits
  the LABEL directly (the paper's on-chip decision point).
* ``fused_event_lif_early_exit_kernel`` — latency mode: a while-loop T-loop
  that stops integrating at the first output spike, returning the step count
  (the paper's TTFS decision latency).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_step(ids_ref, count_ref, w_ref, t, bn):
    """Accumulate the weight rows of step ``t``'s active events: (bn,) int32."""
    n_ev = count_ref[0, t]

    def body(e, acc):
        nid = ids_ref[0, t, e]
        valid = nid >= 0
        safe = jnp.maximum(nid, 0)
        row = w_ref[pl.dslice(safe, 1), :]                     # (1, bn) int8
        return acc + jnp.where(valid, row.astype(jnp.int32)[0], 0)

    return jax.lax.fori_loop(0, n_ev, body, jnp.zeros((bn,), jnp.int32))


def _lif_update(v, first, i_t, thr, t, T, leak_shift):
    v = v - jnp.right_shift(v, leak_shift) + i_t
    fired = (v >= thr) & (first == T)
    first = jnp.where(fired, t, first)
    return v, first


def _fused_kernel(ids_ref, count_ref, w_ref, thr_ref, first_ref, v_ref, *,
                  T: int, leak_shift: int):
    bn = thr_ref.shape[0]
    thr = thr_ref[...]

    def step(t, carry):
        v, first = carry
        i_t = _gather_step(ids_ref, count_ref, w_ref, t, bn)
        return _lif_update(v, first, i_t, thr, t, T, leak_shift)

    v0 = jnp.zeros((bn,), jnp.int32)
    f0 = jnp.full((bn,), T, jnp.int32)
    v, first = jax.lax.fori_loop(0, T, step, (v0, f0))
    first_ref[0, :] = first
    v_ref[0, :] = v


def fused_event_lif_kernel(ids: jnp.ndarray, count: jnp.ndarray,
                           w: jnp.ndarray, thresholds: jnp.ndarray,
                           leak_shift: int, *, block_n: int = 128,
                           interpret: bool = True
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ids (B, T, E_max) int32 (PAD=-1), count (B, T) int32,
    w (N_in, N_pad) int8, thresholds (N_pad,) int32
    -> (first_spike (B, N_pad), v_final (B, N_pad)) int32."""
    B, T, E = ids.shape
    N_in, N = w.shape
    assert N % block_n == 0, f"N_pad {N} must be a multiple of {block_n}"
    grid = (B, N // block_n)
    kernel = functools.partial(_fused_kernel, T=T, leak_shift=leak_shift)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, E), lambda b, n: (b, 0, 0)),
            pl.BlockSpec((1, T), lambda b, n: (b, 0)),
            pl.BlockSpec((N_in, block_n), lambda b, n: (0, n)),
            pl.BlockSpec((block_n,), lambda b, n: (n,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, n: (b, n)),
            pl.BlockSpec((1, block_n), lambda b, n: (b, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B, N), jnp.int32),
        ],
        interpret=interpret,
    )(ids, count, w, thresholds)


# --------------------------------------------------------- decode-fused variant
def _decode_block(first, v, *, n_out: int, n_groups: int, per_group: int,
                  sentinel: int, fallback: str):
    """Grouped TTFS comparator tree over the logical lanes of one block."""
    f = first[:n_out]
    key = f * n_out + jax.lax.iota(jnp.int32, n_out)
    gkey = jnp.min(key.reshape(n_groups, per_group), axis=1)
    ttfs_label = jnp.argmin(gkey).astype(jnp.int32)
    any_spike = jnp.min(f) < sentinel
    if fallback == "membrane":
        gv = jnp.max(v[:n_out].reshape(n_groups, per_group), axis=1)
        fb_label = jnp.argmax(gv).astype(jnp.int32)
    else:
        fb_label = jnp.int32(0)
    return jnp.where(any_spike, ttfs_label, fb_label)


def _fused_decode_kernel(ids_ref, count_ref, w_ref, thr_ref,
                         first_ref, v_ref, label_ref, *,
                         T: int, leak_shift: int, n_out: int, n_groups: int,
                         per_group: int, fallback: str):
    bn = thr_ref.shape[0]
    thr = thr_ref[...]

    def step(t, carry):
        v, first = carry
        i_t = _gather_step(ids_ref, count_ref, w_ref, t, bn)
        return _lif_update(v, first, i_t, thr, t, T, leak_shift)

    v0 = jnp.zeros((bn,), jnp.int32)
    f0 = jnp.full((bn,), T, jnp.int32)
    v, first = jax.lax.fori_loop(0, T, step, (v0, f0))
    first_ref[0, :] = first
    v_ref[0, :] = v
    label_ref[0] = _decode_block(first, v, n_out=n_out, n_groups=n_groups,
                                 per_group=per_group, sentinel=T,
                                 fallback=fallback)


def fused_event_lif_decode_kernel(ids: jnp.ndarray, count: jnp.ndarray,
                                  w: jnp.ndarray, thresholds: jnp.ndarray,
                                  leak_shift: int, *, n_out: int,
                                  n_groups: int, per_group: int,
                                  fallback: str = "membrane",
                                  interpret: bool = True
                                  ) -> tuple[jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray]:
    """Single-block megakernel: the whole padded network (bn = N_pad) per
    batch row, grouped TTFS decode fused after the T-loop. Emits
    (first_spike (B, N_pad), v_final (B, N_pad), labels (B,))."""
    B, T, E = ids.shape
    N_in, N = w.shape
    assert n_out <= N and n_out == n_groups * per_group
    kernel = functools.partial(
        _fused_decode_kernel, T=T, leak_shift=leak_shift, n_out=n_out,
        n_groups=n_groups, per_group=per_group, fallback=fallback)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, E), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, T), lambda b: (b, 0)),
            pl.BlockSpec((N_in, N), lambda b: (0, 0)),
            pl.BlockSpec((N,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(ids, count, w, thresholds)


# ----------------------------------------------------------- early-exit variant
def _fused_early_exit_kernel(ids_ref, count_ref, w_ref, thr_ref,
                             first_ref, v_ref, steps_ref, *,
                             T: int, leak_shift: int):
    """Latency mode: stop integrating once ANY neuron fired (TTFS decision
    point). Single neuron block per row so the exit condition is global —
    semantics identical to ``core.lif_dynamics.lif_scan_early_exit``."""
    bn = thr_ref.shape[0]
    thr = thr_ref[...]

    def cond(state):
        t, v, first = state
        return (t < T) & jnp.all(first == T)

    def body(state):
        t, v, first = state
        i_t = _gather_step(ids_ref, count_ref, w_ref, t, bn)
        v, first = _lif_update(v, first, i_t, thr, t, T, leak_shift)
        return (t + 1, v, first)

    t0 = jnp.int32(0)
    v0 = jnp.zeros((bn,), jnp.int32)
    f0 = jnp.full((bn,), T, jnp.int32)
    t, v, first = jax.lax.while_loop(cond, body, (t0, v0, f0))
    first_ref[0, :] = first
    v_ref[0, :] = v
    steps_ref[0] = t


def fused_event_lif_early_exit_kernel(ids: jnp.ndarray, count: jnp.ndarray,
                                      w: jnp.ndarray, thresholds: jnp.ndarray,
                                      leak_shift: int, *,
                                      interpret: bool = True
                                      ) -> tuple[jnp.ndarray, jnp.ndarray,
                                                 jnp.ndarray]:
    """ids (B, T, E_max), count (B, T) -> (first (B, N_pad), v_final
    (B, N_pad), steps (B,)). ``v_final`` is the membrane AT EXIT TIME, same
    contract as ``lif_scan_early_exit``."""
    B, T, E = ids.shape
    N_in, N = w.shape
    kernel = functools.partial(_fused_early_exit_kernel, T=T,
                               leak_shift=leak_shift)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, E), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, T), lambda b: (b, 0)),
            pl.BlockSpec((N_in, N), lambda b: (0, 0)),
            pl.BlockSpec((N,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(ids, count, w, thresholds)
