"""Production serving launcher: --arch <id>, batched request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 16 --max-new 12
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced as make_reduced
from repro.models.model import LM
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(lm, params, max_batch=args.max_batch, s_max=256)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, rng.randint(4, 16)).astype(np.int32)
               for _ in range(args.requests)]
    outs = engine.generate(prompts, max_new=args.max_new)
    st = engine.stats()
    print(f"served {len(outs)} requests; "
          f"accelerator {st['accelerator_s']:.2f}s / "
          f"system {st['system_s']:.2f}s")


if __name__ == "__main__":
    main()
