"""Qwen2.5-32B [hf:Qwen/Qwen2.5 family; hf]: 64L, d5120, 40H GQA(kv=8),
d_ff 27648, vocab 152064, QKV bias. 40 heads do NOT divide the 16-way model
axis — the sharding resolver falls back to head-dim sharding (DESIGN.md §5)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, vocab=152064,
    n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=27648, qkv_bias=True, rope_theta=1e6,
)
