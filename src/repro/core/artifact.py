"""The single deployment artifact — the paper's central abstraction.

One exported object carries weights, thresholds, connectivity descriptors and
grouped TTFS decoding metadata, and is consumed UNCHANGED by both the software
reference runner and the accelerator runtime. There is no board-specific
conversion stage that could silently change semantics.

Implementation: one ``.npz`` file holding the arrays plus a ``__meta__`` JSON
blob. The meta carries a manifest of per-array SHA-256 hashes and a whole-
artifact fingerprint; ``load`` verifies integrity so a corrupted or tampered
artifact fails loudly instead of silently flipping predictions.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
from typing import Any, Mapping

import numpy as np

FORMAT_VERSION = 2


def array_hash(a: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class Artifact:
    meta: dict[str, Any]
    arrays: dict[str, np.ndarray]

    # ------------------------------------------------------------------ io
    def fingerprint(self) -> str:
        h = hashlib.sha256()
        for name in sorted(self.arrays):
            h.update(name.encode())
            h.update(array_hash(self.arrays[name]).encode())
        h.update(json.dumps(_strip_volatile(self.meta), sort_keys=True).encode())
        return h.hexdigest()

    def save(self, path: str) -> str:
        meta = dict(self.meta)
        meta["format_version"] = FORMAT_VERSION
        meta["manifest"] = {k: array_hash(v) for k, v in self.arrays.items()}
        self.meta = meta
        meta["fingerprint"] = self.fingerprint()
        buf = io.BytesIO()
        np.savez(buf, __meta__=np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8),
            **self.arrays)
        with open(path, "wb") as f:
            f.write(buf.getvalue())
        return meta["fingerprint"]

    @classmethod
    def load(cls, path: str, verify: bool = True) -> "Artifact":
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            arrays = {k: z[k] for k in z.files if k != "__meta__"}
        art = cls(meta, arrays)
        if verify:
            art.verify()
        return art

    def verify(self) -> None:
        manifest = self.meta.get("manifest", {})
        missing = sorted(set(self.arrays) - set(manifest))
        orphaned = sorted(set(manifest) - set(self.arrays))
        if missing or orphaned:
            parts = []
            if missing:
                parts.append(f"arrays missing from manifest: {missing}")
            if orphaned:
                parts.append(f"manifest entries with no array: {orphaned}")
            raise IntegrityError("; ".join(parts))
        bad = [name for name, digest in manifest.items()
               if array_hash(self.arrays[name]) != digest]
        if bad:
            raise IntegrityError(
                f"array content hash mismatch for {bad} — the array bytes or "
                f"their manifest entry were modified after export")
        fp = self.meta.get("fingerprint")
        if fp is not None and fp != self.fingerprint():
            raise IntegrityError(
                "artifact fingerprint mismatch — the __meta__ blob (outside "
                "the per-array manifest) was modified after export")

    # -------------------------------------------------------- conveniences
    def __getitem__(self, name: str) -> np.ndarray:
        return self.arrays[name]

    def m(self, *path: str, default=None):
        """meta lookup: art.m('readout', 'n_groups')"""
        cur: Any = self.meta
        for p in path:
            if not isinstance(cur, Mapping) or p not in cur:
                return default
            cur = cur[p]
        return cur


class IntegrityError(RuntimeError):
    pass


def _strip_volatile(meta: dict) -> dict:
    return {k: v for k, v in meta.items() if k not in ("fingerprint", "manifest")}
