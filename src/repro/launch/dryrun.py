import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import: jax locks the device count on first init.
# This is the ONLY module that forces 512 placeholder devices (dry-run only).

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step with optimizer
update / prefill forward / serve_step decode), abstract ShapeDtypeStruct
inputs, and full in_shardings from the resolver; compiles the SPMD program
for the production mesh; prints memory_analysis() (proves it fits) and
cost_analysis() (feeds §Roofline); parses post-optimization HLO for
collective bytes; and writes one JSON per cell under results/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # sweep every runnable cell
"""

import argparse
import gzip
import json
import time
import traceback

import jax

from repro.configs import shapes as shp
from repro.configs.registry import ALIASES, get_config
from repro.distributed import roofline as RL
from repro.distributed import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.training import lm_step, optim as O


# §Perf iteration variants: config/sharding deltas applied on top of an
# arch config. Measured against baseline via the archived HLO + roofline.
VARIANTS = {
    "baseline": {},
    "remat_dots": {"cfg": {"remat_policy": "dots"}},
    "remat_none": {"cfg": {"remat": False}},
    "kv_seqshard": {"kv_seq_shard": True},
    "tp_only": {"fsdp": False},
    "tp_remat_dots": {"fsdp": False, "cfg": {"remat_policy": "dots"}},
    "tp_kvseq": {"fsdp": False, "kv_seq_shard": True},
    "wgather": {"cfg": {"fsdp_weight_gather": True}},
    "stack_fsdp": {"fsdp_mode": "stack"},
    "stack_wgather": {"fsdp_mode": "stack",
                      "cfg": {"fsdp_weight_gather": True}},
    "stack_wg_dots": {"fsdp_mode": "stack",
                      "cfg": {"fsdp_weight_gather": True,
                              "remat_policy": "dots"}},
    "noconstr": {"cfg": {"activation_constraints": False}},
    "tp_noconstr": {"fsdp": False,
                    "cfg": {"activation_constraints": False}},
    "tp_nc_dots": {"fsdp": False,
                   "cfg": {"activation_constraints": False,
                           "remat_policy": "dots"}},
    "tp_nc_kvseq": {"fsdp": False, "kv_seq_shard": True,
                    "cfg": {"activation_constraints": False}},
    "moe_local": {"cfg": {"moe_buf_mode": "local"}},
    "moe_local_nc": {"cfg": {"moe_buf_mode": "local",
                             "activation_constraints": False}},
    "gqa_repeat": {"cfg": {"attn_gqa_mode": "repeat"}},
    "gqa_dots": {"cfg": {"attn_gqa_mode": "repeat", "remat_policy": "dots"}},
    "gqa_kvseq": {"kv_seq_shard": True,
                  "cfg": {"attn_gqa_mode": "repeat"}},
    "opt_moe": {"cfg": {"attn_gqa_mode": "repeat", "moe_buf_mode": "local"}},
    # beyond-paper sharding scheme: same 256 chips, re-meshed 64x4 so the
    # Megatron AR payload (B_local*S*d) shrinks 4x and DP grows; params must
    # fit at TP=4 (planner-checked). "a different sharding scheme" per §Perf.
    "mesh_tp4": {"mesh_shape": (64, 4), "fsdp": False,
                 "cfg": {"attn_gqa_mode": "repeat"}},
    "mesh_tp4_fsdp": {"mesh_shape": (64, 4),
                      "cfg": {"attn_gqa_mode": "repeat"}},
    "opt_decode": {"kv_seq_shard": True, "fsdp": False,
                   "cfg": {"attn_gqa_mode": "repeat"}},
    # mesh_tp4 + ZeRO-1: optimizer state sharded over data (m/v live once
    # across the fleet); params stay TP-only. Fixes tp4's HBM overshoot for
    # the price of one grad reduce-scatter + param all-gather per step.
    "mesh_tp4_z1": {"mesh_shape": (64, 4), "fsdp": False, "opt_fsdp": True,
                    "cfg": {"attn_gqa_mode": "repeat"}},
    "mesh_tp4_z1_dots": {"mesh_shape": (64, 4), "fsdp": False,
                         "opt_fsdp": True,
                         "cfg": {"attn_gqa_mode": "repeat",
                                 "remat_policy": "dots"}},
    "mesh_tp2_z1": {"mesh_shape": (128, 2), "fsdp": False, "opt_fsdp": True,
                    "cfg": {"attn_gqa_mode": "repeat"}},
    "moe_shmap": {"cfg": {"moe_buf_mode": "shard_map",
                          "attn_gqa_mode": "repeat"}},
}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline"):
    import dataclasses
    var = VARIANTS[variant]
    cfg = get_config(arch)
    if var.get("cfg"):
        cfg = dataclasses.replace(cfg, **var["cfg"])
    cell = shp.SHAPES[shape_name]
    if var.get("mesh_shape"):
        from repro.launch.mesh import build_mesh
        shape = var["mesh_shape"]
        if multi_pod:
            shape = (2,) + shape
            mesh = build_mesh(shape, ("pod", "data", "model"))
        else:
            mesh = build_mesh(shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    constrain = SH.make_constrainer(mesh)
    lm = LM(cfg, constrain=constrain)
    pspec = lm.param_specs()
    fsdp = var.get("fsdp", True)
    fsdp_mode = var.get("fsdp_mode", "hidden")
    p_sh = SH.to_shardings(mesh, SH.param_pspecs(mesh, pspec, fsdp=fsdp,
                                                 fsdp_mode=fsdp_mode))

    if cell.kind == "train":
        optimizer = O.get(cfg.optimizer, 3e-4)
        opt_spec = jax.eval_shape(optimizer.init, pspec)
        o_fsdp = var.get("opt_fsdp", fsdp)   # ZeRO-1: shard opt state only
        o_sh = SH.to_shardings(mesh, SH.param_pspecs(
            mesh, opt_spec, fsdp=o_fsdp, fsdp_mode=fsdp_mode))
        batch_spec = SP.train_batch_specs(cfg, shape_name)
        b_sh = SH.to_shardings(mesh, SH.batch_pspec(mesh, batch_spec))
        step = lm_step.make_train_step(lm, optimizer)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
        args = (pspec, opt_spec, batch_spec)
    elif cell.kind == "prefill":
        batch_spec = SP.prefill_specs(cfg, shape_name)
        b_sh = SH.to_shardings(mesh, SH.batch_pspec(mesh, batch_spec))
        fwd = lm_step.make_prefill_step(lm)

        def fn_impl(params, batch):
            return fwd(params, **batch)
        fn = jax.jit(fn_impl, in_shardings=(p_sh, b_sh))
        args = (pspec, batch_spec)
    else:  # decode
        dec = SP.decode_specs(cfg, shape_name, lm)
        c_sh = SH.to_shardings(mesh, SH.cache_pspecs(
            mesh, dec["cache"], seq_shard=var.get("kv_seq_shard", False)))
        t_sh = SH.to_shardings(mesh, SH.batch_pspec(mesh, dec["tokens"]))
        step = lm_step.make_serve_step(lm)
        fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                     donate_argnums=(1,))
        args = (pspec, dec["cache"], dec["tokens"])
    return cfg, cell, mesh, fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun",
             variant: str = "baseline") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg = get_config(arch)
    runs, why = shp.applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant}
    if not runs:
        rec.update(status="skipped", reason=why)
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    t0 = time.perf_counter()
    cfg, cell, mesh, fn, args = build_cell(arch, shape_name, multi_pod,
                                           variant)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax < 0.5 returns [dict]
            cost = cost[0]
        print(mem)     # proves it fits
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
        hlo = compiled.as_text()
    chips = int(mesh.size)
    rl = RL.analyze(arch=arch, shape=shape_name, mesh_name=mesh_name,
                    chips=chips, cost=cost, hlo_text=hlo, cfg=cfg, cell=cell)
    rec.update(
        status="ok", chips=chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        flops_per_chip=rl.flops_per_chip, bytes_per_chip=rl.bytes_per_chip,
        raw_hlo_flops=rl.raw_hlo_flops, raw_hlo_bytes=rl.raw_hlo_bytes,
        coll_bytes=rl.coll_bytes, coll_by_kind=rl.coll_by_kind,
        model_flops=rl.model_flops, compute_s=rl.compute_s,
        memory_s=rl.memory_s, collective_s=rl.collective_s,
        bottleneck=rl.bottleneck, useful_ratio=rl.useful_ratio,
        step_s=rl.step_s, mfu=rl.mfu,
        memory_analysis={
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")},
    )
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}"
    if variant != "baseline":
        stem += f"__{variant}"
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    # archive the post-optimization HLO so perf iterations can re-analyze
    # collective schedules without recompiling
    hlo_dir = os.path.join(out_dir, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    with gzip.open(os.path.join(hlo_dir, stem + ".txt.gz"), "wt") as f:
        f.write(hlo)
    print(rl.row())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (assignment name)")
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose result JSON already exists")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.all:
        ok = failed = skipped = 0
        for arch in ALIASES:
            for shape_name in shp.SHAPES:
                for mesh_name in ("single", "multi"):
                    fname = os.path.join(
                        args.out, f"{arch.replace('.', '_')}__{shape_name}"
                        f"__{mesh_name}.json")
                    if args.resume and os.path.exists(fname):
                        ok += 1
                        continue
                    try:
                        rec = run_cell(arch, shape_name, mesh_name == "multi",
                                       args.out)
                        if rec["status"] == "ok":
                            ok += 1
                        else:
                            skipped += 1
                    except Exception:
                        failed += 1
                        traceback.print_exc()
        print(f"dry-run sweep: ok={ok} skipped={skipped} failed={failed}")
        raise SystemExit(1 if failed else 0)

    rec = run_cell(args.arch, args.shape, args.mesh == "multi", args.out,
                   variant=args.variant)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("coll_by_kind", "memory_analysis")},
                     indent=1, default=float))


if __name__ == "__main__":
    main()
