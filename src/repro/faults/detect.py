"""Fault detectors — how the serving tier notices something went wrong.

Each detector is matched to a fault class (the table in the README's
"Failure modes & resilience" section) and NONE of them peek at the injected
plan — they work from invariants the clean system already guarantees:

  checksum — the deployment artifact carries a per-array SHA-256 manifest;
             ``integrity_errors`` re-hashes the runtime's in-memory copy
             against it. Catches any static SEU in the weight / threshold
             blocks, at lane startup and per batch in paranoid mode.
  canary   — pinned probe images with known reference labels, one crafted
             per readout group (plus any user-supplied pool), re-classified
             through the lane's OWN serve path. Catches stuck-at groups and
             any corruption gross enough to move a known answer.
  trace    — the board runtime records the per-tick AER dispatch histogram;
             ``trace_errors`` recomputes the expected histogram from the
             TTFS encoder and re-evaluates the ``BoardCostModel`` account
             from it. Catches AER drop/duplicate/cross-tick displacement
             and any cycle/energy-account anomaly.
  ecc      — the membrane-BRAM parity model (``MembraneUpsetInjector``)
             surfaces per-image hit counts on the runtime
             (``last_ecc``); ``ecc_errors`` reads them. Catches transient
             membrane SEUs the instant they land, as parity does on-board.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.artifact import Artifact
from repro.telemetry import trace as ttrace


def _traced(kind: str):
    """Wrap a detector so each firing is a ``detect.<kind>`` system-scope
    span carrying the error count — a no-op until a Tracer is installed."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            rec = ttrace.get()
            if not rec.enabled:
                return fn(*args, **kw)
            sp = rec.begin(f"detect.{kind}", "system")
            errs = fn(*args, **kw)
            rec.end(sp, attrs={"errors": len(errs)})
            return errs
        return wrapper
    return deco


@_traced("checksum")
def integrity_errors(art: Artifact | None) -> list[str]:
    """Re-hash an artifact's arrays against its manifest. Empty list means
    intact; ``None`` (a runtime that exposes no artifact) or an in-memory
    artifact that was never exported (no manifest to check against) is
    vacuously OK. Only the ARRAY bytes are checked — that is what BRAM SEUs
    can hit; meta overrides (e.g. a host-side e_max change) are legitimate
    configuration, which is why this does not use the stricter full-
    fingerprint ``Artifact.verify``."""
    if art is None or not art.meta.get("manifest"):
        return []
    from repro.core.artifact import array_hash
    manifest = art.meta["manifest"]
    bad = [name for name, digest in manifest.items()
           if name in art.arrays
           and array_hash(art.arrays[name]) != digest]
    missing = sorted(set(manifest) - set(art.arrays))
    errs = []
    if bad:
        errs.append(f"artifact integrity: array content hash mismatch for "
                    f"{sorted(bad)} — memory corrupted after export")
    if missing:
        errs.append(f"artifact integrity: manifest entries with no array: "
                    f"{missing}")
    return errs


def runtime_integrity_errors(runtime) -> list[str]:
    """Checksum detector applied to a constructed runtime's in-memory
    artifact copy (every runtime family keeps ``.art``)."""
    return integrity_errors(getattr(runtime, "art", None))


# --------------------------------------------------------------------- canary
@dataclasses.dataclass
class Canary:
    """Golden probe set: images whose reference labels are pinned at build
    time. ``mismatches(got)`` is the detector; coverage records which
    readout groups own at least one in-group probe (a stuck-at fault in a
    covered group is guaranteed to move that probe's label)."""

    images: np.ndarray        # (P, n_in) float32
    want: np.ndarray          # (P,) int32 reference labels
    covered_groups: tuple[int, ...]
    n_groups: int

    @property
    def covers_all_groups(self) -> bool:
        return len(self.covered_groups) == self.n_groups

    @_traced("canary")
    def mismatches(self, got_labels) -> list[str]:
        got = np.asarray(got_labels)[: len(self.want)]
        bad = np.nonzero(got != self.want)[0]
        return [f"canary probe {int(i)}: served label {int(got[i])} != "
                f"pinned reference label {int(self.want[i])}" for i in bad]

    @classmethod
    def from_program(cls, program,
                     pool: np.ndarray | None = None) -> "Canary":
        """Build the probe set from a lowered program: candidate images are
        the ``pool`` (held-out real samples — preferred) plus one crafted
        probe per readout group (the group's positive float-weight mass, the
        input that drives it hardest). Reference labels are evaluated once on
        ``SNNReference``; one probe is kept per distinct label. A saturated
        stuck-at group is guaranteed to move at least one probe's label
        whenever the set spans two or more labels."""
        from repro.core.reference import SNNReference
        n_groups = program.n_groups
        per_group = program.per_group
        x_min = program.x_min
        w = np.asarray(program.artifact["w_float"], np.float64)
        crafted = []
        for g in range(n_groups):
            drive = np.clip(w[:, g * per_group:(g + 1) * per_group],
                            0.0, None).sum(axis=1)
            peak = float(drive.max())
            x = drive / peak if peak > 0 else np.zeros_like(drive)
            # keep strong pixels comfortably above the encoder's threshold
            crafted.append(np.where(x >= x_min, x, 0.0).astype(np.float32))
        cands = np.stack(crafted)
        if pool is not None:
            cands = np.concatenate([np.asarray(pool, np.float32)[:256],
                                    cands])
        ref = SNNReference(program)
        want = np.asarray(ref.forward(cands).labels, np.int32)
        keep: dict[int, int] = {}
        for i, lab in enumerate(want):
            keep.setdefault(int(lab), i)
        idx = sorted(keep.values())
        return cls(images=cands[idx], want=want[idx],
                   covered_groups=tuple(sorted(keep)), n_groups=n_groups)

    @classmethod
    def from_artifact(cls, art: Artifact,
                      pool: np.ndarray | None = None) -> "Canary":
        from repro.core.lowering import lower
        return cls.from_program(lower(art), pool=pool)


# ---------------------------------------------------------------------- trace
@_traced("trace")
def trace_errors(runtime, images: np.ndarray) -> list[str]:
    """Board-trace cross-check: re-encode the served images, rebuild the
    expected per-tick AER dispatch histogram and the full
    ``BoardCostModel`` account from it, and compare against what the
    runtime actually dispatched (``last_tick_counts``) and charged
    (``last_trace``). Only meaningful for full-window board runtimes —
    returns [] for runtimes that expose no tick histogram or run
    latency-mode early exit."""
    actual = getattr(runtime, "last_tick_counts", None)
    trace = getattr(runtime, "last_trace", None)
    if actual is None or trace is None or getattr(runtime, "latency_mode",
                                                  False):
        return []
    import jax.numpy as jnp

    from repro.board.energy import account
    from repro.core import ttfs
    from repro.core.events import step_counts

    T = int(runtime.T)
    times = np.asarray(ttfs.encode_ttfs(
        jnp.asarray(np.atleast_2d(images), jnp.float32), T, runtime.x_min))
    expect = step_counts(times, T)[:, :T].astype(np.int64)
    errs: list[str] = []
    actual = np.asarray(actual, np.int64)
    if actual.shape != expect.shape:
        return [f"trace: tick-histogram shape {actual.shape} != expected "
                f"{expect.shape}"]
    bad = np.nonzero(np.any(actual != expect, axis=1))[0]
    if bad.size:
        i = int(bad[0])
        errs.append(
            f"trace: AER tick histogram diverges on {bad.size} images "
            f"(image {i}: dispatched {int(actual[i].sum())} events vs "
            f"{int(expect[i].sum())} scheduled — drop/duplicate/displace)")
    depth = int(runtime.depth)
    stalls = np.maximum(expect - depth, 0).sum(axis=1)
    want_tr = account(expect.sum(axis=1), np.full(len(expect), T, np.int64),
                      stalls, runtime.n_pad, runtime.cost)
    for f in dataclasses.fields(want_tr):
        a = np.asarray(getattr(want_tr, f.name))
        b = np.asarray(getattr(trace, f.name))
        if a.shape == b.shape and not np.array_equal(a, b):
            errs.append(f"trace: cost-model account anomaly in {f.name} "
                        f"(expected {a.tolist()[:4]}…, charged "
                        f"{b.tolist()[:4]}…)")
            break
    return errs


# ------------------------------------------------------------------------ ecc
@_traced("ecc")
def ecc_errors(runtime) -> list[str]:
    """Membrane-parity detector readout: nonzero per-image ECC hit counts
    from the last forward mean membrane words were upset mid-inference."""
    ecc = getattr(runtime, "last_ecc", None)
    if ecc is None:
        return []
    ecc = np.asarray(ecc)
    rows = np.nonzero(ecc > 0)[0]
    if not rows.size:
        return []
    return [f"ecc: membrane parity hits on {rows.size} images "
            f"(rows {rows.tolist()[:8]}, {int(ecc.sum())} upsets)"]
