"""Runtime registry — one place that maps a spec string to a runner.

Every runtime consumes the SAME deployment artifact and exposes
``forward(images) -> SNNOutput``; the registry replaces the if/elif chains
that used to live in the agreement harness and the serving engine with a
declarative table, so adding a runtime (the board emulator is the third) is
one ``@register`` away.

Spec grammar: ``family[-option[-option]]``:

    reference                      software reference (the oracle)
    accelerator-batch[-pallas]     time-batched MXU path
    accelerator-event[-jnp|pallas|fused]
                                   packed-event path (kernel picked via the
                                   suffix or the ``kernel=`` keyword)
    board[-batched]                board emulator, vectorized fast path
    board-py                       board emulator, per-image Python scheduler

Factories ignore keywords they don't understand so harness-level defaults
(e.g. ``kernel=``) can be passed uniformly across families.
"""

from __future__ import annotations

from typing import Callable

from repro.core.artifact import Artifact

_REGISTRY: dict[str, Callable] = {}


def register(family: str):
    def deco(factory: Callable) -> Callable:
        _REGISTRY[family] = factory
        return factory
    return deco


def available() -> list[str]:
    return sorted(_REGISTRY)


def make_runtime(artifact: Artifact, spec: str, **kw):
    """Build the runtime named by ``spec`` over ``artifact``."""
    family, _, opts = spec.partition("-")
    if family not in _REGISTRY:
        raise ValueError(f"unknown runtime family {family!r} in spec "
                         f"{spec!r}; available: {available()}")
    return _REGISTRY[family](artifact, opts, **kw)


@register("reference")
def _reference(art: Artifact, opts: str, **_):
    from repro.core.reference import SNNReference
    if opts:
        raise ValueError(f"reference runtime takes no options, got {opts!r}")
    return SNNReference(art)


@register("accelerator")
def _accelerator(art: Artifact, opts: str, kernel: str = "jnp", **_):
    from repro.core.accelerator import SNNAccelerator
    mode, _, k = opts.partition("-")
    return SNNAccelerator(art, mode=mode or "batch", kernel=k or kernel)


@register("board")
def _board(art: Artifact, opts: str, latency_mode: bool = False,
           kernel: str = "jnp", **_):
    from repro.board import SNNBoard, SNNBoardBatched
    if opts in ("", "batched"):
        # forwarded, not swallowed: the batched path understands jnp/pallas
        # and rejects kernels it doesn't (e.g. the accelerator-only "fused")
        return SNNBoardBatched(art, latency_mode=latency_mode, kernel=kernel)
    if opts == "py":
        return SNNBoard(art, latency_mode=latency_mode)  # plain python path
    raise ValueError(f"unknown board option {opts!r} (use '', 'batched', 'py')")
