"""Cycle and dynamic-energy accounting for the board emulator.

One ``account`` function shared verbatim by the per-image Python scheduler
and the vectorized batched fast path: the same expression evaluated on
python ints or on (B,) numpy arrays, so the two paths cannot drift apart
(their trace equality is asserted by tests and the bench ``--check`` gate).

The model terms and their microarchitectural justification live on
``hw.BoardCostModel`` (next to the paper's FPGA reference constants);
this module only does the bookkeeping:

    cycles = fixed + events*c_event + ticks*c_tick + stalls*c_stall + decode
    nJ     = (events*pj_event + events*n_pad*pj_synop
              + ticks*n_pad*pj_neuron_tick + pj_decode) / 1000
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hw import BoardCostModel, PYNQ_COST


@dataclasses.dataclass
class BoardTrace:
    """Per-image datapath account. Fields are (B,) arrays (batched) or the
    same expressions evaluated per image and stacked — identical either way."""

    ticks: np.ndarray        # ticks executed (T, or first-spike tick + 1)
    events: np.ndarray       # AER events dispatched within the executed window
    stalls: np.ndarray       # FIFO backpressure events (depth exceeded)
    synops: np.ndarray       # int8 synaptic accumulates (events * n_pad)
    cycles: np.ndarray       # total PL cycles
    energy_nj: np.ndarray    # dynamic energy estimate

    def us(self, clock_hz: float = PYNQ_COST.clock_hz) -> np.ndarray:
        return self.cycles / clock_hz * 1e6

    def summary(self, clock_hz: float = PYNQ_COST.clock_hz) -> str:
        return (f"cycles/img {float(np.mean(self.cycles)):.1f}  "
                f"({float(np.mean(self.us(clock_hz))):.4f} us @ "
                f"{clock_hz / 1e6:.0f} MHz)  "
                f"nJ/img {float(np.mean(self.energy_nj)):.1f}  "
                f"events/img {float(np.mean(self.events)):.1f}  "
                f"ticks/img {float(np.mean(self.ticks)):.1f}")


def account(events, ticks, stalls, n_pad: int,
            cost: BoardCostModel = PYNQ_COST) -> BoardTrace:
    """Evaluate the cost model. ``events``/``ticks``/``stalls`` may be python
    ints (one image) or int64 arrays (a batch); n_pad is the populated lane
    count (synapse row width — padded lanes still clock, as on the board)."""
    events = np.asarray(events, np.int64)
    ticks = np.asarray(ticks, np.int64)
    stalls = np.asarray(stalls, np.int64)
    synops = events * n_pad
    cycles = (cost.cycles_fixed
              + events * cost.cycles_per_event
              + ticks * cost.cycles_per_tick
              + stalls * cost.cycles_per_stall
              + cost.cycles_decode)
    energy_nj = (events * cost.pj_per_event
                 + synops * cost.pj_per_synop
                 + ticks * (n_pad * cost.pj_per_neuron_tick)
                 + cost.pj_per_decode) * 1e-3
    return BoardTrace(ticks=ticks, events=events, stalls=stalls,
                      synops=synops, cycles=cycles,
                      energy_nj=np.asarray(energy_nj, np.float64))


def span_attrs(trace: BoardTrace) -> tuple[dict, list[dict]]:
    """Project a (B,)-array trace into telemetry span attributes: the
    ``board.run`` totals and one ``board.image`` attr dict per image. All
    values are logical clocks (cost-model integers + the derived energy
    float), so the spans are deterministic for a seeded run — the per-image
    scheduler and the batched fast path produce bit-identical attrs because
    their traces are bit-identical (the conformance suite's guarantee)."""
    ticks = np.atleast_1d(np.asarray(trace.ticks, np.int64))
    events = np.atleast_1d(np.asarray(trace.events, np.int64))
    stalls = np.atleast_1d(np.asarray(trace.stalls, np.int64))
    synops = np.atleast_1d(np.asarray(trace.synops, np.int64))
    cycles = np.atleast_1d(np.asarray(trace.cycles, np.int64))
    energy = np.atleast_1d(np.asarray(trace.energy_nj, np.float64))
    totals = {"events": int(events.sum()), "ticks": int(ticks.sum()),
              "stalls": int(stalls.sum()), "synops": int(synops.sum()),
              "cycles": int(cycles.sum()), "energy_nj": float(energy.sum())}
    per = [{"i": i, "events": int(events[i]), "ticks": int(ticks[i]),
            "stalls": int(stalls[i]), "synops": int(synops[i]),
            "cycles": int(cycles[i]), "energy_nj": float(energy[i])}
           for i in range(len(cycles))]
    return totals, per


def stack_traces(traces: list[BoardTrace]) -> BoardTrace:
    """Stack per-image scalar traces into one (B,)-array trace."""
    return BoardTrace(*(np.stack([np.asarray(getattr(tr, f.name))
                                  for tr in traces])
                        for f in dataclasses.fields(BoardTrace)))
