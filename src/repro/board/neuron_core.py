"""Grouped neuron core — the PL microarchitecture's state and update rules.

16 hardware groups x 128 neurons (the paper's direct-addressing limit), each
holding int8 synapse rows and int32 membranes. The artifact's padded layout
(``w_padded``/``thr_padded``, lane-padded by the co-design planner) maps onto
the first ``n_pad / lane`` groups; padded lanes carry a never-fire threshold
so they are architecturally present but electrically dead.

Update rules are the repo-wide integer LIF contract
(``core.lif_dynamics``), evaluated here event-by-event:

    dispatch(event nid):  acc[g, :] += w[nid, g, :]          (all groups, int32)
    tick(t):              v <- v - (v >> leak_shift) + acc
                          fired = (v >= thr) & (first == T); latch first <- t

Integer addition is associative, so per-event accumulation is bit-exact with
the reference's dense per-tick matmul row sum.
"""

from __future__ import annotations

import numpy as np

from repro.core.artifact import Artifact
from repro.core.hw import BoardCostModel, PYNQ_COST


class GroupedNeuronCore:
    def __init__(self, w_padded: np.ndarray, thr_padded: np.ndarray,
                 leak_shift: int, T: int, cost: BoardCostModel = PYNQ_COST):
        n_in, n_pad = w_padded.shape
        if n_pad % cost.lane:
            raise ValueError(f"n_pad {n_pad} is not a multiple of the "
                             f"hardware lane width {cost.lane}")
        self.groups_used = n_pad // cost.lane
        if self.groups_used > cost.groups:
            raise ValueError(
                f"network needs {self.groups_used} hardware groups but the "
                f"board has {cost.groups} ({cost.neurons_direct} directly "
                f"addressable neurons — the paper's packing limit)")
        self.lane = cost.lane
        self.n_pad = n_pad
        self.T = int(T)
        self.leak_shift = int(leak_shift)
        # (N_in, G, lane): one row fetch serves every group in parallel
        self.w = np.ascontiguousarray(
            w_padded.reshape(n_in, self.groups_used, cost.lane)).astype(np.int8)
        self.thr = thr_padded.reshape(self.groups_used, cost.lane).astype(np.int32)
        self.reset()

    @classmethod
    def from_program(cls, program,
                     cost: BoardCostModel = PYNQ_COST) -> "GroupedNeuronCore":
        """Build from a lowered program (``core.lowering.LoweredProgram``).
        Uses the artifact's host arrays — the core owns mutable int32/int8
        copies (``.astype`` below), so fault models may write ``core.thr``
        without touching the shared program arrays."""
        art = program.artifact
        return cls(np.asarray(art["w_padded"]), np.asarray(art["thr_padded"]),
                   program.leak_shift, program.T, cost)

    @classmethod
    def from_artifact(cls, art: Artifact,
                      cost: BoardCostModel = PYNQ_COST) -> "GroupedNeuronCore":
        from repro.core.lowering import lower
        return cls.from_program(lower(art), cost)

    def reset(self) -> None:
        self.v = np.zeros((self.groups_used, self.lane), np.int32)
        self.first = np.full((self.groups_used, self.lane), self.T, np.int32)
        self._acc = np.zeros((self.groups_used, self.lane), np.int32)

    def dispatch(self, nid: int) -> None:
        """Route one AER event: its weight row accumulates into every group."""
        self._acc += self.w[nid].astype(np.int32)

    def tick(self, t: int) -> bool:
        """Close tick t: leak, integrate, fire. Returns True if any neuron
        fired at this tick (the TTFS decision signal)."""
        self.v = self.v - (self.v >> self.leak_shift) + self._acc
        fired = (self.v >= self.thr) & (self.first == self.T)
        self.first = np.where(fired, np.int32(t), self.first)
        self._acc = np.zeros_like(self._acc)
        return bool(fired.any())

    # flat (n_pad,) views for the decode stage / output contract
    @property
    def first_flat(self) -> np.ndarray:
        return self.first.reshape(self.n_pad)

    @property
    def v_flat(self) -> np.ndarray:
        return self.v.reshape(self.n_pad)
