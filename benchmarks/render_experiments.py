"""Regenerate the §Roofline tables inside EXPERIMENTS.md from
results/dryrun/*.json (between the ROOFLINE_TABLE markers).

    PYTHONPATH=src python -m benchmarks.render_experiments
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN = os.path.join(ROOT, "results", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def _fmt(x: float) -> str:
    return f"{x:.3e}"


def load(variant_filter=lambda v: v == "baseline") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        r = json.load(open(f))
        if variant_filter(r.get("variant", "baseline")):
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9),
                             r["mesh"]))
    return rows


def table(rows: list[dict], mesh: str) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful | MFU@bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"*skip: full-attention* | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.1%} | {r['mfu']:.2%} |")
    return "\n".join(out)


def summary(rows: list[dict]) -> str:
    ok = [r for r in rows if r.get("status") == "ok"]
    sk = [r for r in rows if r.get("status") == "skipped"]
    by_b = {}
    for r in ok:
        by_b[r["bottleneck"]] = by_b.get(r["bottleneck"], 0) + 1
    worst = sorted((r for r in ok if r["shape"] == "train_4k"),
                   key=lambda r: r["mfu"])[:3]
    return (f"{len(ok)} compiled cells, {len(sk)} documented skips. "
            f"Bottleneck census: {by_b}. "
            "Lowest-MFU train cells: "
            + ", ".join(f"{r['arch']} ({r['mfu']:.1%})" for r in worst) + ".")


def main():
    rows = load()
    text = open(EXP).read()
    start = text.index("<!-- ROOFLINE_TABLE -->")
    end_marker = "## §Perf — hillclimb log"
    end = text.index(end_marker)
    gen = ["<!-- ROOFLINE_TABLE -->", "",
           summary(rows), "",
           "### Single-pod (16×16 = 256 chips)", "",
           table(rows, "single"), "",
           "### Multi-pod (2×16×16 = 512 chips)", "",
           table(rows, "multi"), "", ""]
    open(EXP, "w").write(text[:start] + "\n".join(gen) + text[end:])
    print(f"EXPERIMENTS.md §Roofline regenerated ({len(rows)} cells)")


if __name__ == "__main__":
    main()
