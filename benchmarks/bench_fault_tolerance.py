"""Chaos gate — the fault-injection sweep over the resilient serving tier.

Sweeps seeded fault types x rates from ``repro.faults`` through
``ServingScheduler`` lanes (static artifact SEUs, membrane upsets, stuck-at
groups, AER link glitches, forced FIFO depth, host-side lane crash / hang /
slowdown) and measures what the resilience machinery actually delivers:

  * detection rate   — did the matched detector (checksum / ECC / trace /
    canary / watchdog / exception path) fire for every injected-fault case;
  * recovery latency — fault-to-healthy scrub/rebuild time (recovery_ms);
  * the INVARIANT (the reason the subsystem exists): every admitted request
    completes with either a label bit-exact to the software reference or an
    explicit ``.error`` — never a silently wrong answer, never a hang.

``--check`` exits non-zero if any case violates the invariant, misses its
expected detection/recovery counters, or (for the clean baseline) shows any
fault activity at all. Violating cases are dumped to
``results/fault_failures/`` (JSON report per case) so chaos regressions are
reproducible from the seed. ``--trace-out DIR`` runs every case under a
fresh telemetry ``Tracer``, attaches a ``telemetry`` block to each row, and
writes ``<name>.trace.jsonl`` into DIR for violating cases — the span tree
(request → batch → lane → detector firings → requeues) sits alongside the
JSON verdict so the failure's causal history is in the same place as its
report. Emits ``results/bench/fault_tolerance.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import time

import numpy as np

from benchmarks import common as CM
from repro.core.reference import SNNReference
from repro.faults.plan import FaultPlan
from repro.serving.scheduler import ServingScheduler
from repro.telemetry import export as texport
from repro.telemetry import trace as ttrace
from repro.telemetry.trace import Tracer

FAIL_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                        "fault_failures")

#: detection counters, keyed by what fired them — a faulty case "detects"
#: when at least one of its expected counters is nonzero
DETECTORS = ("lane_faults", "integrity_failures", "canary_failures",
             "trace_failures", "ecc_detected", "watchdog_timeouts")


def _cases(quick: bool) -> list[dict]:
    """The sweep: one entry per fault type (plus a rate variant where rates
    are meaningful), each matched to the detector expected to catch it.
    ``n``/``mb`` are sized per runtime family — the per-image python board
    datapath (the only dynamic-fault site) gets small batches."""
    n_acc = 48 if quick else 96          # accelerator-path traffic per case
    n_brd = 8 if quick else 16           # board-py traffic per case
    cases = [
        # -- baseline: no plan, every fault counter must stay zero ---------
        dict(name="clean", spec="accelerator-event", kernel="fused",
             n=n_acc, mb=16, faults=None, faulty=False),
        # -- static SEU: artifact BRAM image, caught by the checksum -------
        dict(name="seu_weight", spec="accelerator-event", kernel="fused",
             n=n_acc, mb=16, faults="seu_weight=4,seed=3",
             expect={"integrity_failures": 1, "lane_restarts": 1}),
        dict(name="seu_threshold", spec="accelerator-event", kernel="fused",
             n=n_acc, mb=16, faults="seu_thr=2,seed=4",
             expect={"integrity_failures": 1, "lane_restarts": 1}),
        # -- persistent SEU: scrub cannot clear it -> quarantine + degrade -
        dict(name="seu_persistent", spec="accelerator-event", kernel="fused",
             n=n_acc, mb=16,
             faults={"seu_weight_flips": 4, "persistent": True, "seed": 5},
             expect={"integrity_failures": 2, "quarantines": 1,
                     "breaker_degraded": 1},
             all_fallback=True),
        # -- membrane SEU: mid-tick upsets, caught by the ECC/parity model -
        dict(name="membrane_seu", spec="board-py", n=n_brd, mb=4,
             faults="membrane=0.05,seed=6", verify=True,
             expect={"ecc_detected": 1, "lane_restarts": 1}),
        # -- stuck-at groups: a logic fault, caught by the canary probes ---
        dict(name="stuck_group", spec="board-py", n=n_brd, mb=4,
             faults="stuck=1,seed=7", canary=True,
             expect={"canary_failures": 1, "lane_restarts": 1}),
        # -- AER link glitches x rates: caught by the trace cross-check ----
        dict(name="aer_drop_2pct", spec="board-py", n=n_brd, mb=4,
             faults="aer_drop=0.02,seed=8", verify=True,
             expect={"trace_failures": 1, "lane_restarts": 1}),
        dict(name="aer_drop_10pct", spec="board-py", n=n_brd, mb=4,
             faults="aer_drop=0.10,seed=8", verify=True,
             expect={"trace_failures": 1, "lane_restarts": 1}),
        dict(name="aer_dup_10pct", spec="board-py", n=n_brd, mb=4,
             faults="aer_dup=0.10,seed=9", verify=True,
             expect={"trace_failures": 1, "lane_restarts": 1}),
        dict(name="aer_reorder_10pct", spec="board-py", n=n_brd, mb=4,
             faults="aer_reorder=0.10,seed=10", verify=True,
             expect={"trace_failures": 1, "lane_restarts": 1}),
        # -- forced FIFO depth: semantically clean backpressure — labels
        #    must stay bit-exact while the stall cycles land in the account
        dict(name="fifo_depth_4", spec="board-py", n=n_brd, mb=4,
             faults="fifo=4,seed=11", verify=True, faulty=False,
             min_stalls=1),
        # -- host-side lane faults: exception path / watchdog --------------
        dict(name="lane_crash", spec="accelerator-event", kernel="fused",
             n=n_acc, mb=16, faults="crash=0,seed=12",
             expect={"lane_faults": 1, "requeued": 1, "lane_restarts": 1}),
        dict(name="lane_hang", spec="accelerator-event", kernel="fused",
             n=n_acc, mb=16,
             faults=FaultPlan(seed=13, hang_batches=(0,), hang_s=1.5),
             watchdog_s=0.3,
             expect={"watchdog_timeouts": 1, "requeued": 1,
                     "lane_restarts": 1}),
        dict(name="lane_slow", spec="accelerator-event", kernel="fused",
             n=24 if quick else 48, mb=16, faults="slow=0.02,seed=14",
             faulty=False),
    ]
    return cases


def _run_case(case: dict, art, pool: np.ndarray, want: np.ndarray,
              traced: bool = False) -> dict:
    """Serve one chaos case end to end; returns the verdict + measurements.
    The invariant check is strict: every rid must come back, and a request
    may be wrong ONLY by being explicitly errored. With ``traced``, the case
    runs under its own fresh Tracer (kept on the verdict under ``_tracer``,
    stripped before JSON dumps)."""
    res = {"backoff_s": 0.002}
    if case.get("verify"):
        res["verify"] = True
    if case.get("watchdog_s"):
        res["watchdog_s"] = case["watchdog_s"]
    n = case["n"]
    tracer = Tracer() if traced else None
    prev = ttrace.install(tracer) if tracer else None
    t0 = time.perf_counter()
    try:
        sched = ServingScheduler(
            art, spec=case["spec"], kernel=case.get("kernel"), workers=1,
            max_batch=case["mb"], max_wait_us=500.0, faults=case["faults"],
            resilience=res,
            canary_pool=pool[:32] if case.get("canary") else None)
        with sched:
            rids = [sched.submit(pool[i % len(pool)]) for i in range(n)]
            done = sched.drain()
            st = sched.stats()
    finally:
        if tracer is not None:
            ttrace.install(prev)
    wall = time.perf_counter() - t0

    problems: list[str] = []
    missing = [r for r in rids if r not in done]
    if missing:
        problems.append(f"{len(missing)} requests never completed "
                        f"(rids {missing[:5]})")
    errored = wrong = fallbacks = 0
    for i, r in enumerate(rids):
        req = done.get(r)
        if req is None:
            continue
        if req.error is not None:
            errored += 1                 # explicit — the invariant allows it
            continue
        fallbacks += int(req.fallback_dense)
        if int(req.label) != int(want[i % len(pool)]):
            wrong += 1
    if wrong:
        problems.append(f"{wrong} SILENTLY WRONG labels — the one outcome "
                        "the resilience tier must never allow")
    # every fault in this sweep is recoverable or degradable: nothing may
    # be given up on
    if errored:
        problems.append(f"{errored} requests errored instead of being "
                        "served post-recovery")

    detected = {k: st[k] for k in DETECTORS if st.get(k)}
    if case.get("faulty", True):
        for key, floor in case.get("expect", {}).items():
            if st.get(key, 0) < floor:
                problems.append(f"expected {key} >= {floor}, got "
                                f"{st.get(key, 0)} (detection/recovery "
                                "machinery did not engage)")
    elif case["faults"] is None and detected:
        problems.append(f"clean baseline shows fault activity: {detected}")
    if case.get("all_fallback") and fallbacks < n - errored:
        problems.append(f"expected every request on the dense fallback, "
                        f"got {fallbacks}/{n - errored}")
    if case.get("min_stalls") and st.get("board_stalls", 0) < case["min_stalls"]:
        problems.append(f"forced FIFO depth produced no backpressure stalls "
                        f"(board_stalls={st.get('board_stalls', 0)})")

    plan = FaultPlan.coerce(case["faults"])
    verdict = {
        "name": case["name"], "spec": case["spec"],
        "plan": plan.describe() if plan is not None else "none",
        "faulty": bool(case.get("faulty", True)),
        "n": n, "wall_s": wall, "stats": st, "errored": errored,
        "wrong": wrong, "fallbacks": fallbacks,
        "detected": bool(detected), "detectors_fired": sorted(detected),
        "problems": problems,
    }
    if tracer is not None:
        verdict["telemetry"] = {"span_count": len(tracer.spans),
                                "dropped_spans": tracer.dropped}
        verdict["_tracer"] = tracer
    return verdict


def _dump_failure(verdict: dict) -> str:
    os.makedirs(FAIL_DIR, exist_ok=True)
    path = os.path.join(FAIL_DIR, f"{verdict['name']}.json")
    clean = {k: v for k, v in verdict.items() if not k.startswith("_")}
    with open(path, "w") as f:
        json.dump(clean, f, indent=1, default=float)
    return path


def main(quick: bool = False, check: bool = False,
         trace_out: str | None = None) -> int:
    art, xte, yte = CM.get_artifact_and_data(quick=quick)
    pool = xte[:64]
    want = np.asarray(SNNReference(art).forward(pool).labels)
    if os.path.isdir(FAIL_DIR):         # stale repros must not mask a green run
        shutil.rmtree(FAIL_DIR)

    verdicts = [_run_case(c, art, pool, want, traced=bool(trace_out))
                for c in _cases(quick)]

    rows, failures = [], []
    faulty = [v for v in verdicts if v["faulty"]]
    for v in verdicts:
        st = v["stats"]
        rows.append({
            "runtime": v["spec"],
            "config": v["name"],
            "scope": "resilience (chaos sweep, serving tier)",
            "fault_plan": v["plan"],
            "n_img": v["n"],
            "wall_s": v["wall_s"],
            "errored_img": v["errored"],
            "silently_wrong_img": v["wrong"],
            "fallback_img": v["fallbacks"],
            "detected": v["detected"],
            "detectors_fired": v["detectors_fired"],
            "recovery_ms_mean": st["recovery_ms_mean"],
            "lane_faults": st["lane_faults"],
            "requeued": st["requeued"],
            "lane_restarts": st["lane_restarts"],
            "quarantines": st["quarantines"],
            "breaker_degraded": st["breaker_degraded"],
            "watchdog_timeouts": st["watchdog_timeouts"],
            "invariant_ok_pct": 0.0 if v["problems"] else 100.0,
        })
        if "telemetry" in v:
            rows[-1]["telemetry"] = v["telemetry"]
        if v["problems"]:
            failures.append(v)
            _dump_failure(v)
            if trace_out and "_tracer" in v:
                path = os.path.join(trace_out,
                                    f"{v['name']}.trace.jsonl")
                n_spans = texport.write_jsonl(v["_tracer"], path)
                print(f"trace for failing case {v['name']!r} dumped to "
                      f"{path} ({n_spans} spans)", file=sys.stderr)
    det_rate = (100.0 * sum(v["detected"] for v in faulty) / len(faulty)
                if faulty else 0.0)
    rows.append({
        "stage": "summary",
        "scope": "resilience (chaos sweep, serving tier)",
        "cases": len(verdicts),
        "faulty_cases": len(faulty),
        "detection_rate_pct": det_rate,
        "invariant_ok_pct": 100.0 * (1 - len(failures) / len(verdicts)),
        "recovery_ms_mean": float(np.mean([
            v["stats"]["recovery_ms_mean"] for v in faulty
            if v["stats"]["recovery_ms_mean"]] or [0.0])),
    })
    CM.emit("fault_tolerance", rows)

    for v in verdicts:
        mark = "ok " if not v["problems"] else "BAD"
        fired = ",".join(v["detectors_fired"]) or "-"
        print(f"{mark} {v['name']:<18} {v['spec']:<20} "
              f"served {v['n'] - v['errored']:>3}/{v['n']}  "
              f"wrong {v['wrong']}  detectors [{fired}]  "
              f"recov {v['stats']['recovery_ms_mean']:7.1f} ms  "
              f"{v['wall_s']:5.1f}s")
    print(f"chaos gate: {len(verdicts) - len(failures)}/{len(verdicts)} cases "
          f"hold the invariant; detection {det_rate:.0f}% over "
          f"{len(faulty)} faulty cases")
    for v in failures:
        for p in v["problems"]:
            print(f"  FAIL [{v['name']}] {p}", file=sys.stderr)

    if check and failures:
        print(f"CHECK FAILED: {len(failures)} chaos cases violate the "
              f"detected-or-correct invariant — reports under "
              f"{os.path.normpath(FAIL_DIR)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller traffic per case (the CI configuration)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any case violates the detected-or-"
                         "correct invariant or misses its expected "
                         "detection/recovery counters")
    ap.add_argument("--trace-out", default=None, metavar="DIR",
                    help="record telemetry span trees per case and dump "
                         "JSONL traces for violating cases into DIR")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check, trace_out=a.trace_out))
