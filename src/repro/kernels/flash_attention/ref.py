"""Pure-jnp oracle: dense masked attention with f32 softmax."""

from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int | None = None,
                        q_offset: int = 0, kv_len: int | None = None) -> jnp.ndarray:
    """q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    kv_len = Skv if kv_len is None else kv_len
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = (kpos[None, :] < kv_len)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    lsum = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(lsum == 0.0, 1.0, lsum)
    # rows with no visible kv (possible under SWA offsets) -> zero output
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
