"""Paper §3.3 analogue: repeatability — N repeated runs over the test set,
report prediction mismatches across runs (paper: 0 in 50,000 image-run
pairs) and end-to-end latency mean/std (paper: 56.77 +/- 0.20 ms/img on the
embedded host)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as CM
from repro.core.accelerator import SNNAccelerator
from repro.core.agreement import repeatability


def run(quick: bool = False) -> list[dict]:
    art, xte, yte = CM.get_artifact_and_data(quick)
    n = 2000 if quick else 10000
    rep = repeatability(art, xte[:n], yte[:n], runs=5, chunk=2048)

    # end-to-end per-image latency over repeated single-batch runs
    acc = SNNAccelerator(art, mode="batch")
    lat = []
    _ = acc.forward(xte[:256])
    for _ in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(acc.forward(xte[:256]).labels)
        lat.append((time.perf_counter() - t0) / 256 * 1e3)
    rows = [{
        "config": f"repeatability-{rep['runs']}-runs",
        "scope": "system",
        "runs": rep["runs"],
        "image_run_pairs": rep["image_run_pairs"],
        "mismatches": rep["mismatches"],
        "accuracy_per_run_pct": [100 * a for a in rep["accuracy_per_run"]],
        "accuracy_stable": rep["accuracy_stable"],
        "e2e_ms_per_img_mean": float(np.mean(lat)),
        "e2e_ms_per_img_std": float(np.std(lat)),
    }]
    CM.emit("repeatability", rows)
    return rows


def main(quick: bool = False):
    r = run(quick)[0]
    print(f"runs={r['runs']} pairs={r['image_run_pairs']} "
          f"mismatches={r['mismatches']} stable={r['accuracy_stable']}")
    print(f"accuracy/run: {[f'{a:.2f}' for a in r['accuracy_per_run_pct']]}")
    print(f"e2e latency: {r['e2e_ms_per_img_mean']:.4f} "
          f"+/- {r['e2e_ms_per_img_std']:.4f} ms/img (this host)")
    assert r["mismatches"] == 0


if __name__ == "__main__":
    main()
