"""Software reference runner — consumes the deployment artifact unchanged.

This is the "software TTFS reference" of the paper: a straightforward dense
time-loop evaluation of the integer LIF/TTFS semantics. The accelerator
runtime must match it bit-exactly on first-spike times and decoded labels
(the paper's full-test-set, 10,000/10,000 agreement claim).

Also hosts the dense GPU/CPU-baseline analogues (Table 3 rows 2-5): dense
grouped-neuron execution of the SAME exported parameters in FP32 and INT8,
executed as plain matmuls rather than event-level TTFS runtimes.

Execution parameters come from the lowered program (``core.lowering``), not
ad-hoc artifact meta reads, and the jitted callables live in the
process-wide program cache — two ``SNNReference`` instances over the same
artifact share one compiled forward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ttfs
from repro.core.artifact import Artifact
from repro.core.lif_dynamics import lif_scan
from repro.core.lowering import (LoweredProgram, get_cache, lower,
                                 program_nbytes)
from repro.core.types import SNNOutput, decode_output  # noqa: F401 — SNNOutput
#                               re-exported: runtimes/tests import it from here


def _build_bundle(prog: LoweredProgram) -> dict:
    """Jitted callables closed over the program's fields (module-level
    closures, never bound methods — jax caches executables on the function
    object, so the bundle IS the compilation cache entry)."""
    T, x_min, leak_shift = prog.T, prog.x_min, prog.leak_shift
    w_int8, thr, w_f32 = prog.w_int8, prog.thresholds, prog.w_float
    plan = prog.decode
    g, p = prog.n_groups, prog.per_group

    def forward(images: jnp.ndarray) -> SNNOutput:
        times = ttfs.encode_ttfs(images, T, x_min)              # (B, N_in)
        raster = ttfs.frames_from_times(times, T)               # (B, T, N_in)
        # integer synaptic currents per step: (B, T, N_out) int32
        currents = jax.lax.dot_general(
            raster, w_int8,
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        currents = jnp.moveaxis(currents, 1, 0)                 # (T, B, N_out)
        res = lif_scan(currents, thr[None, :], leak_shift, T)
        labels = decode_output(res.first_spike, res.v_final, plan)
        steps = jnp.full(labels.shape, T, jnp.int32)
        return SNNOutput(labels, res.first_spike, res.v_final, steps)

    def dense_logits_fp32(images):
        """Dense grouped-neuron execution, FP32 (the 'GPU FP32'/'CPU FP32' row)."""
        z = jnp.asarray(images, jnp.float32) @ w_f32            # (B, N_out)
        return jnp.mean(z.reshape(-1, g, p), axis=-1)           # grouped readout

    def dense_logits_int8(images):
        """Dense INT8 execution of the same exported parameters."""
        x_q = jnp.clip(jnp.round(jnp.asarray(images, jnp.float32) * 127.0),
                       0, 127).astype(jnp.int8)
        z = jax.lax.dot_general(x_q, w_int8, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
        return jnp.mean(z.reshape(-1, g, p).astype(jnp.float32), axis=-1)

    return {"forward": jax.jit(forward),
            "dense_fp32": jax.jit(dense_logits_fp32),
            "dense_int8": jax.jit(dense_logits_int8)}


class SNNReference:
    """Reference runtime. ``forward(images)`` mirrors torch's ``model(x)``."""

    def __init__(self, artifact: Artifact | LoweredProgram):
        prog = lower(artifact)
        self.program = prog
        self.art = prog.artifact
        self.T = prog.T
        self.x_min = prog.x_min
        self.leak_shift = prog.leak_shift
        self.w_int8 = prog.w_int8              # (N_in, N_out)
        self.thr = prog.thresholds             # (N_out,) int32
        self.w_f32 = prog.w_float
        self.scale = prog.scale
        bundle, self.cache_hit = get_cache().bundle(
            ("reference", prog.fingerprint), lambda: _build_bundle(prog),
            nbytes=program_nbytes(prog))
        self._fwd = bundle["forward"]
        # dense baselines (Table 3) — shared jitted callables, one compile
        # per program per process
        self.dense_logits_fp32 = bundle["dense_fp32"]
        self.dense_logits_int8 = bundle["dense_int8"]

    # ---------------------------------------------------------------- TTFS
    def forward(self, images) -> SNNOutput:
        return self._fwd(jnp.asarray(images, jnp.float32))

    __call__ = forward

    # ---------------------------------------------- dense baselines (Table 3)
    def dense_labels(self, images, mode: str = "fp32"):
        logits = (self.dense_logits_fp32 if mode == "fp32"
                  else self.dense_logits_int8)(images)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
