"""Serving-tier resilience: the lane health state machine end to end —
detection (checksum / canary / trace / ECC / watchdog), bounded retry with
requeue, scrub/rebuild recovery, quarantine, and circuit-breaker degradation
to the dense fallback. Every scenario asserts the subsystem's invariant:
each admitted request completes with a reference-bit-exact label or an
explicit error — never silently wrong, never hung."""

import numpy as np
import pytest

from repro.core.reference import SNNReference
from repro.faults import FaultPlan
from repro.serving.scheduler import ServingError, ServingScheduler
from repro.serving.snn_engine import SNNServeEngine


def _want(art, images):
    return np.asarray(SNNReference(art).forward(images).labels)


def _serve_all(sched, images):
    rids = [sched.submit(x) for x in images]
    done = sched.drain()
    return np.asarray([done[r].label for r in rids]), done, rids


# ----------------------------------------------------------- crash + retry
def test_lane_crash_retries_to_bitexact_labels(trained_artifact):
    """An injected lane crash requeues its batch; after the scrub/rebuild
    every label is still bit-exact and the ledger shows the round trip."""
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=8, max_wait_us=500.0,
                          faults="crash=0,seed=3",
                          resilience={"backoff_s": 0.001}) as s:
        got, done, rids = _serve_all(s, xte[:24])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:24]))
    assert all(done[r].error is None for r in rids)
    assert st["lane_faults"] >= 1 and st["requeued"] >= 1
    assert st["lane_restarts"] >= 1 and st["recoveries"] >= 1
    assert st["errors"] == 0 and st["recovery_ms_mean"] > 0
    assert any(done[r].attempts > 0 for r in rids)   # retries really happened


def test_startup_seu_scrubbed_before_service(trained_artifact):
    """A transient SEU in the lane's BRAM image fails the commission-time
    checksum; the rebuilt lane serves bit-exact with zero request impact."""
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=8, max_wait_us=500.0,
                          faults="seu_weight=4,seed=5",
                          resilience={"backoff_s": 0.001}) as s:
        got, done, rids = _serve_all(s, xte[:16])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:16]))
    assert st["integrity_failures"] >= 1 and st["lane_restarts"] >= 1
    assert st["errors"] == 0
    assert all(not done[r].fallback_dense for r in rids)  # healthy event path


# ---------------------------------------------------------------- watchdog
def test_watchdog_replaces_hung_lane(trained_artifact):
    art, _, (xte, _) = trained_artifact
    plan = FaultPlan(seed=7, hang_batches=(0,), hang_s=1.5)
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=4, max_wait_us=500.0,
                          faults=plan,
                          resilience={"watchdog_s": 0.2,
                                      "backoff_s": 0.001}) as s:
        got, done, rids = _serve_all(s, xte[:12])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:12]))
    assert st["watchdog_timeouts"] >= 1 and st["requeued"] >= 1
    assert st["lane_restarts"] >= 1 and st["errors"] == 0


# --------------------------------------------------- quarantine + breaker
def test_persistent_seu_quarantines_and_degrades(trained_artifact):
    """A fault the scrub cannot clear: commission fails twice, the lane is
    quarantined and circuit-broken onto the dense fallback — every request
    still served, bit-exact, flagged as fallback traffic."""
    art, _, (xte, _) = trained_artifact
    faults = {"seu_weight_flips": 4, "persistent": True, "seed": 9}
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=8, max_wait_us=500.0,
                          faults=faults,
                          resilience={"backoff_s": 0.001}) as s:
        got, done, rids = _serve_all(s, xte[:16])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:16]))
    assert st["quarantines"] >= 1 and st["breaker_degraded"] >= 1
    assert st["errors"] == 0
    assert all(done[r].fallback_dense for r in rids)
    assert "degraded" in st["lane_health"]


def test_persistent_seu_without_degrade_refuses_admission(trained_artifact):
    art, _, (xte, _) = trained_artifact
    faults = {"seu_weight_flips": 4, "persistent": True, "seed": 9}
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         workers=1, max_batch=8, max_wait_us=500.0,
                         faults=faults,
                         resilience={"backoff_s": 0.001, "degrade": False})
    try:
        with pytest.raises(RuntimeError, match="quarantined"):
            s.submit(xte[0])
        assert s.stats()["quarantines"] >= 1
    finally:
        s.close()


def test_circuit_breaker_stops_crash_flapping(trained_artifact):
    """A persistent crash-at-batch-0 plan re-fires on every rebuilt lane;
    the breaker must stop the flapping by degrading to the dense path, and
    every request must still complete correctly."""
    art, _, (xte, _) = trained_artifact
    plan = FaultPlan(seed=11, crash_batches=(0,), persistent=True)
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=8, max_wait_us=500.0,
                          faults=plan,
                          resilience={"backoff_s": 0.001, "max_retries": 4,
                                      "breaker_threshold": 2}) as s:
        got, done, rids = _serve_all(s, xte[:16])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:16]))
    assert st["breaker_degraded"] >= 1 and st["errors"] == 0
    assert any(done[r].fallback_dense for r in rids)   # post-breaker traffic


# ----------------------------------------------- mid-flight board detectors
def test_stuck_group_caught_by_canary_mid_flight(trained_artifact):
    """startup_checks=False lets a stuck-at lane into service; the per-batch
    canary probes catch it, the batch is requeued, and the rebuilt lane
    serves every label bit-exact."""
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="board-py", workers=1, max_batch=2,
                          max_wait_us=500.0, faults="stuck=1,seed=13",
                          canary_pool=xte[:32],
                          resilience={"startup_checks": False, "verify": True,
                                      "canary_every": 1,
                                      "backoff_s": 0.001}) as s:
        got, done, rids = _serve_all(s, xte[:4])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:4]))
    assert st["canary_failures"] >= 1 and st["lane_faults"] >= 1
    assert st["lane_restarts"] >= 1 and st["errors"] == 0


def test_membrane_seu_caught_by_ecc_mid_flight(trained_artifact):
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="board-py", workers=1, max_batch=2,
                          max_wait_us=500.0, faults="membrane=0.9,seed=15",
                          resilience={"startup_checks": False, "verify": True,
                                      "backoff_s": 0.001}) as s:
        got, done, rids = _serve_all(s, xte[:4])
        st = s.stats()
    assert np.array_equal(got, _want(art, xte[:4]))
    assert st["ecc_detected"] >= 1 and st["lane_restarts"] >= 1
    assert st["errors"] == 0


# --------------------------------------------------------- close semantics
def test_context_exit_completes_every_admitted_request(trained_artifact):
    """Satellite: close() with queued/in-flight requests must not drop them
    silently — exiting the context completes EVERY admitted request, each
    with a label or an explicit 'scheduler closed' error."""
    art, _, (xte, _) = trained_artifact
    with ServingScheduler(art, spec="accelerator-event", kernel="fused",
                          workers=1, max_batch=4,
                          max_wait_us=10_000_000.0) as s:
        rids = [s.submit(x) for x in xte[:32]]
        # exit immediately: a huge deadline means most of these are queued
    done = s.drain()
    assert sorted(done) == rids
    for r in rids:
        req = done[r]
        assert (req.label is not None) or (req.error == "scheduler closed")
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(xte[0])


def test_close_drain_serves_backlog_first(trained_artifact):
    art, _, (xte, _) = trained_artifact
    s = ServingScheduler(art, spec="accelerator-event", kernel="fused",
                         workers=1, max_batch=4, max_wait_us=500.0)
    rids = [s.submit(x) for x in xte[:20]]
    s.close(drain=True)
    done = s.drain()
    got = np.asarray([done[r].label for r in rids])
    assert np.array_equal(got, _want(art, xte[:20]))
    assert all(done[r].error is None for r in rids)
    assert s.stats()["errors"] == 0


# ------------------------------------------------------------ engine facade
def test_engine_classify_through_crash_recovery(trained_artifact):
    art, _, (xte, _) = trained_artifact
    eng = SNNServeEngine(art, backend="accelerator", max_batch=8, workers=1,
                         faults="crash=0,seed=17",
                         resilience={"backoff_s": 0.001})
    try:
        got = eng.classify(xte[:16])
        st = eng.stats()
    finally:
        eng.close()
    assert np.array_equal(got, _want(art, xte[:16]))
    assert st["lane_faults"] >= 1 and st["errors"] == 0


def test_engine_classify_raises_serving_error_on_gave_up(trained_artifact):
    """classify() must never fabricate a label for a failed request: when
    retries are exhausted it raises ServingError naming the request."""
    art, _, (xte, _) = trained_artifact

    def boom(images, k, probe=False):
        raise RuntimeError("lane keeps dying")

    eng = SNNServeEngine(art, backend="accelerator", max_batch=4, workers=1,
                         resilience={"max_retries": 0, "backoff_s": 0.001})
    try:
        eng.sched.lanes[0].serve = boom
        with pytest.raises(ServingError, match="lane keeps dying"):
            eng.classify(xte[:2])
    finally:
        eng.close()
