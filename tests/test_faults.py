"""Fault-injection subsystem: seeded plans, the three injection sites
(artifact SEU / board datapath / host lanes), the matched detectors, and the
clean-plan guarantee — ``FaultPlan.none()`` must leave every datapath
bit-exact (checked against the PR 4 golden traces)."""

import copy

import numpy as np
import pytest

from repro.board.event_queue import AEREventQueue
from repro.board.neuron_core import GroupedNeuronCore
from repro.conformance import fuzz_case
from repro.conformance.golden import golden_path
from repro.core.hw import PYNQ_COST
from repro.core.quant import INT32_NEVER_FIRE
from repro.core.runtimes import make_runtime
from repro.faults import (Canary, FaultPlan, FaultyAEREventQueue,
                          apply_stuck, corrupt_artifact, ecc_errors,
                          integrity_errors, trace_errors)


@pytest.fixture(scope="module")
def fuzz0():
    return fuzz_case(0)


# ---------------------------------------------------------------------- plan
def test_plan_parse_grammar():
    p = FaultPlan.parse("seu_weight=4,aer_drop=0.02,crash=0:2,seed=7")
    assert p.seu_weight_flips == 4 and p.aer_drop_rate == 0.02
    assert p.crash_batches == (0, 2) and p.seed == 7
    assert p.has_static and p.has_dynamic and p.has_lane_faults
    assert FaultPlan.parse("").is_clean
    assert FaultPlan.parse("fifo=4").fifo_depth == 4
    assert FaultPlan.parse("persistent=true,stuck=1").persistent
    with pytest.raises(ValueError, match="unknown fault-plan key"):
        FaultPlan.parse("bogus=1")
    with pytest.raises(ValueError, match="needs '=value'"):
        FaultPlan.parse("seu_weight")


def test_plan_coerce_and_lifecycle():
    p = FaultPlan(seed=3, crash_batches=(0,), lanes=(1,))
    assert FaultPlan.coerce(p) is p
    assert FaultPlan.coerce(None) is None
    assert FaultPlan.coerce({"seed": 2}).seed == 2
    assert FaultPlan.coerce("seu_thr=1").seu_threshold_flips == 1
    with pytest.raises(TypeError):
        FaultPlan.coerce(42)
    # lane split: out-of-scope lanes serve clean, in-scope decorrelate seeds
    assert p.for_lane(0).is_clean
    assert p.for_lane(1).crash_batches == (0,)
    assert p.for_lane(1).seed != p.seed
    # scrub clears transient plans, keeps persistent ones
    assert p.after_scrub().is_clean
    pp = FaultPlan(seu_weight_flips=2, persistent=True)
    assert pp.after_scrub() is pp


def test_plan_rng_deterministic_and_stream_decorrelated():
    a = FaultPlan(seed=5).rng("aer", 0).randint(1 << 30, size=8)
    b = FaultPlan(seed=5).rng("aer", 0).randint(1 << 30, size=8)
    c = FaultPlan(seed=5).rng("aer", 1).randint(1 << 30, size=8)
    d = FaultPlan(seed=6).rng("aer", 0).randint(1 << 30, size=8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c) and not np.array_equal(a, d)


# -------------------------------------------------------------- artifact SEU
def test_corrupt_artifact_detected_and_original_pristine(fuzz0):
    art = fuzz0.artifact
    before = {k: v.copy() for k, v in art.arrays.items()}
    plan = FaultPlan(seed=9, seu_weight_flips=3, seu_threshold_flips=1)
    bad = corrupt_artifact(art, plan)
    # the detector: the clone's manifest was stamped from PRISTINE arrays
    assert integrity_errors(bad)
    # determinism: same plan, same flipped bits
    bad2 = corrupt_artifact(art, plan)
    for k in bad.arrays:
        assert np.array_equal(bad.arrays[k], bad2.arrays[k])
    # the caller's artifact is untouched (it backs the scrub/reload path)
    for k, v in before.items():
        assert np.array_equal(art.arrays[k], v)
    assert integrity_errors(art) == []   # no manifest -> vacuously intact
    assert corrupt_artifact(art, FaultPlan.none()) is art


def test_make_runtime_static_plan_any_family_dynamic_board_py_only(fuzz0):
    art = fuzz0.artifact
    rt = make_runtime(art, "reference", faults="seu_weight=2,seed=1")
    assert integrity_errors(rt.art)      # corrupted clone rides in
    assert integrity_errors(art) == []   # original pristine
    # dynamic plans only make sense where the datapath is emulated
    with pytest.raises(ValueError, match="board-py"):
        make_runtime(art, "accelerator-event", faults="aer_drop=0.1")
    with pytest.raises(ValueError, match="board-py"):
        make_runtime(art, "reference", faults="membrane=0.5")
    make_runtime(art, "board-py", faults="aer_drop=0.1")   # accepted


# ------------------------------------------------------------------ AER link
def test_aer_queue_depth_exact_boundary():
    """Stall accounting at the exact FIFO boundary: a tick holding exactly
    ``depth`` events backpressures nothing; one more event stalls one cycle."""
    T, n = 4, 6
    times = np.zeros(n, np.int64)                # n events flood tick 0
    q_fit = AEREventQueue(times, T, depth=n)
    q_over = AEREventQueue(times, T, depth=n - 1)
    assert q_fit.stalls_at(0) == 0
    assert q_over.stalls_at(0) == 1
    assert q_fit.total_events == q_over.total_events == n   # never drops


def test_faulty_aer_queue_drop_dup_reorder(fuzz0):
    art, times = fuzz0.artifact, fuzz0.times
    T = int(art.m("encode", "T"))
    depth = int(art.m("events", "e_max"))
    row = times[0]
    clean = AEREventQueue(row, T, depth)
    drop = FaultyAEREventQueue(row, T, depth,
                               FaultPlan(seed=1, aer_drop_rate=0.5))
    dup = FaultyAEREventQueue(row, T, depth,
                              FaultPlan(seed=1, aer_dup_rate=0.5))
    reorder = FaultyAEREventQueue(row, T, depth,
                                  FaultPlan(seed=1, aer_reorder_rate=0.5))
    assert drop.total_events == clean.total_events - drop.injected_drops
    assert drop.injected_drops > 0
    assert dup.total_events == clean.total_events + dup.injected_dups
    assert dup.injected_dups > 0
    # reorder preserves the event multiset, only displaces across tick edges
    assert reorder.total_events == clean.total_events
    assert reorder.injected_moves > 0
    def ids(q):
        return sorted(int(i) for t in range(T) for i in q.events_at(t))
    assert ids(reorder) == ids(clean)
    # determinism: the same (plan, image_key) perturbs identically
    drop2 = FaultyAEREventQueue(row, T, depth,
                                FaultPlan(seed=1, aer_drop_rate=0.5))
    assert all(np.array_equal(drop.events_at(t), drop2.events_at(t))
               for t in range(T))


def test_fifo_depth_override_stalls_only(fuzz0):
    """A forced-tiny FIFO is pure backpressure: labels bit-exact, stall
    cycles charged in the account."""
    art, images = fuzz0.artifact, fuzz0.images[:3]
    clean = make_runtime(art, "board-py")
    faulty = make_runtime(art, "board-py", faults="fifo=1")
    out_c, out_f = clean.forward(images), faulty.forward(images)
    assert np.array_equal(out_c.labels, out_f.labels)
    assert np.array_equal(out_c.first_spike, out_f.first_spike)
    assert int(np.sum(faulty.last_trace.stalls)) > int(
        np.sum(clean.last_trace.stalls))
    assert trace_errors(faulty, images) == []   # consistent with its depth


# ------------------------------------------------------------ board datapath
def test_membrane_seu_hits_ecc(fuzz0):
    art, images = fuzz0.artifact, fuzz0.images[:2]
    rt = make_runtime(art, "board-py", faults="membrane=0.9,seed=2")
    rt.forward(images)
    assert int(np.sum(rt.last_ecc)) > 0
    assert ecc_errors(rt)                       # the parity detector fires
    clean = make_runtime(art, "board-py")
    clean.forward(images)
    assert ecc_errors(clean) == []


def test_apply_stuck_modes_and_readout_restriction(trained_artifact):
    art, _, _ = trained_artifact
    n_out = int(art.m("model", "n_out"))
    core = GroupedNeuronCore.from_artifact(art, PYNQ_COST)
    readout_span = -(-n_out // core.lane)
    sat = apply_stuck(core, FaultPlan(seed=3, stuck_groups=2), n_out=n_out)
    assert len(sat) == 2 and all(g < readout_span for g in sat)
    assert all(np.all(core.thr[g, :] == np.iinfo(np.int32).min) for g in sat)
    core2 = GroupedNeuronCore.from_artifact(art, PYNQ_COST)
    sil = apply_stuck(core2, FaultPlan(seed=3, stuck_groups=1,
                                       stuck_mode="silent"), n_out=n_out)
    assert all(np.all(core2.thr[g, :] == INT32_NEVER_FIRE) for g in sil)
    with pytest.raises(ValueError, match="stuck_mode"):
        apply_stuck(core2, FaultPlan(stuck_groups=1, stuck_mode="wedged"))
    assert apply_stuck(core2, FaultPlan.none()) == []


def test_trace_detector_catches_aer_glitches(fuzz0):
    art, images = fuzz0.artifact, fuzz0.images[:3]
    clean = make_runtime(art, "board-py")
    clean.forward(images)
    assert trace_errors(clean, images) == []
    glitched = make_runtime(art, "board-py", faults="aer_drop=0.3,seed=4")
    glitched.forward(images)
    errs = trace_errors(glitched, images)
    assert errs and any("histogram" in e for e in errs)


# -------------------------------------------------------------------- canary
def test_canary_probes_detect_stuck_group(trained_artifact):
    art, _, (xte, _) = trained_artifact
    canary = Canary.from_artifact(art, pool=xte[:64])
    assert len(canary.covered_groups) >= 2      # detection needs >=2 labels
    assert canary.mismatches(canary.want) == []
    flipped = np.array(canary.want)
    flipped[0] = (flipped[0] + 1) % canary.n_groups
    assert canary.mismatches(flipped)
    # a saturated stuck group really moves a probe label through board-py
    rt = make_runtime(art, "board-py", faults="stuck=1,seed=5")
    got = rt.forward(canary.images).labels
    assert canary.mismatches(got)


# ------------------------------------------------------- clean-plan guarantee
def test_clean_plan_board_py_bitexact_with_golden(fuzz0):
    """``FaultPlan.none()`` keeps every injection hook inert: board-py under
    the clean plan matches both the unfaulted runtime AND the committed
    PR 4 golden snapshot, outputs and cost account alike."""
    art, images = fuzz0.artifact, fuzz0.images[:5]
    plain = make_runtime(art, "board-py")
    hooked = make_runtime(art, "board-py", faults=FaultPlan.none())
    out_p, out_h = plain.forward(images), hooked.forward(images)
    for f in ("labels", "first_spike", "v_final", "steps"):
        assert np.array_equal(getattr(out_p, f), getattr(out_h, f)), f
    import dataclasses
    for f in dataclasses.fields(plain.last_trace):
        assert np.array_equal(np.asarray(getattr(plain.last_trace, f.name)),
                              np.asarray(getattr(hooked.last_trace, f.name)))
    with np.load(golden_path(0)) as z:
        assert np.array_equal(out_h.labels, z["labels"][:5])
        assert np.array_equal(out_h.first_spike, z["first_spike"][:5])
        assert np.array_equal(
            np.asarray(hooked.last_trace.cycles), z["board_cycles"][:5])
        assert np.array_equal(
            np.asarray(hooked.last_trace.energy_nj),
            z["board_energy_nj"][:5])


def test_clean_plan_static_sites_inert(fuzz0):
    from repro.core.lowering import lower
    art = fuzz0.artifact
    meta_before = copy.deepcopy(art.meta)
    rt = make_runtime(art, "reference", faults=FaultPlan.none())
    # a clean plan must not trigger the corruption lowering pass: the
    # runtime serves the pristine program (content identity — the program
    # cache may hold the lowering of an EQUAL artifact object from an
    # earlier test, so object identity is not the invariant)
    assert rt.program.fingerprint == lower(art, cache=False).fingerprint
    assert rt.art.fingerprint() == art.fingerprint()
    assert art.meta == meta_before
