"""Procedural MNIST — a deterministic synthetic stand-in.

This container is offline, so real MNIST is unavailable (DESIGN.md §6). We
generate a 28x28 grayscale digit dataset procedurally: 10 glyph bitmaps ->
random affine (shift/rotate/scale/shear) -> bilinear resample -> stroke-
intensity jitter + Gaussian noise. Deterministic per seed; cached on disk.

All accuracy numbers in EXPERIMENTS.md are on this dataset and say so. The
paper's *agreement/determinism* claims — the actual contribution — are
dataset-independent and validated exactly.
"""

from __future__ import annotations

import os

import numpy as np

_GLYPHS = {  # 7x5 classic bitmap font
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_images() -> np.ndarray:
    """(10, 28, 28) float32 smoothed glyph templates."""
    out = np.zeros((10, 28, 28), np.float32)
    for d, rows in _GLYPHS.items():
        bmp = np.array([[int(c) for c in r] for r in rows], np.float32)  # 7x5
        big = np.kron(bmp, np.ones((3, 3), np.float32))                  # 21x15
        img = np.zeros((28, 28), np.float32)
        img[3:24, 6:21] = big
        # cheap 3x3 box blur for stroke softness
        pad = np.pad(img, 1)
        img = sum(pad[i:i + 28, j:j + 28] for i in range(3) for j in range(3)) / 9
        out[d] = np.clip(img * 1.6, 0, 1)
    return out


def _affine_batch(imgs: np.ndarray, rng: np.random.RandomState) -> np.ndarray:
    """Random affine per image with vectorized bilinear resampling."""
    B = imgs.shape[0]
    ang = rng.uniform(-0.30, 0.30, B)                 # ~±17 deg
    scale = rng.uniform(0.80, 1.20, B)
    shear = rng.uniform(-0.25, 0.25, B)
    tx = rng.uniform(-2.5, 2.5, B)
    ty = rng.uniform(-2.5, 2.5, B)
    c, s = np.cos(ang) / scale, np.sin(ang) / scale
    # inverse map: dest (x,y) -> src coords, centered at 13.5
    yy, xx = np.mgrid[0:28, 0:28].astype(np.float32)
    xc, yc = (xx - 13.5).ravel(), (yy - 13.5).ravel()           # (784,)
    sx = c[:, None] * xc + (s[:, None] + shear[:, None]) * yc + 13.5 - tx[:, None]
    sy = -s[:, None] * xc + c[:, None] * yc + 13.5 - ty[:, None]
    x0 = np.floor(sx).astype(np.int32)
    y0 = np.floor(sy).astype(np.int32)
    fx, fy = sx - x0, sy - y0

    def grab(yi, xi):
        yi = np.clip(yi, 0, 27)
        xi = np.clip(xi, 0, 27)
        return imgs[np.arange(B)[:, None], yi, xi]

    out = (grab(y0, x0) * (1 - fx) * (1 - fy) + grab(y0, x0 + 1) * fx * (1 - fy)
           + grab(y0 + 1, x0) * (1 - fx) * fy + grab(y0 + 1, x0 + 1) * fx * fy)
    return out.reshape(B, 28, 28)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (n, 784) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.RandomState(seed)
    glyphs = _glyph_images()
    labels = rng.randint(0, 10, n).astype(np.int32)
    base = glyphs[labels]
    imgs = _affine_batch(base, rng)
    imgs *= rng.uniform(0.7, 1.0, (n, 1, 1))                    # stroke intensity
    imgs += rng.normal(0, 0.08, imgs.shape)                     # sensor noise
    imgs = np.clip(imgs, 0, 1).astype(np.float32)
    return imgs.reshape(n, 784), labels


def load(split: str = "train", n_train: int = 60_000, n_test: int = 10_000,
         seed: int = 1234, cache_dir: str | None = None
         ) -> tuple[np.ndarray, np.ndarray]:
    cache_dir = cache_dir or os.path.join(
        os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache")),
        "repro_procmnist")
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, f"procmnist_{seed}_{n_train}_{n_test}.npz")
    if not os.path.exists(path):
        xtr, ytr = generate(n_train, seed)
        xte, yte = generate(n_test, seed + 1)
        tmp = path + ".tmp.npz"        # np.savez appends .npz itself
        np.savez_compressed(tmp, xtr=xtr, ytr=ytr, xte=xte, yte=yte)
        os.replace(tmp, path)
    with np.load(path) as z:
        if split == "train":
            return z["xtr"], z["ytr"]
        return z["xte"], z["yte"]
