"""Post-optimization HLO text parser with while-loop trip-count scaling.

XLA's HloCostAnalysis (and a naive text grep) counts a while body ONCE —
but our layer stack is a lax.scan, so per-layer collectives (FSDP weight
all-gathers, TP all-reduces) execute n_periods times per step. This parser:

  1. splits the module text into named computations,
  2. records collective result bytes per computation,
  3. finds `while` ops, reads the trip count from the largest s32 constant
     in the condition computation (jax lowers scan bounds there),
  4. recursively totals: entry + trip * body (nested scans handled).

Shapes in the partitioned module are per-device, so the result is per-chip
collective bytes. Wire model: all-reduce counts 2x (ring reduce-scatter +
all-gather), other collectives 1x.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# computation header: "<name> (params...) -> result {"; params may nest
# parens (tuple types), so split on the first "(" of a non-instruction line.
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-$]+)\s*\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_WHILE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_S32_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL = re.compile(r"\b(?:call|fusion)\(.*?calls=%?([\w.\-]+)")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    coll_bytes: dict = field(default_factory=dict)   # kind -> bytes (one pass)
    whiles: list = field(default_factory=list)        # (cond_name, body_name)
    calls: list = field(default_factory=list)
    max_s32_const: int = 0


def parse_module(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in hlo.splitlines():
        is_header = (line.rstrip().endswith("{") and "->" in line
                     and " = " not in line and not line.startswith("HloModule"))
        if is_header:
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = Computation(hdr.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _COLLECTIVE.search(line)
        if m:
            b = _shape_bytes(m.group(1))
            cur.coll_bytes[m.group(2)] = cur.coll_bytes.get(m.group(2), 0) + b
        w = _WHILE.search(line)
        if w:
            cur.whiles.append((w.group(1), w.group(2)))
        c = _CALL.search(line)
        if c:
            cur.calls.append(c.group(1))
        for sc in _S32_CONST.findall(line):
            cur.max_s32_const = max(cur.max_s32_const, int(sc))
    return comps, entry or ""


def collective_bytes_scaled(hlo: str) -> dict[str, float]:
    """Per-chip collective result bytes by kind, with while bodies multiplied
    by their trip counts."""
    comps, entry = parse_module(hlo)

    def total(name: str, seen: tuple = ()) -> dict[str, float]:
        if name not in comps or name in seen:
            return {}
        comp = comps[name]
        out = {k: float(v) for k, v in comp.coll_bytes.items()}
        for callee in comp.calls:
            for k, v in total(callee, seen + (name,)).items():
                out[k] = out.get(k, 0.0) + v
        for cond, body in comp.whiles:
            trip = max(comps.get(cond, Computation(cond)).max_s32_const, 1)
            inner = total(body, seen + (name,))
            for k, v in inner.items():
                out[k] = out.get(k, 0.0) + trip * v
        return out

    return total(entry)


def wire_bytes(coll: dict[str, float]) -> float:
    return sum(2.0 * v if k == "all-reduce" else v for k, v in coll.items())


def count_ops(hlo: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo))
