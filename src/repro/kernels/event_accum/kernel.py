"""Event-driven synaptic accumulation — the router/gather path.

This is the latency-oriented sibling of spike_matmul: work scales with the
number of ACTIVE events, not with N_in. Each grid step processes one timestep
against one 128-lane neuron block; event ids index weight ROWS held in VMEM
(the BRAM-resident packed-synapse analogue), and masked rows (PAD = -1)
contribute exactly zero, preserving integer determinism.

    grid = (T, N_pad // bn)
    ids block   (1, E_max)       int32  VMEM
    w block     (N_in, bn)       int8   VMEM   (784 x 128 int8 = 98 KiB)
    out block   (1, bn)          int32

The E-loop is a fori_loop of dynamic single-row loads — on TPU these are VMEM
loads (cheap); the event-sparse structure is what the FPGA's router provides
and what dense matmul cannot: cost ~ O(E_active * bn) instead of O(N_in * bn).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _event_accum_kernel(ids_ref, w_ref, o_ref, *, e_max: int):
    bn = o_ref.shape[1]

    def body(e, acc):
        nid = ids_ref[0, e]
        valid = nid >= 0
        safe = jnp.maximum(nid, 0)
        row = w_ref[pl.dslice(safe, 1), :]                       # (1, bn) int8
        return acc + jnp.where(valid, row.astype(jnp.int32)[0], 0)

    acc = jax.lax.fori_loop(0, e_max, body, jnp.zeros((bn,), jnp.int32))
    o_ref[0, :] = acc


def event_accum_kernel(ids: jnp.ndarray, w: jnp.ndarray, *,
                       block_n: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """ids (T, E_max) int32 (PAD=-1), w (N_in, N_pad) int8
    -> currents (T, N_pad) int32."""
    T, E = ids.shape
    N_in, N = w.shape
    assert N % block_n == 0
    grid = (T, N // block_n)
    kernel = functools.partial(_event_accum_kernel, e_max=E)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, E), lambda t, n: (t, 0)),
            pl.BlockSpec((N_in, block_n), lambda t, n: (0, n)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda t, n: (t, n)),
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.int32),
        interpret=interpret,
    )(ids, w)
