"""Shared benchmark plumbing: the trained deployment artifact (cached), the
test split, timing helpers, and the TPU projection model.

Scope discipline (the paper's measurement protocol, §2.3):
  * accelerator-scope — jitted device execution only (block_until_ready
    around the compiled call), plus a labeled TPU *projection* from the
    energy/roofline model;
  * system-scope — host-inclusive wall clock: encode, packing, dispatch,
    readback, python.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import deploy
from repro.core.artifact import Artifact
from repro.core.hw import TPU_V5E

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
ART_PATH = os.path.join(RESULTS, "mnist_ttfs_artifact.npz")


def get_artifact_and_data(quick: bool = False):
    """Train-once-and-cache the deployed classifier + test split."""
    from repro.data import mnist
    os.makedirs(RESULTS, exist_ok=True)
    xte, yte = mnist.load("test")
    if quick:
        xte, yte = xte[:2000], yte[:2000]
    if os.path.exists(ART_PATH):
        return Artifact.load(ART_PATH), xte, yte
    from repro.training.ttfs_trainer import train_dense_proxy
    xtr, ytr = mnist.load("train")
    res = train_dense_proxy(xtr, ytr, test_images=xte, test_labels=yte,
                            epochs=3)
    deploy.export(res.model, ART_PATH, calib_images=xtr[:8192],
                  calib_labels=ytr[:8192])
    return Artifact.load(ART_PATH), xte, yte


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def snn_event_cost_per_image(art: Artifact, images: np.ndarray) -> dict:
    """Analytic per-image cost of the event path (the TPU projection):
    work scales with ACTIVE events; weights are VMEM-resident (the paper's
    BRAM-resident co-design point, verified by the planner)."""
    active = float(np.mean(np.sum(images >= art.m("encode", "x_min"), axis=1)))
    n_pad = art.m("codesign", "n_pad")
    T = art.m("encode", "T")
    flops = 2.0 * active * n_pad                       # gather-accumulate
    flops += 5.0 * T * n_pad                           # LIF update
    vmem_bytes = active * n_pad * 1.0 + T * n_pad * 4.0
    hbm_bytes = 784 * 4.0                              # image in
    t_tpu = max(flops / TPU_V5E.peak_bf16_flops,
                vmem_bytes / 2.0e13)                   # ~20 TB/s VMEM-class bw
    energy_nj = (flops * TPU_V5E.pj_per_flop_bf16
                 + vmem_bytes * TPU_V5E.pj_per_vmem_byte
                 + hbm_bytes * TPU_V5E.pj_per_hbm_byte) * 1e-3
    return {"active_events": active, "flops": flops,
            "vmem_bytes": vmem_bytes, "proj_latency_us": t_tpu * 1e6,
            "proj_energy_nj": energy_nj}


def snn_dense_cost_per_image(art: Artifact, bytes_per_w: float = 1.0) -> dict:
    """Dense (time-batched matmul) execution cost per image — HBM-streamed,
    the GPU-baseline analogue."""
    T = art.m("encode", "T")
    n_in = art.m("model", "n_in")
    n_pad = art.m("codesign", "n_pad")
    flops = 2.0 * T * n_in * n_pad
    hbm = n_in * n_pad * bytes_per_w + T * n_in + T * n_pad * 4
    t = max(flops / TPU_V5E.peak_bf16_flops, hbm / TPU_V5E.hbm_bandwidth)
    energy_nj = (flops * TPU_V5E.pj_per_flop_bf16
                 + hbm * TPU_V5E.pj_per_hbm_byte) * 1e-3
    return {"flops": flops, "hbm_bytes": hbm, "proj_latency_us": t * 1e6,
            "proj_energy_nj": energy_nj}


def emit(name: str, rows: list[dict]) -> None:
    """Validate rows against the shared bench schema, then write the JSON.
    Schema violations fail the bench loudly — results/bench/ files must stay
    comparable across PRs (scope + identity + unit-suffixed metrics)."""
    from benchmarks import schema
    schema.validate_rows(name, rows)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
