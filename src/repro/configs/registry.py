"""Architecture registry: the 10 assigned configs + the paper's own SNN
workload, selectable via ``--arch <id>``.

Every entry lives in its own ``configs/<id>.py`` with the exact published
numbers; ``reduced()`` shrinks any config to a CPU-smoke size while
preserving the family structure (period layout, GQA ratio, MoE top-k, SSD)."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "mixtral_8x7b",
    "qwen3_moe_235b_a22b",
    "mistral_nemo_12b",
    "qwen2_5_32b",
    "yi_6b",
    "qwen3_8b",
    "whisper_tiny",
    "mamba2_780m",
    "jamba_1_5_large",
    "internvl2_26b",
]

# public cell ids from the assignment -> module names
ALIASES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2.5-32b": "qwen2_5_32b",
    "yi-6b": "yi_6b",
    "qwen3-8b": "qwen3_8b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-780m": "mamba2_780m",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "internvl2-26b": "internvl2_26b",
}


def get_config(arch: str) -> ArchConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving reduction for CPU smoke tests."""
    kv_ratio = max(1, (cfg.n_heads or 4) // max(cfg.n_kv_heads or 1, 1))
    n_heads = 4
    n_kv = max(1, n_heads // min(kv_ratio, n_heads))
    period = cfg.period
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=len(period) * (2 if len(period) == 1 else 1),
        d_model=64,
        n_heads=n_heads if cfg.n_heads else 0,
        n_kv_heads=n_kv if cfg.n_heads else 0,
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        n_experts=min(4, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        capacity_factor=8.0,   # drop-free at smoke scale (full cfgs keep 1.0)
        ssm_d_state=16 if cfg.ssm_d_state else 0,
        ssm_head_dim=8,
        ssm_chunk=16,
        attn_window=16 if cfg.attn_window else None,
        enc_layers=2 if cfg.enc_layers else 0,
        cross_len=24 if cfg.enc_layers else cfg.cross_len,
        dec_max_len=32,
        n_patches=8,
        remat=False,
    )
