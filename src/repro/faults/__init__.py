"""Deterministic fault injection + detection for the SNN deployment stack.

``plan``   — seeded, immutable ``FaultPlan`` recipes (what goes wrong);
``models`` — the injectors interpreting a plan at the artifact / board /
             lane sites (how it goes wrong);
``detect`` — checksum, canary, trace, and ECC detectors (how it's caught).
"""

from repro.faults.detect import (Canary, ecc_errors, integrity_errors,
                                 runtime_integrity_errors, trace_errors)
from repro.faults.models import (FaultyAEREventQueue, InjectedFault,
                                 LaneFaultInjector, MembraneUpsetInjector,
                                 apply_stuck, corrupt_artifact)
from repro.faults.plan import FaultPlan

__all__ = [
    "FaultPlan", "InjectedFault", "corrupt_artifact", "FaultyAEREventQueue",
    "MembraneUpsetInjector", "apply_stuck", "LaneFaultInjector", "Canary",
    "integrity_errors", "runtime_integrity_errors", "trace_errors",
    "ecc_errors",
]
