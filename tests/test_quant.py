"""Quantization: roundtrip bounds and leak mapping."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.RandomState(seed % 2**32)
    w = rng.randn(32, 16).astype(np.float32)
    q, scale = quant.quantize_weights(w)
    assert q.dtype == np.int8
    err = np.max(np.abs(quant.dequantize(q, scale) - w))
    assert err <= scale / 2 + 1e-7          # round-to-nearest bound


def test_quantize_zero_weights():
    q, scale = quant.quantize_weights(np.zeros((4, 4), np.float32))
    assert np.all(q == 0) and scale == 1.0


def test_leak_shift_monotone():
    shifts = [quant.leak_shift_from_tau(t) for t in (2.0, 8.0, 32.0, 128.0)]
    assert shifts == sorted(shifts)          # longer tau -> weaker leak
    assert quant.leak_shift_from_tau(np.inf) == 31


def test_leak_shift_nonpositive_tau_is_no_leak_sentinel():
    """tau <= 0 is the 'leak disabled' config sentinel: shift 31 means
    v >> 31 == 0 for any plausible membrane, i.e. no leak. Pinned so the
    deployed dynamics can't silently change under a config typo."""
    for tau in (0.0, -1.0, -np.inf):
        assert quant.leak_shift_from_tau(tau) == 31


def test_leak_shift_nan_rejected():
    with pytest.raises(ValueError, match="NaN"):
        quant.leak_shift_from_tau(float("nan"))


def test_leak_shift_very_large_tau_saturates():
    """decay -> 1 as tau grows; the shift saturates at the largest
    representable candidate (15), the weakest realizable leak."""
    assert quant.leak_shift_from_tau(1e6) == 15
    assert quant.leak_shift_from_tau(1e300) == 15
    # and the saturation is stable: larger finite tau cannot decrease it
    assert quant.leak_shift_from_tau(1e12) == 15


def test_leak_shift_tiny_positive_tau_is_strongest_leak():
    """tau -> 0+ gives decay -> 0; the closest realizable decay is
    1 - 2**-1 = 0.5, i.e. shift 1 (the strongest hardware leak)."""
    assert quant.leak_shift_from_tau(1e-9) == 1
