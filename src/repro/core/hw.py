"""Hardware constants for the TPU v5e-class target and the paper's FPGA.

All roofline terms, the deployment planner (the Table-1 "resource utilization"
analogue) and the energy model (the Table-3 analogue) read from here, so the
assumptions live in exactly one place.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuTarget:
    """TPU v5e-class single-chip budget (assignment constants)."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12       # FLOP/s per chip
    hbm_bandwidth: float = 819e9          # bytes/s per chip
    ici_link_bandwidth: float = 50e9      # bytes/s per link
    ici_links_per_chip: int = 4           # 2D torus (v5e-class)
    hbm_bytes: int = 16 * 2**30           # 16 GiB HBM per chip
    vmem_bytes: int = 32 * 2**20          # ~32 MiB VMEM per core (planner budget)
    lane_width: int = 128                 # VREG lane dim == MXU tile dim
    sublane_width: int = 8
    # Energy model constants (order-of-magnitude, labeled estimates — the
    # paper's own energy numbers are tool-based estimates too, UG907).
    pj_per_flop_bf16: float = 0.25
    pj_per_hbm_byte: float = 60.0
    pj_per_vmem_byte: float = 1.0     # on-chip (the BRAM-energy analogue)
    pj_per_ici_byte: float = 120.0


@dataclasses.dataclass(frozen=True)
class FpgaReference:
    """The paper's deployed design point (PYNQ-Z2 / XC7Z020) — for scope-aware
    comparisons in the benchmark harness."""

    name: str = "pynq-z2-80mhz"
    clock_hz: float = 80e6
    first_spike_cycles: int = 12
    service_cycles: int = 11
    service_latency_us: float = 0.1375
    dynamic_energy_nj: float = 31.6
    accuracy_pct: float = 87.40
    neurons_direct: int = 2048            # 16 groups x 128
    groups: int = 16
    neurons_per_group: int = 128
    encodable_neurons: int = 4890
    packed_synapses: int = 843_776
    bram_tiles: int = 140                 # saturated — the design is BRAM-limited


@dataclasses.dataclass(frozen=True)
class BoardCostModel:
    """Cycle/energy model of the PL event datapath driven by the board-runtime
    emulator (``repro.board``). One constant per microarchitectural assumption,
    so the Table-3 analogue is auditable term by term:

      * AER dispatch is pipelined at II=1: each popped event costs
        ``cycles_per_event`` and its int8 weight row is accumulated into all
        ``groups`` hardware groups in parallel (the row spans every lane).
      * The tick boundary (leak shift + integrate + threshold compare +
        first-spike latch) updates every neuron in parallel:
        ``cycles_per_tick`` per tick regardless of network width.
      * The input FIFO has finite depth (the artifact's calibrated E_max);
        events beyond the depth in one tick are never dropped — the ingress
        backpressures, costing ``cycles_per_stall`` per excess event. This is
        the hardware's overflow policy (the TPU runtime reroutes instead).
      * ``cycles_fixed + cycles_decode`` is the zero-event service floor,
        calibrated to the paper's 11-cycle service latency (0.1375 us at
        80 MHz); the grouped TTFS comparator tree costs ``cycles_decode``.
      * Energy terms are per-op dynamic-energy estimates in pJ, the same
        order-of-magnitude discipline as ``TpuTarget`` (the paper's 31.6
        nJ/image is itself a Vivado UG907 tool estimate): one synop is one
        int8 row-element accumulate into an int32 membrane; one neuron-tick
        is one leak-shift + compare; one event is one FIFO push+pop+route.
    """

    name: str = "pynq-z2-pl-model"
    clock_hz: float = 80e6                # PL clock (paper's design point)
    groups: int = 16                      # hardware neuron groups
    lane: int = 128                       # neurons per group
    cycles_per_event: int = 1             # AER pop + row fetch + accumulate
    cycles_per_tick: int = 1              # leak/integrate/fire, all lanes
    cycles_per_stall: int = 1             # FIFO backpressure per excess event
    cycles_fixed: int = 8                 # pipeline fill (ingress + row fetch)
    cycles_decode: int = 3                # grouped TTFS comparator tree
    pj_per_synop: float = 2.0
    pj_per_event: float = 10.0            # FIFO push+pop + router
    pj_per_neuron_tick: float = 1.0
    pj_per_decode: float = 500.0

    @property
    def neurons_direct(self) -> int:
        return self.groups * self.lane


TPU_V5E = TpuTarget()
PYNQ_Z2 = FpgaReference()
PYNQ_COST = BoardCostModel()


def matmul_flops(m: int, k: int, n: int) -> int:
    return 2 * m * k * n


def dyn_energy_joules(flops: float, hbm_bytes: float, ici_bytes: float = 0.0,
                      target: TpuTarget = TPU_V5E) -> float:
    """Dynamic-energy *estimate* (J) from the counter model. Labeled estimate,
    mirroring the paper's Vivado-based PL-dynamic estimates."""
    return (flops * target.pj_per_flop_bf16
            + hbm_bytes * target.pj_per_hbm_byte
            + ici_bytes * target.pj_per_ici_byte) * 1e-12
