"""Jitted public wrapper: pads seq/head dims to block multiples."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_dim, use_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0) -> jnp.ndarray:
    """q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    bq = min(128, max(8, Sq))
    qp = pad_dim(q, 2, bq)
    kp = pad_dim(k, 2, 128)
    vp = pad_dim(v, 2, 128)
    out = flash_attention_kernel(
        qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
        kv_len=Skv, bq=bq, bk=128, interpret=use_interpret())
    return out[:, :, :Sq, :]
