"""Fault injectors — ``FaultPlan`` interpreted at its three injection sites.

Every injector is deterministic given the plan's seed and wraps an EXISTING
hook without forking the clean path:

  * ``corrupt_artifact``      — SEU bit flips in the deployment artifact's
    in-memory arrays (the BRAM image the runtime loads). The per-array
    SHA-256 manifest is deliberately left untouched, so the artifact's own
    integrity check (``Artifact.verify``) is the detector.
  * ``FaultyAEREventQueue``   — AER link glitches (drop / duplicate /
    displace-across-a-tick) and a forced FIFO depth, built ON the clean
    ``AEREventQueue`` schedule; the board runtime records the per-tick
    dispatch histogram either way, which is what the trace detector checks.
  * ``MembraneUpsetInjector`` — SEUs in the membrane BRAM during the tick
    loop, with the parity/ECC detector modeled alongside (single-bit upsets
    are detectable by parity on real FPGAs; the emulator models both the
    upset and the detection, surfaced as per-image ECC hit counts).
  * ``apply_stuck``           — stuck-at neuron groups (a logic defect, NOT
    a memory flip: checksums cannot see it — the canary probes can).
  * ``LaneFaultInjector``     — host-side worker faults around
    ``_Lane.serve``: crash (``InjectedFault``), hang, slowdown.
"""

from __future__ import annotations

import time

import numpy as np

from repro.board.event_queue import AEREventQueue
from repro.board.neuron_core import GroupedNeuronCore
from repro.core.artifact import Artifact, array_hash
from repro.core.quant import INT32_NEVER_FIRE
from repro.faults.plan import MEMBRANE_BITS, FaultPlan


class InjectedFault(RuntimeError):
    """A deliberately injected host-side fault (lane crash)."""


#: artifact arrays the static SEU model can hit, by fault class — the int8
#: weight blocks and the int32 threshold blocks every runtime family loads
WEIGHT_ARRAYS = ("w_padded", "w_int8")
THRESHOLD_ARRAYS = ("thr_padded", "thresholds")


def _flip_bits(arrays: dict[str, np.ndarray], names: tuple[str, ...],
               n_flips: int, rng: np.random.RandomState) -> list[tuple]:
    """Flip ``n_flips`` uniformly random bits across the named arrays
    (in place on the dict's — already copied — entries)."""
    present = [n for n in names if n in arrays and arrays[n].size]
    flips: list[tuple] = []
    for _ in range(n_flips):
        name = present[rng.randint(len(present))]
        a = arrays[name]
        idx = int(rng.randint(a.size))
        bit = int(rng.randint(8 * a.dtype.itemsize))
        flat = a.reshape(-1)
        word = int(flat[idx]) ^ (1 << bit)
        # wrap back into the signed dtype's range (an SEU flips the stored
        # bit pattern; two's complement reinterprets it)
        span = 1 << (8 * a.dtype.itemsize)
        if word >= span // 2:
            word -= span
        elif word < -span // 2:
            word += span
        flat[idx] = word
        flips.append((name, idx, bit))
    return flips


def corrupt_artifact(art: Artifact, plan: FaultPlan) -> Artifact:
    """SEU-corrupted in-memory clone of the artifact: seeded bit flips in the
    weight / threshold blocks, manifest and fingerprint left as exported —
    so ``Artifact.verify`` (the checksum detector) fails loudly on it while
    the original stays pristine for the scrub/reload recovery path."""
    if not plan.has_static:
        return art
    meta = dict(art.meta)
    if not meta.get("manifest"):
        # an in-memory artifact that was never exported: stamp the manifest
        # and fingerprint from the PRISTINE arrays first (exactly what
        # ``Artifact.save`` would have recorded), so the SEU is detectable
        meta["manifest"] = {k: array_hash(v) for k, v in art.arrays.items()}
        meta["fingerprint"] = Artifact(meta, art.arrays).fingerprint()
    arrays = dict(art.arrays)
    for names, n, stream in ((WEIGHT_ARRAYS, plan.seu_weight_flips, "seu-w"),
                             (THRESHOLD_ARRAYS, plan.seu_threshold_flips,
                              "seu-thr")):
        if n:
            for name in names:
                if name in arrays:
                    arrays[name] = arrays[name].copy()
            _flip_bits(arrays, names, n, plan.rng(stream))
    return Artifact(meta, arrays)


class FaultyAEREventQueue(AEREventQueue):
    """The AER ingress behind a glitching link: events may be dropped,
    duplicated, or displaced across one tick boundary — deterministically
    from ``(plan.seed, image_key)``. The perturbed schedule preserves the
    iteration contract (``events_at``/``counts``/``stalls_at``), so the
    board loop is unchanged; only WHAT arrives differs."""

    def __init__(self, times: np.ndarray, T: int, depth: int,
                 plan: FaultPlan, image_key: int = 0):
        super().__init__(times, T, depth)
        rng = plan.rng("aer", int(image_key))
        self.injected_drops = self.injected_dups = self.injected_moves = 0
        buckets: list[list[int]] = [[] for _ in range(T)]
        for t in range(T):
            for nid in super().events_at(t):
                if plan.aer_drop_rate and rng.rand() < plan.aer_drop_rate:
                    self.injected_drops += 1
                    continue
                tt = t
                if (plan.aer_reorder_rate
                        and rng.rand() < plan.aer_reorder_rate):
                    tt = min(T - 1, max(0, t + (1 if rng.rand() < 0.5
                                                else -1)))
                    if tt != t:
                        self.injected_moves += 1
                buckets[tt].append(int(nid))
                if plan.aer_dup_rate and rng.rand() < plan.aer_dup_rate:
                    buckets[tt].append(int(nid))
                    self.injected_dups += 1
        self._buckets = [np.asarray(sorted(b), np.int32) for b in buckets]
        self.total_events = int(sum(len(b) for b in self._buckets))

    def events_at(self, t: int) -> np.ndarray:
        return self._buckets[t]


class MembraneUpsetInjector:
    """Per-image membrane-BRAM SEU source plus its parity detector: after
    each tick, with probability ``seu_membrane_rate``, one bit of one
    neuron's int32 membrane flips — and the modeled ECC logic records the
    hit (``ecc_hits``), which the serving tier turns into a re-serve."""

    def __init__(self, plan: FaultPlan, image_key: int = 0):
        self.rate = float(plan.seu_membrane_rate)
        self._rng = plan.rng("membrane", int(image_key))
        self.ecc_hits = 0

    def after_tick(self, core: GroupedNeuronCore, t: int) -> None:
        if not self.rate or self._rng.rand() >= self.rate:
            return
        g = int(self._rng.randint(core.groups_used))
        li = int(self._rng.randint(core.lane))
        bit = int(self._rng.randint(MEMBRANE_BITS))
        word = int(core.v[g, li]) ^ (1 << bit)
        if word >= 2 ** 31:
            word -= 2 ** 32
        core.v[g, li] = np.int32(word)
        self.ecc_hits += 1


def apply_stuck(core: GroupedNeuronCore, plan: FaultPlan,
                n_out: int | None = None) -> list[int]:
    """Force ``plan.stuck_groups`` hardware groups stuck-at: ``saturated``
    (threshold pinned to INT32_MIN — fires at tick 0 unconditionally) or
    ``silent`` (threshold pinned to never-fire). When ``n_out`` is given the
    afflicted groups are drawn from those carrying output neurons (a stuck
    group past the readout is architecturally harmless). Returns the
    afflicted group indices. A logic fault, not a memory flip: invisible to
    the checksum detector by design; the canary probes catch it."""
    if not plan.stuck_groups:
        return []
    if plan.stuck_mode not in ("silent", "saturated"):
        raise ValueError(f"unknown stuck_mode {plan.stuck_mode!r} "
                         "(use 'saturated' or 'silent')")
    rng = plan.rng("stuck")
    span = core.groups_used
    if n_out is not None:
        span = min(span, -(-int(n_out) // core.lane))
    k = min(int(plan.stuck_groups), span)
    groups = sorted(int(g) for g in rng.choice(span, size=k, replace=False))
    val = (np.int32(INT32_NEVER_FIRE) if plan.stuck_mode == "silent"
           else np.int32(np.iinfo(np.int32).min))
    for g in groups:
        core.thr[g, :] = val
    return groups


class LaneFaultInjector:
    """Host-side worker faults, keyed by the lane-local batch index: crash
    (raise before serving), hang (sleep past any sane watchdog), slowdown
    (fixed added latency). ``disarm()`` is the circuit breaker's hook — a
    degraded lane bypasses the faulted datapath, injector included."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.batches = 0
        self.crashes = self.hangs = self.slowdowns = 0

    def before_batch(self) -> None:
        i = self.batches
        self.batches += 1
        p = self.plan
        if p.slow_s:
            self.slowdowns += 1
            time.sleep(p.slow_s)
        if i in p.hang_batches:
            self.hangs += 1
            time.sleep(p.hang_s)
        if i in p.crash_batches:
            self.crashes += 1
            raise InjectedFault(f"injected lane crash at batch {i} "
                                f"(plan seed {p.seed})")

    def disarm(self) -> None:
        self.plan = FaultPlan.none(seed=self.plan.seed)
