"""Jitted public wrapper for the grouped TTFS decode kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.ttfs_decode.kernel import ttfs_decode_kernel


@functools.partial(jax.jit, static_argnames=("n_groups", "per_group",
                                             "sentinel", "fallback"))
def ttfs_decode(first_spike: jnp.ndarray, v_final: jnp.ndarray, *,
                n_groups: int, per_group: int, sentinel: int,
                fallback: str = "membrane") -> jnp.ndarray:
    return ttfs_decode_kernel(first_spike, v_final, n_groups=n_groups,
                              per_group=per_group, sentinel=sentinel,
                              fallback=fallback, interpret=use_interpret())
