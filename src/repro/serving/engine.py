"""Batched serving engine: continuous prefill+decode over a request queue.

Scope-aware by construction (the paper's measurement discipline):
  * accelerator-scope — jitted decode_step execution time only;
  * system-scope — queueing, batching, tokenizer-stub, host<->device
    transfers, sampling, detokenize.
Both are reported separately by the stats() method, mirroring the paper's
PL-only vs host-inclusive split.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, lm: LM, params, *, max_batch: int = 8,
                 s_max: int = 256, eos: int | None = None):
        self.lm, self.params = lm, params
        self.max_batch, self.s_max, self.eos = max_batch, s_max, eos
        self._decode = jax.jit(lm.decode_step)
        self.accel_s = 0.0
        self.system_s = 0.0
        self.tokens_out = 0

    def _greedy(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)

    def generate(self, prompts: Sequence[np.ndarray], max_new: int = 16
                 ) -> list[list[int]]:
        t_sys0 = time.perf_counter()
        results: list[list[int]] = []
        for i in range(0, len(prompts), self.max_batch):
            chunk = prompts[i:i + self.max_batch]
            results.extend(self._generate_batch(chunk, max_new))
        self.system_s += time.perf_counter() - t_sys0
        return results

    def _generate_batch(self, prompts, max_new: int) -> list[list[int]]:
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for b, p in enumerate(prompts):
            toks[b, S - len(p):] = p                 # left-pad (greedy-safe)
        cache = self.lm.init_cache(B, self.s_max,
                                   dtype=self.params["embed"].dtype)
        x = jnp.asarray(toks)
        # prefill token-by-token through the jitted decode step (one compiled
        # program serves both phases; production prefill would batch this)
        logits = None
        for t in range(S):
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, x[:, t:t + 1])
            jax.block_until_ready(logits)
            self.accel_s += time.perf_counter() - t0
        outs = [[] for _ in range(B)]
        cur = self._greedy(logits)
        done = np.zeros(B, bool)
        for _ in range(max_new):
            for b in range(B):
                if not done[b]:
                    outs[b].append(int(cur[b]))
                    if self.eos is not None and cur[b] == self.eos:
                        done[b] = True
            if done.all():
                break
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur[:, None]))
            jax.block_until_ready(logits)
            self.accel_s += time.perf_counter() - t0
            cur = self._greedy(logits)
            self.tokens_out += int(np.sum(~done))
        return outs

    def stats(self) -> dict:
        return {
            "accelerator_s": self.accel_s,
            "system_s": self.system_s,
            "host_overhead_s": max(0.0, self.system_s - self.accel_s),
            "tokens_out": self.tokens_out,
        }
