"""Fault-tolerance demo: kill/restore + host churn + straggler response.

Simulates a 4-host data-parallel training job in-process:
  1. trains with deterministic per-host data shards,
  2. "crashes" after step 5 (state discarded),
  3. restores from the atomic checkpoint and replays to step 10 —
     asserts the trajectory is bit-identical to an uninterrupted run,
  4. kills host h2: rendezvous reassignment moves ONLY h2's shards,
  5. a straggler appears: work shares rebalance inversely to speed.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, reduced
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.model import LM
from repro.training import lm_step, optim as O
from repro.training.checkpoint import CheckpointManager
from repro.training.elastic import (StragglerMonitor, rebalance,
                                    shard_assignment)

CKPT = "/tmp/repro_elastic_demo"

cfg = reduced(get_config("yi-6b"))
lm = LM(cfg)
params0 = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
optimizer = O.adamw(lr=1e-3)
step = jax.jit(lm_step.make_train_step(lm, optimizer))
pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                         global_batch=8, n_hosts=4))

# --- uninterrupted run (ground truth) -----------------------------------
p, o = params0, optimizer.init(params0)
for i in range(10):
    p, o, _ = step(p, o, jax.tree.map(jnp.asarray, pipe.global_batch_at(i)))
truth = jax.tree.leaves(p)

# --- crash at 5, restore, replay ------------------------------------------
mgr = CheckpointManager(CKPT, keep=1)
p, o = params0, optimizer.init(params0)
for i in range(5):
    p, o, _ = step(p, o, jax.tree.map(jnp.asarray, pipe.global_batch_at(i)))
mgr.save(5, {"params": p, "opt": o})
print("step 5: checkpoint saved; simulating crash (state dropped)")
del p, o

at, restored = mgr.restore({"params": params0, "opt": optimizer.init(params0)})
p, o = restored["params"], restored["opt"]
print(f"restored at step {at}; data pipeline regenerates shards "
      "deterministically per (seed, step, host)")
for i in range(at, 10):
    p, o, _ = step(p, o, jax.tree.map(jnp.asarray, pipe.global_batch_at(i)))
ok = all(np.array_equal(np.asarray(a), np.asarray(b))
         for a, b in zip(truth, jax.tree.leaves(p)))
print(f"post-restore trajectory bit-identical to uninterrupted run: {ok}")
assert ok

# --- host failure: minimal-movement reassignment ---------------------------
hosts = ["h0", "h1", "h2", "h3"]
assign = shard_assignment(hosts, 16)
new, moved = rebalance(assign, ["h0", "h1", "h3"])
print(f"h2 died: {len(moved)}/{16} shards moved "
      f"(only h2's: {moved}); survivors keep their shards")

# --- straggler mitigation ---------------------------------------------------
mon = StragglerMonitor()
for _ in range(10):
    for h, t in [("h0", 1.0), ("h1", 1.02), ("h3", 0.98), ("h2*", 2.4)]:
        mon.record(h, t)
shares = mon.work_shares(["h0", "h1", "h3", "h2*"])
print(f"stragglers detected: {mon.stragglers()}; "
      f"rebalanced work shares: "
      + ", ".join(f"{h}={s:.2f}" for h, s in sorted(shares.items())))
print("demo complete.")
