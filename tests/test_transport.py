"""The TCP program-distribution transport + its fault-injection harness.

Covers the frame codec (every header rejection path named), the
server/fetcher pair over real sockets, bounded retries with seeded-jitter
backoff (reproducible schedules), telemetry spans and the metrics surface
``ServingScheduler.stats()`` reports, the transport grammar, the
end-to-end tcp broadcast, and the full fault-proxy scenario sweep's
*detected-or-bit-exact* invariant.
"""

import socket
import threading

import pytest

from repro.conformance.fuzz import fuzz_case
from repro.conformance.transport_faults import SCENARIOS, run_suite
from repro.core.lowering import ProgramCache, install, lower
from repro.core.program_io import (ProgramIOError, envelope_digest,
                                   serialize_program)
from repro.distributed import transport as tp
from repro.launch.cluster import Endpoint, parse_transport
from repro.launch.mesh import broadcast_program
from repro.telemetry import trace as ttrace


@pytest.fixture()
def scoped_cache():
    cache = ProgramCache()
    prev = install(cache)
    yield cache
    install(prev)


@pytest.fixture(scope="module")
def envelope():
    """A real fuzzed artifact + its serialized program envelope."""
    art = fuzz_case(11).artifact
    prog = lower(art, cache=False)
    return art, prog, serialize_program(prog)


def _serve_raw(data: bytes) -> tuple[str, int, threading.Thread]:
    """One-shot raw-byte server for crafting invalid frames on the wire."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.bind(("127.0.0.1", 0))
    sock.listen(1)
    host, port = sock.getsockname()

    def serve():
        conn, _ = sock.accept()
        conn.sendall(data)
        conn.close()
        sock.close()

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    return host, port, t


# ------------------------------------------------------------ frame codec
def test_frame_roundtrip():
    payload = b'{"hello": "program"}'
    frame = tp.encode_frame(payload)
    length, digest = tp.decode_header(frame[:tp.HEADER_LEN])
    assert length == len(payload)
    assert frame[tp.HEADER_LEN:] == payload
    assert digest == bytes.fromhex(envelope_digest(payload))


def test_frame_header_rejections_name_the_corruption():
    frame = bytearray(tp.encode_frame(b"payload"))
    with pytest.raises(tp.FrameError, match="header is 3 bytes"):
        tp.decode_header(bytes(frame[:3]))
    bad = frame.copy()
    bad[0] ^= 0xFF
    with pytest.raises(tp.FrameError, match="magic"):
        tp.decode_header(bytes(bad[:tp.HEADER_LEN]))
    bad = frame.copy()
    bad[4] = 99
    with pytest.raises(tp.FrameError, match="wire version 99"):
        tp.decode_header(bytes(bad[:tp.HEADER_LEN]))
    bad = frame.copy()
    bad[5:13] = (tp.MAX_ENVELOPE_BYTES + 1).to_bytes(8, "big")
    with pytest.raises(tp.FrameError, match="transport cap"):
        tp.decode_header(bytes(bad[:tp.HEADER_LEN]))
    bad = frame.copy()
    bad[5:13] = (0).to_bytes(8, "big")
    with pytest.raises(tp.FrameError, match="non-positive"):
        tp.decode_header(bytes(bad[:tp.HEADER_LEN]))
    with pytest.raises(tp.FrameError, match="transport cap"):
        tp.encode_frame(b"\x00" * (tp.MAX_ENVELOPE_BYTES + 1))


def test_checksum_mismatch_detected_on_the_wire():
    frame = bytearray(tp.encode_frame(b"the quick brown program"))
    frame[-1] ^= 0x01                      # flip a payload byte
    host, port, t = _serve_raw(bytes(frame))
    with pytest.raises(tp.FetchRetriesExhausted) as ei:
        tp.fetch_bytes(host, port, retries=0, read_timeout_s=1.0)
    assert isinstance(ei.value.last, tp.FrameError)
    assert "checksum mismatch" in str(ei.value.last)
    t.join(timeout=5)


def test_truncation_detected_on_the_wire():
    frame = tp.encode_frame(b"cut short")
    host, port, t = _serve_raw(frame[:-2])
    with pytest.raises(tp.FetchRetriesExhausted) as ei:
        tp.fetch_bytes(host, port, retries=0, read_timeout_s=1.0)
    assert "truncated frame" in str(ei.value.last)
    t.join(timeout=5)


# -------------------------------------------------------- server + fetcher
def test_server_fetch_is_bit_identical(envelope):
    _, _, blob = envelope
    with tp.ProgramServer(blob) as srv:
        got = tp.fetch_bytes(srv.host, srv.port)
        assert got == blob
        assert envelope_digest(got) == envelope_digest(blob)


def test_server_counts_serves_and_awaits(envelope):
    _, _, blob = envelope
    with tp.ProgramServer(blob) as srv:
        assert not srv.await_serves(1, timeout_s=0.05)
        for _ in range(3):
            tp.fetch_bytes(srv.host, srv.port)
        assert srv.await_serves(3, timeout_s=5.0)
        assert srv.serves == 3
    assert srv.endpoint == f"tcp://127.0.0.1:{srv.port}"


def test_fetch_from_dead_endpoint_exhausts_retries():
    # bind-then-close: the port exists but nothing listens -> refused
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    before = tp.metrics_snapshot().get("fetch_failures", 0)
    with pytest.raises(tp.FetchRetriesExhausted) as ei:
        tp.fetch_bytes("127.0.0.1", port, retries=2, backoff_s=0.005,
                       connect_timeout_s=0.5)
    assert ei.value.attempts == 3
    assert tp.metrics_snapshot().get("fetch_failures", 0) == before + 1


def test_backoff_schedule_is_seeded_and_exponential():
    a = tp.backoff_schedule(4, 0.05, seed=3)
    b = tp.backoff_schedule(4, 0.05, seed=3)
    c = tp.backoff_schedule(4, 0.05, seed=4)
    assert a == b, "same seed must replay the same jitter"
    assert a != c, "different seeds must not thundering-herd in lockstep"
    for i, sleep in enumerate(a):
        base = 0.05 * (2 ** i)
        assert base <= sleep < 2 * base, "jitter must stay in [1, 2)x"


# --------------------------------------------------------------- telemetry
def test_publish_and_fetch_emit_spans(envelope):
    _, _, blob = envelope
    tracer = ttrace.Tracer()
    prev = ttrace.install(tracer)
    try:
        publish = tp.tcp_publisher()
        publish(blob)
        server = publish.server
        try:
            tp.fetch_bytes(server.host, server.port)
        finally:
            server.stop()
    finally:
        ttrace.install(prev)
    (pub,) = tracer.find("transport.publish")
    assert pub.scope == "system"
    assert pub.attrs["bytes"] == len(blob)
    (fetch,) = tracer.find("transport.fetch")
    assert fetch.scope == "system"
    assert fetch.attrs == {"bytes": len(blob), "attempts": 1, "retries": 0}
    # endpoint is host context, not canonical
    assert "endpoint" in fetch.meta and "endpoint" not in fetch.attrs


def test_scheduler_stats_surface_transport_health(trained_artifact,
                                                  scoped_cache):
    from repro.serving.scheduler import ServingScheduler
    art, _, _ = trained_artifact
    blob = serialize_program(lower(art))
    tp.reset_metrics()
    with tp.ProgramServer(blob) as srv:
        tp.fetch_bytes(srv.host, srv.port)
    with ServingScheduler(art, spec="reference", workers=1,
                          max_batch=4) as s:
        st = s.stats()
    assert st["transport_fetches"] == 1
    assert st["transport_serves"] == 1
    assert st["transport_fetch_bytes"] == len(blob)
    assert st["transport_fetch_retries"] == 0
    assert st["transport_fetch_failures"] == 0
    assert st["transport_fetch_ms_p95"] > 0.0


# ------------------------------------------------------- transport grammar
def test_parse_transport_grammar():
    assert parse_transport("tcp://10.0.0.7:7070") == Endpoint(
        scheme="tcp", host="10.0.0.7", port=7070)
    assert parse_transport("tcp://leader:0").port == 0
    assert parse_transport("file:///shared/prog.json") == Endpoint(
        scheme="file", path="/shared/prog.json")
    assert parse_transport("/shared/prog.json") == Endpoint(
        scheme="file", path="/shared/prog.json")
    for bad, why in (("", "empty"), ("tcp://noport", "HOST:PORT"),
                     ("tcp://h:notanint", "not an integer"),
                     ("tcp://h:70000", "out of range"),
                     ("file://", "empty path"),
                     ("udp://h:1", "unknown transport scheme")):
        with pytest.raises(ValueError, match=why):
            parse_transport(bad)


# -------------------------------------------------------- tcp broadcast e2e
def test_broadcast_over_tcp(envelope, scoped_cache):
    art, leader_prog, blob = envelope
    publish = tp.tcp_publisher()
    publish(serialize_program(lower(art)))
    server = publish.server
    try:
        follower_cache = ProgramCache()
        prev = install(follower_cache)
        try:
            follower = broadcast_program(
                art, leader=False,
                fetch=tp.tcp_fetcher(server.host, server.port))
        finally:
            install(prev)
    finally:
        server.stop()
    assert follower.fingerprint == leader_prog.fingerprint
    st = follower_cache.stats()
    assert st["programs"] == 1 and st["program_misses"] == 0


def test_fetch_program_verifies_against_wrong_artifact(envelope):
    art, _, blob = envelope
    other = fuzz_case(12).artifact
    with tp.ProgramServer(blob) as srv:
        with pytest.raises(ProgramIOError, match="artifact fingerprint"):
            tp.fetch_program(srv.host, srv.port, other, cache=False)


# ------------------------------------------------- fault-proxy conformance
def test_fault_suite_holds_detected_or_bitexact(envelope):
    art, prog, blob = envelope
    stale = serialize_program(lower(fuzz_case(12).artifact, cache=False))
    verdicts = run_suite(blob, art, prog.fingerprint, stale_blob=stale,
                         seed=5)
    assert len(verdicts) == len(SCENARIOS) >= 20
    bad = [v for v in verdicts if not v["ok"]]
    assert not bad, "; ".join(
        f"{v['scenario']}: expected {v['expect']}, got {v['outcome']} "
        f"({v['detail']})" for v in bad)
    # the invariant's hard floor: NOTHING may silently diverge or crash
    # untyped, even if an expectation is wrong
    assert all(v["outcome"] in ("detected", "bitexact") for v in verdicts)


def test_detected_failures_name_the_corruption(envelope):
    art, prog, blob = envelope
    by_name = {s.name: s for s in SCENARIOS}
    checks = {"flip-checksum": "checksum mismatch",
              "truncate-last-byte": "truncated frame",
              "flip-version": "wire version",
              "tamper-array-hash-reframed": "hash mismatch"}
    from repro.conformance.transport_faults import run_scenario
    for name, needle in checks.items():
        v = run_scenario(by_name[name], blob=blob, artifact=art,
                         leader_fingerprint=prog.fingerprint)
        assert v["outcome"] == "detected", v
        assert needle in v["detail"], (name, v["detail"])
