"""Per-image board scheduler — the readable audit path of the emulator.

``SNNBoard`` consumes the SAME deployment artifact as ``SNNReference`` and
``SNNAccelerator`` (no conversion stage) and executes the paper's PL loop one
image at a time, one tick at a time:

    TTFS encode -> AER queue -> per-tick event dispatch into the grouped
    neuron core -> leak/integrate/fire -> grouped TTFS first-spike decode

with every tick's cycle and energy cost accounted against the board cost
model. ``latency_mode=True`` stops at the tick of the first output spike
(the paper's TTFS decision point — this is what the 0.1375 us/image service
latency measures); the default full-T mode runs the whole window so
first-spike times are bit-exact with the software reference on ALL neurons,
which is what the three-way agreement harness compares.

This path is deliberately plain Python/numpy — small, steppable, and slow.
``board.batched.SNNBoardBatched`` is the vectorized fast path proven
bit-exact against it (outputs AND traces).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.board.energy import BoardTrace, account, span_attrs, stack_traces
from repro.board.event_queue import AEREventQueue
from repro.board.neuron_core import GroupedNeuronCore
from repro.core import ttfs
from repro.core.artifact import Artifact
from repro.core.hw import BoardCostModel, PYNQ_COST
from repro.core.lowering import LoweredProgram, lower
from repro.core.types import SNNOutput, decode_output
from repro.telemetry import trace as ttrace


class SNNBoard:
    def __init__(self, artifact: Artifact | LoweredProgram, *,
                 latency_mode: bool = False,
                 cost: BoardCostModel = PYNQ_COST, faults=None):
        prog = lower(artifact)
        self.program = prog
        self.art = prog.artifact
        self.cost = cost
        self.latency_mode = bool(latency_mode)
        self.T = prog.T
        self.x_min = prog.x_min
        self.n_out = prog.n_out
        self.depth = prog.e_max
        self.core = GroupedNeuronCore.from_program(prog, cost)
        self.n_pad = self.core.n_pad
        # dynamic fault plan (repro.faults.FaultPlan), interpreted per image
        # by the tick loop; None / a clean plan leaves the datapath bit-exact
        self.plan = faults
        self.stuck_groups: list[int] = []
        if faults is not None and faults.fifo_depth is not None:
            self.depth = int(faults.fifo_depth)
        if faults is not None and faults.stuck_groups:
            from repro.faults.models import apply_stuck
            self.stuck_groups = apply_stuck(self.core, faults,
                                            n_out=self.n_out)
        self.last_trace: BoardTrace | None = None
        # per-forward observability (the trace / ECC detectors read these):
        # (B, T) events dispatched per tick, (B,) membrane parity hits
        self.last_tick_counts: np.ndarray | None = None
        self.last_ecc: np.ndarray | None = None

    # ------------------------------------------------------------- one image
    def _make_queue(self, times: np.ndarray, image_key: int):
        if self.plan is not None and self.plan.has_aer_faults:
            from repro.faults.models import FaultyAEREventQueue
            return FaultyAEREventQueue(times, self.T, self.depth, self.plan,
                                       image_key)
        return AEREventQueue(times, self.T, self.depth)

    def run_image(self, times: np.ndarray, image_key: int = 0
                  ) -> tuple[np.ndarray, np.ndarray, int, BoardTrace]:
        """times (N_in,) int spike times -> (first (n_pad,), v (n_pad,),
        ticks_executed, trace). Also records the per-tick dispatch histogram
        and membrane-parity hits on ``self`` for the batch front-end."""
        queue = self._make_queue(times, image_key)
        upset = None
        if self.plan is not None and self.plan.seu_membrane_rate:
            from repro.faults.models import MembraneUpsetInjector
            upset = MembraneUpsetInjector(self.plan, image_key)
        core = self.core
        core.reset()
        events = stalls = 0
        ticks = self.T
        tick_counts = np.zeros(self.T, np.int64)
        for t, ids in queue:
            for nid in ids:
                core.dispatch(int(nid))
            tick_counts[t] = len(ids)
            events += len(ids)
            stalls += queue.stalls_at(t)
            fired = core.tick(t)
            if upset is not None:
                upset.after_tick(core, t)
            if self.latency_mode and fired:
                ticks = t + 1
                break
        self._last_tick_counts_row = tick_counts
        self._last_ecc_row = upset.ecc_hits if upset is not None else 0
        trace = account(events, ticks, stalls, core.n_pad, self.cost)
        return core.first_flat.copy(), core.v_flat.copy(), ticks, trace

    # ------------------------------------------------------------- batch API
    def forward(self, images) -> SNNOutput:
        # telemetry: the span tree here (board.forward -> encode / run
        # [/ image x B] / decode, impl in META so the canonical form matches
        # the batched fast path bit for bit) is a deterministic projection
        # of the cost-model account — no-ops unless a Tracer is installed
        rec = ttrace.get()
        images = np.atleast_2d(np.asarray(images, np.float32))
        fwd = rec.begin("board.forward", "system",
                        attrs={"batch": int(images.shape[0]), "T": self.T},
                        meta={"impl": "board-py"}) if rec.enabled else None
        enc = rec.begin("board.encode", "system", trace=fwd.trace,
                        parent=fwd.sid,
                        attrs={"n_in": int(images.shape[1])}) \
            if fwd is not None else None
        times = np.asarray(ttfs.encode_ttfs(jnp.asarray(images), self.T,
                                            self.x_min))
        rec.end(enc)
        run = rec.begin("board.run", "accel", trace=fwd.trace,
                        parent=fwd.sid) if fwd is not None else None
        firsts, vs, steps, traces = [], [], [], []
        tick_counts, eccs = [], []
        for key, row in enumerate(times):
            first, v, ticks, trace = self.run_image(row, image_key=key)
            firsts.append(first[:self.n_out])
            vs.append(v[:self.n_out])
            steps.append(ticks)
            traces.append(trace)
            tick_counts.append(self._last_tick_counts_row)
            eccs.append(self._last_ecc_row)
        first_l = np.stack(firsts)
        v_l = np.stack(vs)
        self.last_trace = stack_traces(traces)
        self.last_tick_counts = np.stack(tick_counts)
        self.last_ecc = np.asarray(eccs, np.int64)
        if run is not None:
            totals, per = span_attrs(self.last_trace)
            rec.end(run, attrs=totals)
            for a in per:
                rec.emit("board.image", "accel", trace=run.trace,
                         parent=run.sid, attrs=a)
        dec = rec.begin("board.decode", "accel", trace=fwd.trace,
                        parent=fwd.sid, attrs={"n_out": self.n_out}) \
            if fwd is not None else None
        labels = np.asarray(decode_output(first_l, v_l, self.program.decode))
        rec.end(dec)
        rec.end(fwd)
        return SNNOutput(labels=labels, first_spike=first_l, v_final=v_l,
                         steps=np.asarray(steps, np.int32))

    __call__ = forward
