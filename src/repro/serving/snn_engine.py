"""Batched SNN serving engine — the synchronous facade over the scheduler.

``SNNServeEngine`` keeps the submit()/flush()/classify() surface but owns no
serving logic anymore: micro-batching, the overflow→dense reroute, board
cycle/energy accounting, and every stat (scope split, latency percentiles,
queue depth) live in ``serving.scheduler.ServingScheduler`` — one code path
shared with the continuous-batching load bench, so the sync and async tiers
cannot drift apart.

Mirrors ``ServeEngine``'s measurement discipline (the paper's §2.3 split):
  * accelerator-scope — jitted device execution only (block_until_ready
    around the compiled forward);
  * system-scope — everything a request actually pays: queueing, TTFS
    encode, host-side spike packing, micro-batching, dispatch, readback.

Micro-batching pads every chunk to the engine's fixed ``max_batch`` so ONE
compiled program (the artifact's padded shapes) serves all traffic — no
recompiles as request counts vary, which is what "serve heavy traffic" needs.
Rows whose event frames exceed the artifact's calibrated E_max are NOT
dropped: the scheduler falls back to the dense time-batched path for exactly
those rows (the co-design overflow policy — the FPGA would backpressure, we
reroute), and counts the reroutes in stats.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.artifact import Artifact
from repro.serving.scheduler import (ServeRequest, ServingError,
                                     ServingScheduler)

# back-compat alias: completed requests returned by flush() used to be
# SNNRequest instances; they are the scheduler's ServeRequest now
SNNRequest = ServeRequest

_BACKEND_SPECS = {"accelerator": "accelerator-event", "board": "board-batched"}


class SNNServeEngine:
    """Request-queue classifier serving: submit() → flush() → labels.

    ``backend`` selects the runtime behind the queue:
      * "accelerator" (default) — the packed-event TPU path; ``kernel``
        selects its implementation ("fused" = the event→LIF→decode
        megakernel, the default; "jnp"/"pallas" = the staged pipeline).
      * "board" — the board-runtime emulator's batched fast path; ``kernel``
        selects its LIF implementation ("jnp" default, "pallas"); every
        flush additionally accounts PL cycles and dynamic energy (the
        Table-3 analogue), surfaced in ``stats()``. The board never drops
        overflow events (FIFO backpressure costs cycles instead), so the
        dense reroute path does not apply.

    ``kernel=None`` means the backend's own default; an explicit kernel is
    forwarded to whichever backend is selected (a board engine asked for
    "pallas" really runs the Pallas LIF — and one asked for the
    accelerator-only "fused" fails loudly instead of silently running jnp).

    ``latency_mode`` serves with per-row early exit at the first output
    spike (the paper's TTFS decision latency).

    ``workers=0`` (default) serves synchronously inside flush() — the
    deterministic facade mode; ``workers>=1`` hands the queue to that many
    continuous-batching worker lanes (see ``serving.scheduler``)."""

    def __init__(self, artifact: Artifact, *, max_batch: int = 64,
                 kernel: str | None = None, latency_mode: bool = False,
                 backend: str = "accelerator", workers: int = 0,
                 max_wait_us: float = 2000.0, faults=None, resilience=None,
                 canary_pool: np.ndarray | None = None):
        if backend not in _BACKEND_SPECS:
            raise ValueError(f"unknown backend {backend!r}")
        self.art = artifact
        self.backend = backend
        self.max_batch = int(max_batch)
        self.latency_mode = bool(latency_mode)
        if kernel is None:
            kernel = "fused" if backend == "accelerator" else "jnp"
        self.sched = ServingScheduler(
            artifact, spec=_BACKEND_SPECS[backend], workers=workers,
            max_batch=max_batch, max_wait_us=max_wait_us, kernel=kernel,
            latency_mode=latency_mode, faults=faults, resilience=resilience,
            canary_pool=canary_pool)
        # the facade's runtime (lane 0's) — kept as .accel for back-compat
        self.accel = self.sched.lanes[0].runtime
        self._unclaimed: dict[int, ServeRequest] = {}

    # ----------------------------------------------------------------- queue
    def submit(self, image: np.ndarray) -> int:
        return self.sched.submit(image)

    def flush(self) -> dict[int, SNNRequest]:
        """Serve every queued request; returns {rid: completed request} for
        ALL completed-but-unclaimed requests — including ones submitted by
        earlier callers whose results a classify() batch completed but did
        not claim."""
        done = self._unclaimed
        self._unclaimed = {}
        done.update(self.sched.drain())
        return done

    def classify(self, images: Sequence[np.ndarray] | np.ndarray
                 ) -> np.ndarray:
        """Convenience batch API: images (B, N_in) -> labels (B,) int32.

        Claims ONLY its own requests; anything else completed by the flush
        is preserved for the submitting caller's next flush()."""
        rids = [self.submit(img) for img in np.asarray(images, np.float32)]
        done = self.flush()
        out = [done.pop(r) for r in rids]
        self._unclaimed.update(done)
        for r in out:
            if r.error is not None:
                # never hand back a fabricated label for a failed request
                raise ServingError(r)
        return np.asarray([r.label for r in out], np.int32)

    def close(self) -> None:
        self.sched.close()

    # ----------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        """Zero the counters (e.g. after a warmup pass, so compile time does
        not pollute the measured trajectory)."""
        self.sched.reset_stats()

    def stats(self) -> dict:
        return {"backend": self.backend, **self.sched.stats()}
