"""Data pipelines: procedural MNIST + token stream determinism."""

import numpy as np

from repro.data import mnist
from repro.data.tokens import TokenPipeline, TokenPipelineConfig


def test_mnist_deterministic_and_valid():
    x1, y1 = mnist.generate(256, seed=3)
    x2, y2 = mnist.generate(256, seed=3)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    assert x1.shape == (256, 784) and x1.dtype == np.float32
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    assert set(np.unique(y1)) <= set(range(10))


def test_mnist_classes_are_linearly_separable_enough():
    """A trivial nearest-centroid classifier should beat 60% — the dataset
    must carry real class signal for the accuracy claims to mean anything."""
    xtr, ytr = mnist.generate(2000, seed=11)
    xte, yte = mnist.generate(500, seed=12)
    cents = np.stack([xtr[ytr == c].mean(0) for c in range(10)])
    pred = np.argmin(((xte[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    assert (pred == yte).mean() > 0.6


def test_token_pipeline_deterministic_per_step_host():
    cfg = TokenPipelineConfig(vocab=1000, seq_len=32, global_batch=8, n_hosts=4)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 7):
        for host in range(4):
            a, b = p1.host_batch(step, host), p2.host_batch(step, host)
            assert np.array_equal(a["tokens"], b["tokens"])
    # different hosts / steps differ
    assert not np.array_equal(p1.host_batch(0, 0)["tokens"],
                              p1.host_batch(0, 1)["tokens"])
    assert not np.array_equal(p1.host_batch(0, 0)["tokens"],
                              p1.host_batch(1, 0)["tokens"])


def test_token_pipeline_labels_are_shifted_tokens():
    cfg = TokenPipelineConfig(vocab=100, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).host_batch(0, 0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    # autoregressive alignment: labels[t] continues tokens[t]
    TokenPipeline(cfg)._host_rng(0, 0)  # smoke: rng accessible
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_global_batch_concatenates_hosts():
    cfg = TokenPipelineConfig(vocab=50, seq_len=8, global_batch=8, n_hosts=2)
    pipe = TokenPipeline(cfg)
    g = pipe.global_batch_at(3)
    h0, h1 = pipe.host_batch(3, 0), pipe.host_batch(3, 1)
    assert np.array_equal(g["tokens"][:4], h0["tokens"])
    assert np.array_equal(g["tokens"][4:], h1["tokens"])
