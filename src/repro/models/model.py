"""Unified LM: one forward/prefill/decode covering the whole assigned pool.

Layer stacking: parameters are stacked over ``n_periods`` and the layer loop
is a single ``lax.scan`` over one *period* of sublayers (dense archs: period
= ("attn",); Jamba: 8 sublayers, 1 attn + 7 mamba; Whisper decoder: one
self+cross sublayer). This keeps the lowered HLO compact (66 dry-run cells
compile on one CPU core) and is also the right thing on real hardware
(compile once per period, not per layer).

The ``constrain`` callback injects GSPMD sharding constraints; models never
import mesh code (the distributed layer binds it).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba2, moe
from repro.models.config import ArchConfig

Constrain = Callable[[jnp.ndarray, tuple], jnp.ndarray]
_noop: Constrain = lambda x, axes: x


def _init_dense(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class LM:
    def __init__(self, cfg: ArchConfig, constrain: Constrain = _noop):
        self.cfg = cfg
        self.constrain = constrain
        # mid-layer activation constraints are a §Perf knob (see ArchConfig)
        self.constrain_mid = constrain if cfg.activation_constraints else _noop

    # ================================================================ params
    def _attn_params(self, key, dt, cross: bool = False) -> dict:
        c = self.cfg
        d, hq, hkv, dh = c.d_model, c.n_heads, c.n_kv_heads, c.d_head
        ks = jax.random.split(key, 12)
        p = {
            "ln": jnp.ones((d,), dt),
            "wq": _init_dense(ks[0], (d, hq * dh), dt),
            "wk": _init_dense(ks[1], (d, hkv * dh), dt),
            "wv": _init_dense(ks[2], (d, hkv * dh), dt),
            "wo": _init_dense(ks[3], (hq * dh, d), dt),
        }
        if c.norm == "layernorm":
            p["ln_b"] = jnp.zeros((d,), dt)
        if c.qkv_bias:
            p["bq"] = jnp.zeros((hq * dh,), dt)
            p["bk"] = jnp.zeros((hkv * dh,), dt)
            p["bv"] = jnp.zeros((hkv * dh,), dt)
        if c.qk_norm:
            p["q_norm"] = jnp.ones((dh,), dt)
            p["k_norm"] = jnp.ones((dh,), dt)
        if cross:
            p["x_ln"] = jnp.ones((d,), dt)
            if c.norm == "layernorm":
                p["x_ln_b"] = jnp.zeros((d,), dt)
            p["x_wq"] = _init_dense(ks[4], (d, hq * dh), dt)
            p["x_wk"] = _init_dense(ks[5], (d, hkv * dh), dt)
            p["x_wv"] = _init_dense(ks[6], (d, hkv * dh), dt)
            p["x_wo"] = _init_dense(ks[7], (hq * dh, d), dt)
        return p

    def _ffn_params(self, key, dt, idx_in_period: int) -> dict:
        c = self.cfg
        d = c.d_model
        ks = jax.random.split(key, 4)
        if c.is_moe_layer(idx_in_period):
            f = c.d_ff_expert
            return {
                "ln2": jnp.ones((d,), dt),
                "router": _init_dense(ks[0], (d, c.n_experts), jnp.float32),
                "w_gate": _init_dense(ks[1], (c.n_experts, d, f), dt),
                "w_up": _init_dense(ks[2], (c.n_experts, d, f), dt),
                "w_down": _init_dense(ks[3], (c.n_experts, f, d), dt),
            }
        if c.d_ff == 0:
            return {}
        if c.act == "gelu":
            p = {"ln2": jnp.ones((d,), dt),
                 "w_in": _init_dense(ks[0], (d, c.d_ff), dt),
                 "b_in": jnp.zeros((c.d_ff,), dt),
                 "w_out": _init_dense(ks[1], (c.d_ff, d), dt),
                 "b_out": jnp.zeros((d,), dt)}
            if c.norm == "layernorm":
                p["ln2_b"] = jnp.zeros((d,), dt)
            return p
        return {"ln2": jnp.ones((d,), dt),
                "w_gate": _init_dense(ks[0], (d, c.d_ff), dt),
                "w_up": _init_dense(ks[1], (d, c.d_ff), dt),
                "w_down": _init_dense(ks[2], (c.d_ff, d), dt)}

    def _mamba_params(self, key, dt) -> dict:
        c = self.cfg
        d, d_in = c.d_model, c.d_inner
        H, N, G, K = c.ssm_heads, c.ssm_d_state, c.ssm_n_groups, c.ssm_conv
        conv_ch = d_in + 2 * G * N
        ks = jax.random.split(key, 4)
        return {
            "ln": jnp.ones((d,), dt),
            "in_proj": _init_dense(ks[0], (d, 2 * d_in + 2 * G * N + H), dt),
            "conv_w": _init_dense(ks[1], (K, conv_ch), dt, scale=0.1),
            "conv_b": jnp.zeros((conv_ch,), dt),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
            "D": jnp.ones((H,), jnp.float32),
            "dt_bias": jnp.zeros((H,), jnp.float32),
            "norm": jnp.ones((d_in,), dt),
            "out_proj": _init_dense(ks[2], (d_in, d), dt),
        }

    def _period_params(self, key, dt, cross: bool = False) -> dict:
        c = self.cfg
        out = {}
        keys = jax.random.split(key, 2 * len(c.period))
        for i, kind in enumerate(c.period):
            if kind == "attn":
                sub = self._attn_params(keys[2 * i], dt, cross=cross)
            elif kind == "mamba":
                sub = self._mamba_params(keys[2 * i], dt)
            else:
                raise ValueError(kind)
            sub.update(self._ffn_params(keys[2 * i + 1], dt, i))
            out[f"{i}:{kind}"] = sub
        return out

    def init_params(self, key: jax.Array, dtype=jnp.bfloat16) -> dict:
        c = self.cfg
        ks = jax.random.split(key, 8)
        stacked = jax.vmap(lambda k: self._period_params(
            k, dtype, cross=bool(c.enc_layers)))(
            jax.random.split(ks[0], c.n_periods))
        params = {
            "embed": _init_dense(ks[1], (c.vocab, c.d_model), dtype),
            "blocks": stacked,
            "final_norm": jnp.ones((c.d_model,), dtype),
        }
        if c.norm == "layernorm":
            params["final_norm_b"] = jnp.zeros((c.d_model,), dtype)
        if not c.tie_embeddings:
            params["lm_head"] = _init_dense(ks[2], (c.d_model, c.vocab), dtype)
        if c.enc_layers:
            enc_cfg = dataclasses.replace(c, n_kv_heads=c.n_heads)
            enc = LM(enc_cfg, self.constrain)
            params["enc_blocks"] = jax.vmap(
                lambda k: enc._period_params(k, dtype))(
                jax.random.split(ks[3], c.enc_layers))
            params["enc_final_norm"] = jnp.ones((c.d_model,), dtype)
            params["enc_final_norm_b"] = jnp.zeros((c.d_model,), dtype)
        return params

    def param_specs(self, dtype=jnp.bfloat16):
        """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
        return jax.eval_shape(
            lambda k: self.init_params(k, dtype), jax.random.PRNGKey(0))

    # =============================================================== helpers
    _WG_IN = ("wq", "wk", "wv", "x_wq", "x_wk", "x_wv", "w_in", "in_proj")
    _WG_OUT = ("wo", "x_wo", "w_down", "w_out", "out_proj")

    def _gather_weights(self, sub: dict) -> dict:
        """ZeRO-3 weight-gather (cfg.fsdp_weight_gather): constrain this
        layer's weights to TP-only specs at use time. Under data-sharded
        in_shardings, XLA materializes a per-layer weight all-gather —
        O(params/L) wire per step — instead of per-layer ACTIVATION reshards
        — O(B*S*d) wire — which baselines show dominating."""
        if not self.cfg.fsdp_weight_gather:
            return sub
        out = {}
        for k, v in sub.items():
            if k in self._WG_IN and v.ndim == 2:
                out[k] = self.constrain(v, (None, ("model", None)))
            elif k in self._WG_OUT and v.ndim == 2:
                out[k] = self.constrain(v, (("model", None), None))
            elif k in ("w_gate", "w_up"):
                if v.ndim == 3:     # experts (E, d, f): E first, f fallback
                    out[k] = self.constrain(
                        v, (("model", None), None, ("model", None)))
                else:
                    out[k] = self.constrain(v, (None, ("model", None)))
            elif k == "w_down" and v.ndim == 3:
                out[k] = self.constrain(
                    v, (("model", None), ("model", None), None))
            else:
                out[k] = v
        return out

    def _norm(self, x, p, name="ln"):
        if self.cfg.norm == "layernorm":
            return L.layernorm(x, p[name], p[f"{name}_b"], self.cfg.norm_eps)
        return L.rmsnorm(x, p[name], self.cfg.norm_eps)

    def _qkv(self, h, p, prefix=""):
        c = self.cfg
        q = h @ p[prefix + "wq"]
        k = h @ p[prefix + "wk"]
        v = h @ p[prefix + "wv"]
        if c.qkv_bias and not prefix:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        B, S = h.shape[:2]
        q = q.reshape(B, S, c.n_heads, c.d_head)
        k = k.reshape(B, S, c.n_kv_heads, c.d_head)
        v = v.reshape(B, S, c.n_kv_heads, c.d_head)
        if c.qk_norm and not prefix:
            q = L.rmsnorm(q, p["q_norm"], c.norm_eps)
            k = L.rmsnorm(k, p["k_norm"], c.norm_eps)
        return q, k, v

    def _attn_full(self, x, p, positions, causal=True):
        """Training/prefill attention over the whole sequence."""
        c = self.cfg
        h = self._norm(x, p)
        q, k, v = self._qkv(h, p)
        if c.rope_theta > 0:
            q = L.apply_rope(q, positions, c.rope_theta)
            k = L.apply_rope(k, positions, c.rope_theta)
        sp = ("data", None, "model", None)
        q = self.constrain_mid(q, sp)
        k = self.constrain_mid(k, sp)
        out = L.chunked_attention(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
            causal=causal, window=c.attn_window, gqa=c.attn_gqa_mode)
        out = jnp.moveaxis(out, 1, 2).reshape(x.shape[0], x.shape[1], -1)
        return x + out @ p["wo"]

    def _cross_attn(self, x, p, enc_out=None, cache=None):
        c = self.cfg
        h = self._norm(x, p, "x_ln")
        B, S = h.shape[:2]
        q = (h @ p["x_wq"]).reshape(B, S, c.n_heads, c.d_head)
        if cache is not None:
            k, v = cache["xk"], cache["xv"]             # (B, Hkv, Senc, D)
        else:
            k = (enc_out @ p["x_wk"]).reshape(B, -1, c.n_kv_heads, c.d_head)
            v = (enc_out @ p["x_wv"]).reshape(B, -1, c.n_kv_heads, c.d_head)
            k, v = jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)
        out = L.chunked_attention(jnp.moveaxis(q, 1, 2), k, v, causal=False)
        out = jnp.moveaxis(out, 1, 2).reshape(B, S, -1)
        return x + out @ p["x_wo"]

    def _ffn(self, x, p, idx_in_period):
        c = self.cfg
        if c.is_moe_layer(idx_in_period) and c.n_experts:
            h = self._norm(x, p, "ln2")
            mesh = getattr(self.constrain, "mesh", None)
            if (c.moe_buf_mode == "shard_map" and mesh is not None
                    and "model" in mesh.axis_names
                    and c.n_experts % int(mesh.shape["model"]) == 0):
                y, aux = moe.moe_ffn_shard_map(
                    h, p, n_experts=c.n_experts, top_k=c.top_k,
                    capacity_factor=c.capacity_factor, mesh=mesh)
            else:
                bm = "local" if c.moe_buf_mode == "shard_map" \
                    else c.moe_buf_mode
                y, aux = moe.moe_ffn(h, p, n_experts=c.n_experts,
                                     top_k=c.top_k,
                                     capacity_factor=c.capacity_factor,
                                     constrain=self.constrain_mid,
                                     buf_mode=bm)
            return x + y, aux
        if not p or "ln2" not in p:
            return x, jnp.float32(0.0)
        h = self._norm(x, p, "ln2")
        if c.act == "gelu":
            y = L.gelu_mlp(h, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
        else:
            h = self.constrain_mid(h, ("data", None, None))
            y = L.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        return x + y, jnp.float32(0.0)

    # ================================================================ forward
    def _embed(self, params, tokens, patch_embeds=None, frame_embeds=None):
        if frame_embeds is not None:              # audio stub: already embedded
            return frame_embeds
        x = jnp.take(params["embed"], tokens, axis=0)
        if patch_embeds is not None:              # vlm stub: patch prefix
            P = patch_embeds.shape[1]
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, P:]], axis=1)
        return x

    def forward(self, params, tokens, *, patch_embeds=None, enc_frames=None):
        """Training/prefill forward -> (logits (B,S,V), aux_loss)."""
        c = self.cfg
        x = self._embed(params, tokens, patch_embeds)
        x = self.constrain(x, ("data", None, None))
        B, S = x.shape[:2]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

        enc_out = None
        if c.enc_layers:
            enc_out = self.encode(params, enc_frames)

        def body(carry, per_params):
            x, aux = carry
            for i, kind in enumerate(c.period):
                p = self._gather_weights(per_params[f"{i}:{kind}"])
                if kind == "attn":
                    x = self._attn_full(x, p, positions)
                    if enc_out is not None:
                        x = self._cross_attn(x, p, enc_out=enc_out)
                else:
                    h = self._norm(x, p)
                    x = x + mamba2.mamba2_mixer(h, p, c, self.constrain_mid)
                x, a = self._ffn(x, p, i)
                aux = aux + a
            return (x, aux), None

        if not c.remat or c.remat_policy == "none":
            body_fn = body
        elif c.remat_policy == "dots":
            # save matmul outputs, recompute only cheap elementwise ops:
            # trades ~25% recompute FLOPs for activation memory (§Perf)
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body_fn = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                   params["blocks"])
        x = self._norm(x, {"ln": params["final_norm"],
                           "ln_b": params.get("final_norm_b")})
        head = params["embed"].T if c.tie_embeddings else params["lm_head"]
        logits = x @ head
        return self.constrain(logits, ("data", None, "model")), aux

    def encode(self, params, frames):
        """Whisper encoder: bidirectional attention over frame embeddings."""
        c = self.cfg
        B, S, _ = frames.shape
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = frames + _sinusoid(S, c.d_model, frames.dtype)

        def body(x, per_params):
            p = per_params["0:attn"]
            x = self._attn_full(x, p, pos, causal=False)
            x, _ = self._ffn(x, p, 0)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.layernorm(x, params["enc_final_norm"], params["enc_final_norm_b"])

    def loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        """batch: tokens (B,S), labels (B,S) (-100 = masked), optional
        patch_embeds / enc_frames."""
        logits, aux = self.forward(
            params, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"))
        labels = batch["labels"]
        mask = labels >= 0
        safe = jnp.maximum(labels, 0)
        # CE without materializing a full f32 log_softmax at 150k vocab:
        # nll = logsumexp(logits) - logits[label]; XLA fuses the exp-reduce.
        lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = lse - gold.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(mask), 1)
        ce = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux,
                       "tokens": denom.astype(jnp.float32)}

    # ================================================================= cache
    def init_cache(self, B: int, s_max: int, dtype=jnp.bfloat16,
                   abstract: bool = False, enc_len: int | None = None):
        c = self.cfg
        s_kv = min(s_max, c.attn_window) if c.attn_window else s_max
        mk = (lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)) if abstract \
            else (lambda sh, dt: jnp.zeros(sh, dt))
        blocks = {}
        for i, kind in enumerate(c.period):
            entry = {}
            if kind == "attn":
                entry["k"] = mk((c.n_periods, B, c.n_kv_heads, s_kv, c.d_head), dtype)
                entry["v"] = mk((c.n_periods, B, c.n_kv_heads, s_kv, c.d_head), dtype)
                if c.enc_layers:
                    el = enc_len or c.cross_len
                    entry["xk"] = mk((c.n_periods, B, c.n_kv_heads, el, c.d_head), dtype)
                    entry["xv"] = mk((c.n_periods, B, c.n_kv_heads, el, c.d_head), dtype)
            else:
                conv_ch = c.d_inner + 2 * c.ssm_n_groups * c.ssm_d_state
                entry["state"] = mk((c.n_periods, B, c.ssm_heads, c.ssm_d_state,
                                     c.ssm_head_dim), jnp.float32)
                entry["conv"] = mk((c.n_periods, B, c.ssm_conv - 1, conv_ch), dtype)
            blocks[f"{i}:{kind}"] = entry
        ln = jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.zeros((), jnp.int32)
        return {"blocks": blocks, "len": ln}

    def decode_step(self, params, cache, tokens):
        """tokens (B, 1) -> (logits (B, 1, V), new cache). One new token
        against a filled KV/SSM cache — this is what decode_* cells lower."""
        c = self.cfg
        B = tokens.shape[0]
        x = self._embed(params, tokens)
        pos = cache["len"]
        positions = jnp.full((B, 1), pos, jnp.int32)

        def body(x, xs):
            per_params, per_cache = xs
            new_cache = {}
            for i, kind in enumerate(c.period):
                p = self._gather_weights(per_params[f"{i}:{kind}"])
                pc = per_cache[f"{i}:{kind}"]
                nc = {}
                if kind == "attn":
                    h = self._norm(x, p)
                    q, k, v = self._qkv(h, p)
                    if c.rope_theta > 0:
                        q = L.apply_rope(q, positions, c.rope_theta)
                        k = L.apply_rope(k, positions, c.rope_theta)
                    s_kv = pc["k"].shape[2]
                    rotated = c.attn_window is not None and s_kv == c.attn_window
                    slot = jnp.where(rotated, pos % s_kv, jnp.minimum(pos, s_kv - 1))
                    kc = jax.lax.dynamic_update_slice(
                        pc["k"], jnp.moveaxis(k, 1, 2),
                        (0, 0, slot.astype(jnp.int32), 0))
                    vc = jax.lax.dynamic_update_slice(
                        pc["v"], jnp.moveaxis(v, 1, 2),
                        (0, 0, slot.astype(jnp.int32), 0))
                    cache_len = jnp.minimum(pos + 1, s_kv)
                    out = L.decode_attention(
                        jnp.moveaxis(q, 1, 2), kc, vc, cache_len=cache_len,
                        window=c.attn_window, window_rotated=bool(rotated),
                        gqa=c.attn_gqa_mode)
                    x = x + jnp.moveaxis(out, 1, 2).reshape(B, 1, -1) @ p["wo"]
                    nc["k"], nc["v"] = kc, vc
                    if c.enc_layers:
                        x = self._cross_attn(x, p, cache={"xk": pc["xk"],
                                                          "xv": pc["xv"]})
                        nc["xk"], nc["xv"] = pc["xk"], pc["xv"]
                else:
                    h = self._norm(x, p)
                    st = mamba2.SSMState(state=pc["state"], conv=pc["conv"])
                    y, st = mamba2.mamba2_decode_step(h, p, c, st)
                    x = x + y
                    nc["state"], nc["conv"] = st.state, st.conv
                x, _ = self._ffn(x, p, i)
                new_cache[f"{i}:{kind}"] = nc
            return x, new_cache

        x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        x = self._norm(x, {"ln": params["final_norm"],
                           "ln_b": params.get("final_norm_b")})
        head = params["embed"].T if c.tie_embeddings else params["lm_head"]
        logits = x @ head
        return logits, {"blocks": new_blocks, "len": cache["len"] + 1}

    def prefill(self, params, tokens, s_max: int, **kw):
        """Run the full forward while building the decode cache (test-scale
        path; production prefill shares forward's chunked attention)."""
        cache = self.init_cache(tokens.shape[0], s_max,
                                dtype=params["embed"].dtype, **kw)
        logits = None
        for t in range(tokens.shape[1]):
            logits, cache = self.decode_step(params, cache, tokens[:, t:t + 1])
        return logits, cache


@functools.lru_cache(maxsize=8)
def _sinusoid_np(S: int, d: int):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)[None]


def _sinusoid(S: int, d: int, dtype):
    return jnp.asarray(_sinusoid_np(S, d), dtype)
