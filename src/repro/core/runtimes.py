"""Runtime registry — one place that maps a spec string to a runner.

Every runtime consumes the SAME deployment artifact and exposes
``forward(images) -> SNNOutput``; the registry replaces the if/elif chains
that used to live in the agreement harness and the serving engine with a
declarative table, so adding a runtime (the board emulator is the third) is
one ``@register`` away.

Spec grammar: ``family[-mode[-kernel]]`` — the kernel suffix parses the
same way in every family (``opts.partition("-")``), so a spec that the
docstring advertises always constructs:

    reference                      software reference (the oracle)
    accelerator                    alias of accelerator-batch (family default)
    accelerator-batch[-jnp|pallas] time-batched MXU path
    accelerator-event[-jnp|pallas|fused]
                                   packed-event path (kernel picked via the
                                   suffix or the ``kernel=`` keyword)
    board[-batched[-jnp|pallas]]   board emulator, vectorized fast path
                                   (kernel suffix selects the LIF impl)
    board-py                       board emulator, per-image Python scheduler
                                   (no kernel suffix — it is plain python)

``ADVERTISED_SPECS`` enumerates every concrete spec above; the grammar
roundtrip test constructs each one, so docstring and parser cannot drift.

Factories ignore keywords they don't understand so harness-level defaults
(e.g. ``kernel=``) can be passed uniformly across families.
"""

from __future__ import annotations

from typing import Callable

from repro.core.artifact import Artifact
from repro.core.lowering import LoweredProgram, get_cache, lower
from repro.telemetry import trace as ttrace

_REGISTRY: dict[str, Callable] = {}

#: every spec the module docstring advertises, fully expanded — each must
#: construct against any exported artifact (pinned by the roundtrip test).
ADVERTISED_SPECS = (
    "reference",
    "accelerator",
    "accelerator-batch", "accelerator-batch-jnp", "accelerator-batch-pallas",
    "accelerator-event", "accelerator-event-jnp", "accelerator-event-pallas",
    "accelerator-event-fused",
    "board", "board-batched", "board-batched-jnp", "board-batched-pallas",
    "board-py",
)


def register(family: str):
    def deco(factory: Callable) -> Callable:
        _REGISTRY[family] = factory
        return factory
    return deco


def available() -> list[str]:
    return sorted(_REGISTRY)


def make_runtime(artifact: Artifact | LoweredProgram, spec: str, *,
                 faults=None, **kw):
    """Build the runtime named by ``spec`` over ``artifact`` (a raw
    ``Artifact`` or an already-lowered ``LoweredProgram`` — rebuilding lanes
    pass the program so the lowering stage runs once per artifact).

    ``faults`` accepts anything ``repro.faults.FaultPlan.coerce`` does
    (None | plan | spec string like ``"seu_weight=4,seed=7"`` | kwargs dict):

      * a STATIC plan (artifact-resident SEU bit flips) is a lowering pass
        (``lowering.lower_with_faults``): it corrupts an in-memory CLONE of
        the artifact for any runtime family — the caller's artifact stays
        pristine (it backs the scrub/reload recovery path) and the clone's
        unchanged SHA-256 manifest is the detector;
      * a DYNAMIC plan (board-datapath faults: membrane SEU, stuck groups,
        AER glitches, forced FIFO depth) is only emulated by the per-image
        ``board-py`` scheduler; every other spec rejects it loudly rather
        than silently serving the clean datapath;
      * lane-fault fields are the serving scheduler's concern and are
        ignored here.

    When a ``Tracer`` is installed, the ``runtime.build`` span's META gains
    ``cache_hit`` — True when the runtime's compiled-callable bundle (or,
    for uncompiled runtimes, its lowered program) came out of the
    process-wide ``ProgramCache``. Meta, not attrs: cache occupancy is
    host-nondeterministic and must not enter the canonical span tree.
    """
    family, _, opts = spec.partition("-")
    if family not in _REGISTRY:
        raise ValueError(f"unknown runtime family {family!r} in spec "
                         f"{spec!r}; available: {available()}")
    if faults is not None:
        from repro.core.lowering import lower_with_faults
        from repro.faults.plan import DYNAMIC_FIELDS, FaultPlan
        plan = FaultPlan.coerce(faults)
        if plan.has_static:
            artifact = lower_with_faults(artifact, plan)
        if plan.has_dynamic:
            if family != "board" or opts.partition("-")[0] != "py":
                raise ValueError(
                    f"dynamic fault plans (fields {DYNAMIC_FIELDS}) are only "
                    f"emulated by the 'board-py' runtime; spec {spec!r} "
                    f"cannot inject {plan.describe()}")
            kw["faults"] = plan
    if isinstance(artifact, LoweredProgram):
        program, program_hit = artifact, True
    else:
        program, program_hit = get_cache().program(artifact)
    rec = ttrace.get()
    if not rec.enabled:
        return _REGISTRY[family](program, opts, **kw)
    with rec.span("runtime.build", "system", attrs={"family": family},
                  meta={"spec": spec}) as sp:
        rt = _REGISTRY[family](program, opts, **kw)
        if sp is not None:
            sp.meta["cache_hit"] = bool(getattr(rt, "cache_hit",
                                                program_hit))
            cs = get_cache().stats()
            sp.meta["cache_bytes"] = cs["bytes"]
            sp.meta["cache_evictions"] = cs["evictions"]
        return rt


#: near-miss grammar probe set: every way the spec grammar can be (mis)spelled
#: within the known families/modes/kernels. ``registry_consistency_errors``
#: walks it to enforce the bidirectional contract — a spec either constructs
#: AND is advertised, or raises AND is not. (Bare "accelerator" used to
#: construct silently without being advertised; it is now an advertised
#: family-default alias, pinned by this probe.)
PROBE_OPTS = {
    "reference": ("", "jnp", "bogus"),
    "accelerator": ("", "batch", "event",
                    "batch-jnp", "batch-pallas", "batch-fused", "batch-bogus",
                    "event-jnp", "event-pallas", "event-fused", "event-bogus",
                    "jnp", "pallas", "fused", "bogus"),
    "board": ("", "batched", "py",
              "batched-jnp", "batched-pallas", "batched-fused",
              "batched-bogus", "py-jnp", "jnp", "pallas", "fused", "bogus"),
}


def probe_specs() -> list[str]:
    return [family + ("-" + opts if opts else "")
            for family, all_opts in PROBE_OPTS.items() for opts in all_opts]


def registry_consistency_errors(artifact: Artifact) -> list[str]:
    """The registry's advertise/construct contract, checked both ways:

      1. the families ``available()`` exposes are exactly the families
         ``ADVERTISED_SPECS`` spells out (a family registered without
         advertised specs — or advertised without a factory — is an error);
      2. every advertised spec constructs against ``artifact``;
      3. no probe-set spec constructs WITHOUT being advertised (a silently
         accepted spelling is an undocumented runtime, itself a conformance
         failure).

    Returns a list of human-readable errors; empty means consistent."""
    errors: list[str] = []
    adv_families = {s.partition("-")[0] for s in ADVERTISED_SPECS}
    for fam in sorted(adv_families - set(available())):
        errors.append(f"family {fam!r} is advertised but not registered")
    for fam in sorted(set(available()) - adv_families):
        errors.append(f"family {fam!r} is registered but advertises no spec")
    for spec in ADVERTISED_SPECS:
        try:
            make_runtime(artifact, spec)
        except Exception as e:  # noqa: BLE001 — any failure is the finding
            errors.append(f"advertised spec {spec!r} does not construct: {e}")
    for spec in probe_specs():
        if spec in ADVERTISED_SPECS:
            continue  # construction already asserted above
        try:
            make_runtime(artifact, spec)
        except Exception:
            continue  # rejected and unadvertised: consistent
        errors.append(f"spec {spec!r} constructs but is not advertised")
    return errors


@register("reference")
def _reference(art: Artifact, opts: str, **_):
    from repro.core.reference import SNNReference
    if opts:
        raise ValueError(f"reference runtime takes no options, got {opts!r}")
    return SNNReference(art)


@register("accelerator")
def _accelerator(art: Artifact, opts: str, kernel: str = "jnp", **_):
    from repro.core.accelerator import SNNAccelerator
    mode, _, k = opts.partition("-")
    return SNNAccelerator(art, mode=mode or "batch", kernel=k or kernel)


@register("board")
def _board(art: Artifact, opts: str, latency_mode: bool = False,
           kernel: str = "jnp", faults=None, **_):
    from repro.board import SNNBoard, SNNBoardBatched
    mode, _, k = opts.partition("-")
    if mode in ("", "batched"):
        # kernel suffix parses uniformly with the accelerator family
        # ("board-batched-pallas"); forwarded, not swallowed: the batched
        # path understands jnp/pallas and rejects kernels it doesn't
        # (e.g. the accelerator-only "fused")
        return SNNBoardBatched(art, latency_mode=latency_mode,
                               kernel=k or kernel)
    if mode == "py":
        if k:
            raise ValueError(f"board-py takes no kernel suffix, got {k!r} "
                             "(the per-image scheduler is plain python)")
        # plain python path — the only family that emulates dynamic faults
        return SNNBoard(art, latency_mode=latency_mode, faults=faults)
    raise ValueError(f"unknown board option {mode!r} "
                     "(use '', 'batched', 'py')")
