"""Qwen3-8B [hf:Qwen/Qwen3-8B; hf]: 36L, d4096, 32H GQA(kv=8), d_ff 12288,
vocab 151936, qk_norm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, vocab=151936,
    n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12288, qk_norm=True, rope_theta=1e6,
)
