"""Event-pipeline benchmark: staged vs fused vs dense, both measurement scopes.

The paper's §2.3 discipline, applied to the three execution paths of the
same deployment artifact:

  * staged-event — event_accum materializes (B, T, N_pad) currents to
    memory, the LIF kernel re-reads them, a third kernel decodes;
  * fused-event  — the event→LIF→decode megakernel: one pass, membrane
    resident, currents never materialized (plus the early-exit latency
    variant at B=1);
  * dense-batch  — the time-batched MXU matmul path (throughput baseline).

Accelerator-scope times ONLY the jitted forward on pre-packed frames
(block_until_ready); system-scope adds TTFS encode, host spike packing,
dispatch, and readback — the full request path a serving engine pays. Spike
packing is also timed alone (the paper's Fig-2 stage).

Emits ``results/bench/event_pipeline.json`` via benchmarks.common.emit so the
perf trajectory is tracked across PRs. ``--check`` exits non-zero if the
fused path does not beat the staged path on accelerator-scope latency in the
like-for-like serving configuration for each batch size (latency-mode pair at
B=1, full-T pair at larger B) — scripts/check.sh runs this to gate
regressions.
"""

from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks import common as CM
from repro.core import ttfs
from repro.core.accelerator import SNNAccelerator
from repro.core.events import pack_events_batched
from repro.serving.snn_engine import SNNServeEngine


def _frames_for(art, images: np.ndarray):
    T = int(art.m("encode", "T"))
    times = np.asarray(ttfs.encode_ttfs(
        jnp.asarray(images, jnp.float32), T, float(art.m("encode", "x_min"))))
    return pack_events_batched(times, T, int(art.m("events", "e_max")))


def bench_paths(art, images: np.ndarray, B: int, iters: int) -> list[dict]:
    xb = images[:B]
    frames = _frames_for(art, xb)
    assert not np.any(np.asarray(frames.overflow)), "raise artifact E_max"
    ids = jnp.asarray(frames.ids)
    count = jnp.asarray(frames.count)
    rows = []

    staged = SNNAccelerator(art, mode="event", kernel="jnp")
    fused = SNNAccelerator(art, mode="event", kernel="fused")
    dense = SNNAccelerator(art, mode="batch", kernel="jnp")

    # ------------------------------------------------------ accelerator scope
    paths = [
        ("staged-event", lambda: staged._fwd_event(ids, count)),
        ("fused-event", lambda: fused._fwd_event(ids, count)),
        ("dense-batch", lambda: dense._fwd_batch(jnp.asarray(xb))),
    ]
    if B == 1:
        # latency mode is the B=1 serving configuration (per-row early exit
        # at the TTFS decision point) — measured for BOTH implementations so
        # the staged/fused comparison is like-for-like
        paths += [
            ("staged-event-latency",
             lambda: staged._fwd_event_latency(ids, count)),
            ("fused-event-latency",
             lambda: fused._fwd_event_latency(ids, count)),
        ]
    for name, fn in paths:
        dt, _ = CM.timed(fn, warmup=2, iters=iters)
        rows.append({"path": name, "scope": "accelerator", "B": B,
                     "s_per_batch": dt, "us_per_image": 1e6 * dt / B})

    # ---------------------------------------------------------- system scope
    for name, acc in (("staged-event", staged), ("fused-event", fused),
                      ("dense-batch", dense)):
        dt, _ = CM.timed(lambda a=acc: a.forward(images=xb),
                         warmup=2, iters=iters)
        rows.append({"path": name, "scope": "system", "B": B,
                     "s_per_batch": dt, "us_per_image": 1e6 * dt / B})

    # host spike-packing stage alone (Fig-2 "spike packing")
    dt, _ = CM.timed(lambda: _frames_for(art, xb), warmup=1, iters=iters)
    rows.append({"path": "spike-packing", "scope": "host", "B": B,
                 "s_per_batch": dt, "us_per_image": 1e6 * dt / B})
    return rows


def bench_engine(art, images: np.ndarray, n: int) -> list[dict]:
    """System-scope serving: the batched request-queue engine end to end."""
    rows = []
    for kernel in ("jnp", "fused"):
        eng = SNNServeEngine(art, max_batch=64, kernel=kernel)
        eng.classify(images[:n])          # warm the compiled program
        eng.reset_stats()                 # measure steady-state serving only
        eng.classify(images[:n])
        st = eng.stats()
        rows.append({"path": f"engine-{kernel}", "scope": "engine",
                     "max_batch": 64, "n_images": n, **st})
    return rows


def main(quick: bool = False, check: bool = False,
         batches: tuple[int, ...] = (1, 64)) -> int:
    art, xte, yte = CM.get_artifact_and_data(quick=quick)
    iters = 3 if quick else 10

    rows = []
    for B in batches:
        # small batches are cheap and noisy: buy variance down with iters
        rows += bench_paths(art, xte, B, iters * 8 if B <= 4 else iters)
    rows += bench_engine(art, xte, 256 if quick else 1024)
    CM.emit("event_pipeline", rows)

    ok = True
    for B in batches:
        get = {(r["path"], r["scope"]): r["us_per_image"] for r in rows
               if r.get("B") == B and "us_per_image" in r}
        staged = get[("staged-event", "accelerator")]
        fused = get[("fused-event", "accelerator")]
        # the gate compares like-for-like serving configurations: at B=1 the
        # latency-mode pair (per-row early exit — where staged must still
        # materialize all T steps of currents but fused only gathers the
        # steps it executes); at larger B the full-T throughput pair (where
        # staged materializes the (B, T, E, N_pad) row tensor). At B=1
        # full-T both paths compile to the same work on CPU and differ only
        # by dispatch noise, so it is reported but not gated.
        if ("fused-event-latency", "accelerator") in get:
            g_staged = get[("staged-event-latency", "accelerator")]
            g_fused = get[("fused-event-latency", "accelerator")]
            gate_name = "latency-mode"
        else:
            g_staged, g_fused, gate_name = staged, fused, "full-T"
        if g_fused >= g_staged:
            ok = False
        print(f"B={B:<4} accel-scope  staged {staged:9.1f} us/img   "
              f"fused {fused:9.1f} us/img   (full-T)")
        if gate_name == "latency-mode":
            print(f"        latency-mode staged {g_staged:9.1f} us/img   "
                  f"fused {g_fused:9.1f} us/img")
        print(f"        gate[{gate_name}]: "
              f"{'FUSED WINS' if g_fused < g_staged else 'REGRESSION'}")
        if ("dense-batch", "accelerator") in get:
            print(f"        {'dense-batch':<20} "
                  f"{get[('dense-batch', 'accelerator')]:9.1f} us/img")
        print(f"        system-scope staged {get[('staged-event', 'system')]:9.1f}"
              f" us/img   fused {get[('fused-event', 'system')]:9.1f} us/img"
              f"   (packing {get[('spike-packing', 'host')]:.1f})")
    for r in rows:
        if r["scope"] == "engine":
            print(f"engine[{r['path']}]  accel {r['accel_us_per_image']:.1f}"
                  f" us/img  system {r['system_us_per_image']:.1f} us/img  "
                  f"fallbacks {r['overflow_fallbacks']}")

    if check and not ok:
        print("CHECK FAILED: fused path slower than staged path",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small test split + fewer iters")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless fused beats staged (accel scope)")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 64])
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check, batches=tuple(a.batches)))
