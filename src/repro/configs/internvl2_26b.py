"""InternVL2-26B [arXiv:2404.16821; hf]: InternViT frontend (STUB — patch
embeddings provided precomputed at d_model by input_specs) + InternLM2-20B
backbone: 48L, d6144, 48H GQA(kv=8), d_ff 16384, vocab 92553. The 92553
vocab does not divide the 16-way model axis; the resolver replicates the
embedding and shards the contraction instead (DESIGN.md §5)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, vocab=92553,
    n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, rope_theta=1e6,
    frontend="vision", n_patches=256,
)
