"""Deployment planner — the co-design analysis layer (paper Table 1 analogue).

The FPGA design point is BRAM-limited: 140/140 BRAM tiles used, LUT/DSP
headroom left, 16 hardware groups x 128 neurons directly addressable. The
TPU-native counterpart asks the same questions against the v5e budget:

  * how do logical neurons pack into 128-lane hardware blocks (padding cost),
  * does the synapse matrix + runtime state fit VMEM (the BRAM analogue),
  * what is the utilization of each budget and which one binds first,
  * what is the largest network this tiling strategy can host.

``plan()`` runs at export time; its outputs become the artifact's
connectivity descriptor, and ``bench_resources.py`` prints the Table-1
analogue from the same report.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hw import TPU_V5E, PYNQ_Z2, TpuTarget


@dataclasses.dataclass
class PlanReport:
    n_in: int
    n_out: int
    lane: int
    n_pad: int                 # padded output neurons (lane multiple)
    n_blocks: int              # hardware neuron blocks (the "group" analogue)
    pack_efficiency: float     # n_out / n_pad
    synapses: int              # logical synapse count
    synapses_padded: int
    w_bytes_vmem: int          # int8 padded weight bytes (VMEM-resident)
    state_bytes_vmem: int      # membrane + first-spike + threshold (int32 x3)
    vmem_bytes_total: int
    vmem_util: float
    hbm_bytes: int             # artifact-at-rest (weights fp32+int8+meta)
    hbm_util: float
    limiter: str               # which budget binds first
    max_neurons_vmem: int      # largest n_out this n_in fits in VMEM
    notes: str

    def table(self) -> str:
        """Render the Table-1 analogue."""
        rows = [
            ("Neuron blocks (128-lane)", f"{self.n_blocks} "
             f"({self.n_out} logical -> {self.n_pad} padded, "
             f"{self.pack_efficiency:.1%} packed)"),
            ("Synapses (logical/padded)", f"{self.synapses:,} / {self.synapses_padded:,}"),
            ("VMEM weights (int8)", f"{self.w_bytes_vmem:,} B"),
            ("VMEM state (v/first/thr)", f"{self.state_bytes_vmem:,} B"),
            ("VMEM total / budget", f"{self.vmem_bytes_total:,} B / "
             f"{TPU_V5E.vmem_bytes:,} B ({self.vmem_util:.2%})"),
            ("HBM artifact-at-rest", f"{self.hbm_bytes:,} B ({self.hbm_util:.4%})"),
            ("Primary limiter", self.limiter),
            ("Max neurons in VMEM @ n_in", f"{self.max_neurons_vmem:,}"),
            ("Paper reference (XC7Z020)", f"BRAM 140/140 (100%), "
             f"{PYNQ_Z2.packed_synapses:,} packed synapses — BRAM-limited"),
        ]
        w = max(len(k) for k, _ in rows)
        return "\n".join(f"{k:<{w}}  {v}" for k, v in rows)


def pad_to_lane(n: int, lane: int) -> int:
    return ((n + lane - 1) // lane) * lane


def plan(n_in: int, n_out: int, target: TpuTarget = TPU_V5E) -> PlanReport:
    lane = target.lane_width
    n_pad = pad_to_lane(n_out, lane)
    n_blocks = n_pad // lane
    w_bytes = n_in * n_pad                       # int8
    state_bytes = 3 * 4 * n_pad                  # v, first_spike, thresholds int32
    vmem_total = w_bytes + state_bytes
    vmem_util = vmem_total / target.vmem_bytes
    hbm_bytes = n_in * n_out * (4 + 1) + 4 * n_out + 4096   # fp32+int8 weights, thr, meta
    hbm_util = hbm_bytes / target.hbm_bytes
    # Which budget binds first as the network scales (the co-design verdict):
    limiter = "VMEM (on-chip memory — the BRAM analogue)" if vmem_util >= hbm_util \
        else "HBM capacity"
    if vmem_util < 0.01 and hbm_util < 0.01:
        limiter += " [ample headroom at this size]"
    max_neurons = (target.vmem_bytes // (n_in + 12)) // lane * lane
    notes = ("event-processing path holds the padded int8 synapse matrix and all "
             "neuron state in VMEM, mirroring the paper's BRAM-resident design; "
             "HBM plays the role of off-chip DDR (artifact at rest only).")
    return PlanReport(
        n_in=n_in, n_out=n_out, lane=lane, n_pad=n_pad, n_blocks=n_blocks,
        pack_efficiency=n_out / n_pad, synapses=n_in * n_out,
        synapses_padded=n_in * n_pad, w_bytes_vmem=w_bytes,
        state_bytes_vmem=state_bytes, vmem_bytes_total=vmem_total,
        vmem_util=vmem_util, hbm_bytes=hbm_bytes, hbm_util=hbm_util,
        limiter=limiter, max_neurons_vmem=int(max_neurons), notes=notes)


def blocked_layout(w_int8: np.ndarray, thresholds: np.ndarray, group_ids: np.ndarray,
                   lane: int = 128) -> dict[str, np.ndarray]:
    """Produce the padded block layout stored in the artifact (connectivity
    descriptor): columns padded to a lane multiple; dead lanes get a
    never-fire threshold and group id -1. Consumed by the accelerator runtime
    AND by the reference agreement tests (slicing [:n_out] recovers logical)."""
    from repro.core.quant import INT32_NEVER_FIRE
    n_in, n_out = w_int8.shape
    n_pad = pad_to_lane(n_out, lane)
    w_p = np.zeros((n_in, n_pad), np.int8)
    w_p[:, :n_out] = w_int8
    thr_p = np.full((n_pad,), INT32_NEVER_FIRE, np.int32)
    thr_p[:n_out] = thresholds
    gid_p = np.full((n_pad,), -1, np.int32)
    gid_p[:n_out] = group_ids
    block_table = np.stack([np.arange(n_pad // lane) * lane,
                            np.minimum(lane, np.maximum(
                                0, n_out - np.arange(n_pad // lane) * lane))],
                           axis=1).astype(np.int32)   # (n_blocks, [start, live])
    return {"w_padded": w_p, "thr_padded": thr_p, "gid_padded": gid_p,
            "block_table": block_table}
