"""Paper Table 3 analogue: aligned-scope cross-platform comparison.

Rows:
  * TPU-event (Ours, accelerator-scope) — event-driven path, work ~ active
    events, weights VMEM-resident; latency/energy are labeled projections
    from the co-design model (the paper's own FPGA energy number is a
    tool-based estimate too).
  * TPU-batch — time-batched MXU execution (throughput mode), HBM-streamed.
  * dense FP32 / dense INT8 — dense grouped-neuron executions of the SAME
    exported parameters (the paper's GPU/CPU baseline protocol), measured
    wall-clock on this container's CPU (compute-only scope).
All rows share one deployment artifact; accuracy comes from full-test-set
evaluation, and the TTFS rows are bit-exact against the software reference.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common as CM
from repro.core.accelerator import SNNAccelerator
from repro.core.hw import PYNQ_Z2
from repro.core.reference import SNNReference


def run(quick: bool = False) -> list[dict]:
    art, xte, yte = CM.get_artifact_and_data(quick)
    n = len(xte)
    ref = SNNReference(art)
    rows = []

    # --- TTFS runtimes (agreement + accuracy) ----------------------------
    acc_b = SNNAccelerator(art, mode="batch")
    t_batch, out_b = CM.timed(acc_b.forward, xte[:1024], iters=2)
    labels_full = []
    for i in range(0, n, 2048):
        labels_full.append(np.asarray(acc_b.forward(xte[i:i + 2048]).labels))
    labels_full = np.concatenate(labels_full)
    acc_ttfs = float(np.mean(labels_full == yte))

    ev = CM.snn_event_cost_per_image(art, xte[:2048])
    dn = CM.snn_dense_cost_per_image(art)
    rows.append({
        "platform": "TPU-event (Ours, accelerator-scope, projected)",
        "accuracy_pct": 100 * acc_ttfs,
        "latency_us_img": ev["proj_latency_us"],
        "throughput_img_s": 1e6 / ev["proj_latency_us"],
        "energy_nj_img": ev["proj_energy_nj"],
        "scope": "accelerator (event-driven, VMEM-resident weights)",
    })
    rows.append({
        "platform": "TPU-batch (Ours, accelerator-scope, projected)",
        "accuracy_pct": 100 * acc_ttfs,
        "latency_us_img": dn["proj_latency_us"],
        "throughput_img_s": 1e6 / dn["proj_latency_us"],
        "energy_nj_img": dn["proj_energy_nj"],
        "scope": "accelerator (time-batched MXU, HBM-streamed)",
    })

    # --- dense baselines, same exported parameters ------------------------
    for mode in ("fp32", "int8"):
        fn = (ref.dense_logits_fp32 if mode == "fp32" else ref.dense_logits_int8)
        t_dense, _ = CM.timed(fn, xte[:1024], iters=3)
        preds = []
        for i in range(0, n, 2048):
            preds.append(np.asarray(ref.dense_labels(xte[i:i + 2048], mode)))
        acc_d = float(np.mean(np.concatenate(preds) == yte))
        rows.append({
            "platform": f"CPU dense {mode.upper()} (measured, compute-only)",
            "accuracy_pct": 100 * acc_d,
            "latency_us_img": t_dense / 1024 * 1e6,
            "throughput_img_s": 1024 / t_dense,
            "energy_nj_img": None,
            "scope": "compute-only (this container's CPU)",
        })

    # --- measured container wall-clock for the TTFS batch path ------------
    rows.append({
        "platform": "CPU TTFS batch path (measured, this container)",
        "accuracy_pct": 100 * acc_ttfs,
        "latency_us_img": t_batch / 1024 * 1e6,
        "throughput_img_s": 1024 / t_batch,
        "energy_nj_img": None,
        "scope": "accelerator-path ops on host CPU (not a TPU number)",
    })
    rows.append({
        "platform": "FPGA paper reference (PYNQ-Z2 PL-only, reported)",
        "accuracy_pct": PYNQ_Z2.accuracy_pct,
        "latency_us_img": PYNQ_Z2.service_latency_us,
        "throughput_img_s": 1e6 / PYNQ_Z2.service_latency_us,
        "energy_nj_img": PYNQ_Z2.dynamic_energy_nj,
        "scope": "paper Table 3 row (real MNIST; ours is procedural)",
    })
    CM.emit("crossplatform", rows)
    return rows


def main(quick: bool = False):
    rows = run(quick)
    hdr = f"{'platform':<52} {'acc%':>7} {'us/img':>10} {'img/s':>12} {'nJ/img':>10}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        e = "N/A" if r["energy_nj_img"] is None else f"{r['energy_nj_img']:.1f}"
        print(f"{r['platform']:<52} {r['accuracy_pct']:>7.2f} "
              f"{r['latency_us_img']:>10.4f} {r['throughput_img_s']:>12.0f} "
              f"{e:>10}")


if __name__ == "__main__":
    main()
