"""Serving engine: batched greedy decode, scope-aware stats."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.models.model import LM
from repro.serving.engine import ServeEngine


def _setup():
    cfg = reduced(get_config("yi-6b"))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
    return cfg, lm, params


def test_engine_greedy_matches_manual_loop():
    cfg, lm, params = _setup()
    prompt = np.random.RandomState(0).randint(1, cfg.vocab, 12).astype(np.int32)
    eng = ServeEngine(lm, params, max_batch=4, s_max=64)
    out = eng.generate([prompt], max_new=6)[0]

    # manual reference loop
    cache = lm.init_cache(1, 64, dtype=jnp.float32)
    x = jnp.asarray(prompt[None])
    logits = None
    for t in range(len(prompt)):
        logits, cache = lm.decode_step(params, cache, x[:, t:t + 1])
    ref = []
    cur = int(jnp.argmax(logits[0, -1]))
    for _ in range(6):
        ref.append(cur)
        logits, cache = lm.decode_step(params, cache,
                                       jnp.asarray([[cur]], jnp.int32))
        cur = int(jnp.argmax(logits[0, -1]))
    assert out == ref


def test_engine_batches_requests():
    cfg, lm, params = _setup()
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, rng.randint(4, 10)).astype(np.int32)
               for _ in range(5)]
    eng = ServeEngine(lm, params, max_batch=2, s_max=64)
    outs = eng.generate(prompts, max_new=4)
    assert len(outs) == 5
    assert all(len(o) == 4 for o in outs)
    st = eng.stats()
    assert st["system_s"] >= st["accelerator_s"] > 0
    assert st["host_overhead_s"] >= 0
