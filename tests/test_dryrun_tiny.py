"""Dry-run machinery integration test (subprocess: needs its own jax device
count, 8 placeholder CPU devices, mesh (2,2,2) pod/data/model).

Validates the exact pipeline launch/dryrun.py runs at production scale:
abstract ShapeDtypeStruct inputs + resolver shardings -> lower -> compile ->
memory/cost analysis -> while-scaled collective parse, for a train cell and
a decode cell of a reduced config — plus the kv_seqshard §Perf variant."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs.registry import get_config, reduced
from repro.configs.shapes import ShapeCell
from repro.distributed import sharding as SH, hloparse as HP
from repro.launch import specs as SP
from repro.launch.mesh import make_test_mesh
from repro.models.model import LM
from repro.training import lm_step, optim as O

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced(get_config("yi-6b"))
lm = LM(cfg, constrain=SH.make_constrainer(mesh))
pspec = lm.param_specs(jnp.float32)
p_sh = SH.to_shardings(mesh, SH.param_pspecs(mesh, pspec))
out = {}

# --- train cell -----------------------------------------------------------
optimizer = O.get(cfg.optimizer, 1e-3)
opt_spec = jax.eval_shape(optimizer.init, pspec)
o_sh = SH.to_shardings(mesh, SH.param_pspecs(mesh, opt_spec))
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
b_sh = SH.to_shardings(mesh, SH.batch_pspec(mesh, batch))
step = jax.jit(lm_step.make_train_step(lm, optimizer),
               in_shardings=(p_sh, o_sh, b_sh))
with mesh:
    compiled = step.lower(pspec, opt_spec, batch).compile()
    hlo = compiled.as_text()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax < 0.5 returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
coll = HP.collective_bytes_scaled(hlo)
out["train"] = {"flops": float(cost.get("flops", 0)),
                "coll_kinds": sorted(coll),
                "coll_total": sum(coll.values()),
                "temp_bytes": int(mem.temp_size_in_bytes)}

# --- decode cell (baseline + kv_seqshard variant) ---------------------------
for name, seq_shard in (("decode", False), ("decode_seqshard", True)):
    cache = lm.init_cache(8, 64, dtype=jnp.float32, abstract=True)
    c_sh = SH.to_shardings(mesh, SH.cache_pspecs(mesh, cache,
                                                 seq_shard=seq_shard))
    t_sh = SH.to_shardings(mesh, SH.batch_pspec(
        mesh, jax.ShapeDtypeStruct((8, 1), jnp.int32)))
    dstep = jax.jit(lm_step.make_serve_step(lm),
                    in_shardings=(p_sh, c_sh, t_sh))
    with mesh:
        compiled = dstep.lower(pspec, cache,
                               jax.ShapeDtypeStruct((8, 1), jnp.int32)).compile()
    coll = HP.collective_bytes_scaled(compiled.as_text())
    out[name] = {"coll_total": sum(coll.values())}

print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_pipeline_tiny_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    # train cell compiled, produced collectives, fits in (tiny) memory
    assert out["train"]["flops"] > 0
    assert out["train"]["coll_total"] > 0
    assert out["train"]["temp_bytes"] > 0
    # both decode shardings compile; both produce some collective traffic
    assert out["decode"]["coll_total"] >= 0
    assert out["decode_seqshard"]["coll_total"] >= 0
