"""Paper Fig 3 analogue: input-sparsity stress — drop {0,25,50,75}% of input
spikes and track hardware TTFS accuracy. The paper reports graceful
degradation (87.40 -> 86.31 -> 82.38 -> 69.74%); we assert the same *shape*:
monotone decline, no cliff, and reference<->accelerator agreement preserved
at every drop ratio (the decision rule stays deterministic under stress)."""

from __future__ import annotations

import numpy as np

from benchmarks import common as CM
from repro.core.accelerator import SNNAccelerator
from repro.core.reference import SNNReference


def drop_spikes(images: np.ndarray, ratio: float, seed: int = 0) -> np.ndarray:
    """Zero a random fraction of ACTIVE pixels (a dropped input spike is a
    pixel that never fires)."""
    if ratio == 0:
        return images
    rng = np.random.RandomState(seed)
    out = images.copy()
    mask = (rng.rand(*images.shape) < ratio) & (images > 0)
    out[mask] = 0.0
    return out


def run(quick: bool = False) -> list[dict]:
    art, xte, yte = CM.get_artifact_and_data(quick)
    n = 4000 if not quick else 1000
    imgs, labels = xte[:n], yte[:n]
    ref = SNNReference(art)
    acc = SNNAccelerator(art, mode="batch")
    rows = []
    for ratio in (0.0, 0.25, 0.50, 0.75):
        x = drop_spikes(imgs, ratio)
        pr, pa = [], []
        for i in range(0, n, 2000):
            pr.append(np.asarray(ref.forward(x[i:i + 2000]).labels))
            pa.append(np.asarray(acc.forward(x[i:i + 2000]).labels))
        pr, pa = np.concatenate(pr), np.concatenate(pa)
        rows.append({
            "config": f"drop_{int(100 * ratio)}pct",
            "scope": "agreement",
            "drop_pct": 100 * ratio,
            "hw_ttfs_accuracy_pct": 100 * float(np.mean(pa == labels)),
            "ref_accuracy_pct": 100 * float(np.mean(pr == labels)),
            "ref_hw_mismatches": int(np.sum(pr != pa)),
        })
    CM.emit("sparsity", rows)
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print(f"{'drop%':>6} {'hw acc%':>9} {'ref acc%':>9} {'mismatch':>9}")
    for r in rows:
        print(f"{r['drop_pct']:>6.0f} {r['hw_ttfs_accuracy_pct']:>9.2f} "
              f"{r['ref_accuracy_pct']:>9.2f} {r['ref_hw_mismatches']:>9}")
    accs = [r["hw_ttfs_accuracy_pct"] for r in rows]
    assert all(a >= b - 1e-9 for a, b in zip(accs, accs[1:])), \
        "sparsity degradation must be monotone"


if __name__ == "__main__":
    main()
