"""Optimizers, dependency-free (no optax in this container): AdamW, Adafactor,
SGD-momentum. Functional API:

    opt = adamw(lr=3e-4)
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params)

All moments are f32 regardless of param dtype; parameter updates cast back.
Adafactor exists for the co-design reason documented in the big-MoE configs:
AdamW's 8 bytes/param does not fit single-pod HBM at 235B/398B scale.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    name: str


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "m": zeros,
                "v": jax.tree.map(jnp.copy, zeros)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update, "adamw")


def adafactor(lr: float = 3e-4, eps: float = 1e-30, clip: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments (Shazeer & Stern 2018), no first moment:
    state is O(rows+cols) per matrix instead of O(rows*cols) — the only way
    235B/398B optimizer state fits the single-pod HBM budget."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "f": jax.tree.map(per, params, is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rden = jnp.mean(vr, axis=-1, keepdims=True)
                u = g / (jnp.sqrt(vr / rden)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + 1e-16)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + 1e-16)
                ns = {"v": v}
            # update clipping (RMS <= clip)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-16)
            u = u / jnp.maximum(1.0, rms / clip)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_f = tdef.unflatten([o[1] for o in outs])
        return new_p, {"step": step, "f": new_f}

    return Optimizer(init, update, "adafactor")


def sgd(lr: float = 0.1, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
        out = jax.tree.map(upd, grads, state["mom"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": state["step"] + 1, "mom": new_m}

    return Optimizer(init, update, "sgd")


def get(name: str, lr: float) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](lr=lr)
