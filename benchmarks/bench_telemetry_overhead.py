"""Telemetry overhead gate — proves the tracing subsystem is near-free.

The telemetry design promise (README "Observability") is structural: every
instrumented call site guards on ``recorder.enabled`` and the module-level
default is a shared ``NullRecorder`` whose every method is a constant-time
no-op. This bench turns that promise into a CI gate, on the hottest
instrumented path in the repo (the fused accelerator runtime forward):

  * disabled overhead — the per-call cost of the no-op recorder is
    micro-measured directly (millions of guarded span calls), multiplied by
    the measured spans-per-image of the workload, and expressed as a
    percentage of the measured us/image. This is deliberately NOT an
    A/B wall-clock diff: the disabled path costs nanoseconds against a
    workload measured in microseconds, far below run-to-run jitter — the
    analytic bound is the only measurement that cannot be faked by noise.
    ``--check`` gates it at < 2%.
  * enabled overhead — median wall-clock of the workload with a live
    ``Tracer`` installed vs the no-op default, interleaved trials, negative
    diffs clamped to zero. ``--check`` gates it at < 10%.

Emits ``results/bench/telemetry_overhead.json`` (schema-validated, each row
carrying the schema's ``telemetry`` block).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks import common as CM
from repro.core.runtimes import make_runtime
from repro.telemetry import trace as ttrace
from repro.telemetry.trace import Tracer

SPEC = "accelerator-event-fused"
DISABLED_GATE_PCT = 2.0
ENABLED_GATE_PCT = 10.0


def _noop_call_ns(calls: int) -> float:
    """Median per-call cost of the guarded disabled-recorder pattern every
    instrumented site uses: fetch the module recorder, branch on
    ``.enabled``, and (for sites that don't early-out) drive one no-op
    span through enter/exit."""
    rec = ttrace.get()
    assert not rec.enabled, "disabled micro-bench needs the no-op recorder"
    reps = []
    for _ in range(5):
        t0 = time.perf_counter_ns()
        for _ in range(calls):
            r = ttrace.get()
            if r.enabled:                 # the hot-path guard
                pass
            with r.span("x", "system"):   # worst case: site skips the guard
                pass
        reps.append((time.perf_counter_ns() - t0) / calls)
    return float(np.median(reps))


def _time_forwards(rt, images: np.ndarray, repeats: int) -> list[float]:
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        rt.forward(images)
        out.append(time.perf_counter() - t0)
    return out


def main(quick: bool = False, check: bool = False) -> int:
    art, xte, yte = CM.get_artifact_and_data(quick=quick)
    images = xte[:32]
    repeats = 9 if quick else 21
    rt = make_runtime(art, SPEC)
    for _ in range(3):                    # compile + cache warm-up
        rt.forward(images)

    # ---- spans-per-image: count one traced forward -----------------------
    probe = Tracer()
    prev = ttrace.install(probe)
    try:
        rt.forward(images)
    finally:
        ttrace.install(prev)
    spans_per_img = len(probe.spans) / len(images)

    # ---- enabled vs disabled: strictly paired interleaved trials ---------
    # one disabled + one enabled forward per iteration, back to back, so
    # slow machine-level drift (thermal, cache, background load) cancels in
    # the pair instead of landing entirely on one arm
    dis_walls, en_walls = [], []
    tracer = Tracer()
    for _ in range(repeats):
        dis_walls.append(_time_forwards(rt, images, 1)[0])
        prev = ttrace.install(tracer)
        try:
            en_walls.append(_time_forwards(rt, images, 1)[0])
        finally:
            ttrace.install(prev)
    dis_us = 1e6 * float(np.median(dis_walls)) / len(images)
    en_us = 1e6 * float(np.median(en_walls)) / len(images)
    enabled_pct = max(0.0, 100.0 * (en_us - dis_us) / dis_us)

    # ---- disabled: analytic bound from the no-op call cost ---------------
    call_ns = _noop_call_ns(calls=50_000 if quick else 200_000)
    us_per_img = dis_us
    disabled_pct = 100.0 * (spans_per_img * call_ns / 1e3) / us_per_img

    rows = [
        {"runtime": SPEC, "config": "disabled",
         "scope": "telemetry (overhead gate, host wall-clock)",
         "us_per_image": us_per_img,
         "noop_call_us": call_ns / 1e3,
         "spans_per_image": spans_per_img,
         "overhead_pct": disabled_pct,
         "gate_pct": DISABLED_GATE_PCT,
         "telemetry": {"span_count": 0, "dropped_spans": 0,
                       "overhead_pct": disabled_pct}},
        {"runtime": SPEC, "config": "enabled",
         "scope": "telemetry (overhead gate, host wall-clock)",
         "us_per_image": en_us,
         "baseline_us_per_image": dis_us,
         "spans_per_image": spans_per_img,
         "overhead_pct": enabled_pct,
         "gate_pct": ENABLED_GATE_PCT,
         "telemetry": {"span_count": len(tracer.spans),
                       "dropped_spans": tracer.dropped,
                       "overhead_pct": enabled_pct}},
    ]
    CM.emit("telemetry_overhead", rows)

    print(f"telemetry overhead on {SPEC} ({len(images)} img/forward, "
          f"{spans_per_img:.2f} spans/img, {us_per_img:.1f} us/img):")
    print(f"  disabled  {disabled_pct:8.4f}%  "
          f"(no-op recorder call: {call_ns:.0f} ns; gate "
          f"< {DISABLED_GATE_PCT}%)")
    print(f"  enabled   {enabled_pct:8.2f}%  "
          f"({en_us:.1f} vs {dis_us:.1f} us/img; gate "
          f"< {ENABLED_GATE_PCT}%)")

    if check:
        bad = []
        if disabled_pct >= DISABLED_GATE_PCT:
            bad.append(f"disabled overhead {disabled_pct:.4f}% >= "
                       f"{DISABLED_GATE_PCT}%")
        if enabled_pct >= ENABLED_GATE_PCT:
            bad.append(f"enabled overhead {enabled_pct:.2f}% >= "
                       f"{ENABLED_GATE_PCT}%")
        if bad:
            print("CHECK FAILED: " + "; ".join(bad), file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer repeats (the CI configuration)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if disabled overhead >= 2% or enabled "
                         "overhead >= 10%")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check))
