"""Per-image board scheduler — the readable audit path of the emulator.

``SNNBoard`` consumes the SAME deployment artifact as ``SNNReference`` and
``SNNAccelerator`` (no conversion stage) and executes the paper's PL loop one
image at a time, one tick at a time:

    TTFS encode -> AER queue -> per-tick event dispatch into the grouped
    neuron core -> leak/integrate/fire -> grouped TTFS first-spike decode

with every tick's cycle and energy cost accounted against the board cost
model. ``latency_mode=True`` stops at the tick of the first output spike
(the paper's TTFS decision point — this is what the 0.1375 us/image service
latency measures); the default full-T mode runs the whole window so
first-spike times are bit-exact with the software reference on ALL neurons,
which is what the three-way agreement harness compares.

This path is deliberately plain Python/numpy — small, steppable, and slow.
``board.batched.SNNBoardBatched`` is the vectorized fast path proven
bit-exact against it (outputs AND traces).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.board.energy import BoardTrace, account, stack_traces
from repro.board.event_queue import AEREventQueue
from repro.board.neuron_core import GroupedNeuronCore
from repro.core import ttfs
from repro.core.artifact import Artifact
from repro.core.hw import BoardCostModel, PYNQ_COST
from repro.core.reference import SNNOutput


class SNNBoard:
    def __init__(self, artifact: Artifact, *, latency_mode: bool = False,
                 cost: BoardCostModel = PYNQ_COST):
        self.art = artifact
        self.cost = cost
        self.latency_mode = bool(latency_mode)
        self.T = int(artifact.m("encode", "T"))
        self.x_min = float(artifact.m("encode", "x_min"))
        self.n_out = int(artifact.m("model", "n_out"))
        self.depth = int(artifact.m("events", "e_max"))
        self.core = GroupedNeuronCore.from_artifact(artifact, cost)
        self.last_trace: BoardTrace | None = None

    # ------------------------------------------------------------- one image
    def run_image(self, times: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                                    int, BoardTrace]:
        """times (N_in,) int spike times -> (first (n_pad,), v (n_pad,),
        ticks_executed, trace)."""
        queue = AEREventQueue(times, self.T, self.depth)
        core = self.core
        core.reset()
        events = stalls = 0
        ticks = self.T
        for t, ids in queue:
            for nid in ids:
                core.dispatch(int(nid))
            events += len(ids)
            stalls += queue.stalls_at(t)
            fired = core.tick(t)
            if self.latency_mode and fired:
                ticks = t + 1
                break
        trace = account(events, ticks, stalls, core.n_pad, self.cost)
        return core.first_flat.copy(), core.v_flat.copy(), ticks, trace

    # ------------------------------------------------------------- batch API
    def forward(self, images) -> SNNOutput:
        images = np.atleast_2d(np.asarray(images, np.float32))
        times = np.asarray(ttfs.encode_ttfs(jnp.asarray(images), self.T,
                                            self.x_min))
        firsts, vs, steps, traces = [], [], [], []
        for row in times:
            first, v, ticks, trace = self.run_image(row)
            firsts.append(first[:self.n_out])
            vs.append(v[:self.n_out])
            steps.append(ticks)
            traces.append(trace)
        first_l = np.stack(firsts)
        v_l = np.stack(vs)
        labels = np.asarray(ttfs.decode_labels(
            first_l, v_l,
            n_groups=self.art.m("readout", "n_groups"),
            per_group=self.art.m("readout", "per_group"),
            sentinel=self.T, fallback=self.art.m("readout", "fallback")))
        self.last_trace = stack_traces(traces)
        return SNNOutput(labels=labels, first_spike=first_l, v_final=v_l,
                         steps=np.asarray(steps, np.int32))

    __call__ = forward
