"""Conformance subsystem: fuzzer validity, oracle stack, golden traces.

The suite must prove two directions: (a) the fuzzer generates valid,
deterministic artifacts that every advertised runtime spec agrees on, and
(b) the oracles actually CATCH divergence — a deliberately-wrong runtime and
tampered goldens must fail loudly, not be swallowed.
"""

import os

import numpy as np
import pytest

from _fakes import divergent_family, registered_family
from repro.conformance import fuzz_case, golden, run_case
from repro.conformance.fuzz import images_from_times
from repro.core import runtimes, ttfs
from repro.core.artifact import Artifact


# ------------------------------------------------------------------ fuzzer
def test_fuzz_case_deterministic():
    a, b = fuzz_case(42), fuzz_case(42)
    assert a.artifact.fingerprint() == b.artifact.fingerprint()
    assert np.array_equal(a.images, b.images)
    assert np.array_equal(a.times, b.times)
    assert fuzz_case(43).artifact.fingerprint() != a.artifact.fingerprint()


def test_images_from_times_roundtrip_and_validation():
    T = 16
    times = np.array([[0, 5, T - 2, T, T]])
    imgs = images_from_times(times, T)
    assert np.array_equal(np.asarray(ttfs.encode_ttfs(imgs, T, 1 / 255)),
                          times)
    # t = T-1 is unreachable for any x >= x_min > 0: the inverse refuses it
    with pytest.raises(ValueError, match=r"T-2"):
        images_from_times(np.array([[T - 1]]), T)
    with pytest.raises(ValueError, match="too small"):
        images_from_times(np.array([[0]]), 3)


def test_fuzzed_artifact_is_export_shaped(tmp_path):
    """A fuzzed artifact carries exactly the arrays/meta deploy.export emits,
    saves with an intact integrity manifest, and reloads verified."""
    case = fuzz_case(7)
    art = case.artifact
    for k in ("w_float", "w_int8", "thresholds", "group_ids", "w_padded",
              "thr_padded", "gid_padded", "block_table"):
        assert k in art.arrays, k
    n_out = art.m("model", "n_out")
    assert n_out == art.m("readout", "n_groups") * art.m("readout",
                                                         "per_group")
    assert art.m("codesign", "n_pad") % 128 == 0
    assert art.m("events", "e_max") % 128 == 0
    assert np.all(art["thr_padded"][n_out:] == np.int32(2**31 - 1))
    assert np.all(art["gid_padded"][n_out:] == -1)
    p = str(tmp_path / "fuzzed.npz")
    fp = art.save(p)
    assert Artifact.load(p).fingerprint() == fp    # load() verifies integrity


def test_adversarial_patterns_present():
    case = fuzz_case(5)
    T = case.artifact.m("encode", "T")
    names = case.notes["patterns"]
    for p in ("flood", "never", "ties", "ramp", "burst"):
        assert p in names
    flood = case.times[names.index("flood")]
    assert len(np.unique(flood)) == 1 and flood[0] < T   # one tick, all spike
    assert np.all(case.times[names.index("never")] == T)  # zero events


# ------------------------------------------------------------- oracle stack
@pytest.mark.parametrize("seed", [11, 12])
def test_oracle_stack_passes_on_fuzzed_cases(seed):
    rep = run_case(fuzz_case(seed))
    assert rep.passed, rep.summary()
    oracles = {o.oracle for o in rep.outcomes}
    assert {"registry", "differential", "sched-batched-full",
            "sched-batched-latency", "fifo", "cost-model", "quant",
            "events"} <= oracles


def test_divergent_runtime_is_caught_not_swallowed():
    with divergent_family():
        rep = run_case(fuzz_case(3), specs=("divergent",))
        assert not rep.passed
        by_oracle = {o.oracle: o for o in rep.failures()}
        # the registry oracle flags the unadvertised family...
        assert "divergent" in by_oracle["registry"].detail
        # ...and the differential oracle reports the mismatch counts
        diff = by_oracle["differential"]
        assert diff.spec == "divergent"
        assert diff.stats["labels"] == 1
        assert diff.stats["first_spike"] == 1
        assert "mismatches on 1 images" in diff.detail
        assert "FAIL [differential] divergent" in rep.summary()


# ------------------------------------------------------------------- golden
def test_committed_goldens_match_pinned_seed():
    """The committed tests/golden/ snapshots regenerate bit-exactly (one seed
    here; the bench gate checks the full pinned set)."""
    assert golden.check(seeds=[0]) == []


def test_golden_detects_tamper_and_missing(tmp_path):
    d = str(tmp_path)
    golden.regen(seeds=(0, 1), dirpath=d)
    assert golden.check(dirpath=d) == []

    p = golden.golden_path(1, d)
    with np.load(p) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["labels"][0] += 1
    np.savez(p, **arrays)
    diffs = golden.check(dirpath=d)
    assert any(x.seed == 1 and x.array == "labels" for x in diffs), diffs

    os.remove(golden.golden_path(0, d))
    diffs = golden.check(dirpath=d)
    assert any(x.seed == 0 and x.array == "<missing>" for x in diffs), diffs


def test_golden_missing_manifest_reported(tmp_path):
    diffs = golden.check(dirpath=str(tmp_path / "nowhere"))
    assert len(diffs) == 1 and "manifest" in diffs[0].detail


# ----------------------------------------------------------------- registry
def test_registry_consistency_on_fuzzed_artifact():
    assert runtimes.registry_consistency_errors(fuzz_case(1).artifact) == []


def test_registry_consistency_flags_unadvertised_family():
    """A family registered without an advertised spec is itself a conformance
    failure (the advertise<->construct contract, both directions)."""
    with registered_family("ghost", lambda art, opts, **kw: object()):
        errs = runtimes.registry_consistency_errors(fuzz_case(1).artifact)
        assert any("ghost" in e and "advertises no spec" in e for e in errs)
