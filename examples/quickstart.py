"""Quickstart — the paper's Table-2 workflow, end to end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Model definition  ->  snn.SNN / snn.Sequential / snn.Linear / snn.LIF
Artifact export   ->  deploy.export (one shared deployment artifact)
Runtime invoke    ->  SNNAccelerator(...).forward(x)   (module-style call)
"""

import numpy as np

from repro import snn, deploy
from repro.core.accelerator import SNNAccelerator
from repro.core.reference import SNNReference
from repro.data import mnist
from repro.training.ttfs_trainer import train_dense_proxy

# 1. data (procedural MNIST stand-in: this container is offline)
xtr, ytr = mnist.generate(8192, seed=1)
xte, yte = mnist.generate(2048, seed=2)

# 2. model definition + training (dense proxy of the grouped TTFS readout)
result = train_dense_proxy(xtr, ytr, test_images=xte, test_labels=yte,
                           epochs=2)
model = result.model          # snn.SNN(snn.Sequential(Linear(784,150), LIF))
print(f"trained: dense test accuracy {result.test_acc:.2%}")

# 3. single-artifact export: weights + thresholds + connectivity +
#    grouped TTFS decode metadata, integrity-hashed
art = deploy.export(model, "/tmp/quickstart_artifact.npz",
                    calib_images=xtr[:2048], calib_labels=ytr[:2048])
print(f"exported artifact: threshold={art['thresholds'][0]} "
      f"E_max={art.m('events', 'e_max')} "
      f"blocks={art.m('codesign', 'n_blocks')}x128 lanes")

# 4. the SAME artifact drives both runtimes (model(x)-style forward)
reference = SNNReference(art)
accelerator = SNNAccelerator(art, mode="batch")
out_ref = reference(xte)
out_acc = accelerator(xte)

agree = np.array_equal(np.asarray(out_ref.labels), np.asarray(out_acc.labels))
exact = np.array_equal(np.asarray(out_ref.first_spike),
                       np.asarray(out_acc.first_spike))
acc = float(np.mean(np.asarray(out_acc.labels) == yte))
print(f"TTFS accuracy {acc:.2%}; reference<->accelerator: "
      f"labels {'MATCH' if agree else 'MISMATCH'}, "
      f"spike times {'BIT-EXACT' if exact else 'DIFFER'} "
      f"on all {len(xte)} images")
assert agree and exact
