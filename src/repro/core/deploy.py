"""Export companion to the module graph (paper Table 2: deploy.export /
deploy.gen_config).

``export`` turns an ``snn.SNN`` into the single deployment artifact:

    1. quantize weights (fp32 -> symmetric int8),
    2. calibrate integer thresholds on calibration data (small deterministic
       search maximizing TTFS accuracy — the software side of co-design),
    3. calibrate the event-buffer depth E_max,
    4. run the deployment planner and emit the padded block layout
       (connectivity descriptor),
    5. write one .npz with weights (fp32 + int8), thresholds, connectivity
       descriptors, grouped decoding metadata, and integrity manifest.

The SAME file then drives ``SNNReference`` and ``SNNAccelerator``.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import codesign, events, quant, snn, ttfs
from repro.core.artifact import Artifact
from repro.core.lif_dynamics import lif_scan


def gen_config(model: snn.SNN) -> dict:
    """Deployment metadata for a model (no arrays) — inspection/debug aid."""
    lin = model.linear_layers()
    if len(lin) != 1:
        raise NotImplementedError(
            "the deployed path supports the paper's topology: exactly one "
            "Linear stage followed by a LIF stage (deeper/conv models are the "
            "paper's stated future work)")
    lif = model.lif_layers()[0] if model.lif_layers() else snn.LIF()
    leak_shift = quant.leak_shift_from_tau(lif.spec.tau)
    return {
        "model": {"topology": "linear-ttfs", "n_in": lin[0].in_features,
                  "n_out": lin[0].out_features},
        "encode": {"T": model.encode_t, "x_min": model.x_min},
        "lif": {"leak_shift": leak_shift, "v_init": 0},
        "readout": {"n_groups": model.readout.n_groups,
                    "per_group": model.readout.per_group,
                    "fallback": model.readout.fallback},
    }


def _ttfs_accuracy(w_int8, thr, leak_shift, T, x_min, images, labels,
                   n_groups, per_group, fallback) -> float:
    times = ttfs.encode_ttfs(jnp.asarray(images, jnp.float32), T, x_min)
    raster = ttfs.frames_from_times(times, T)
    cur = jax.lax.dot_general(raster, jnp.asarray(w_int8),
                              (((2,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    res = lif_scan(jnp.moveaxis(cur, 1, 0), jnp.asarray(thr), leak_shift, T)
    pred = ttfs.decode_labels(res.first_spike, res.v_final, n_groups=n_groups,
                              per_group=per_group, sentinel=T, fallback=fallback)
    return float(jnp.mean(pred == jnp.asarray(labels)))


def _per_neuron_peaks(w_int8, T, x_min, ls, calib_images) -> np.ndarray:
    """(B, N) per-neuron peak membrane over the calibration set at leak ls."""
    times = ttfs.encode_ttfs(jnp.asarray(calib_images, jnp.float32), T, x_min)
    raster = ttfs.frames_from_times(times, T)
    cur = jax.lax.dot_general(raster, jnp.asarray(w_int8),
                              (((2,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    cur = jnp.moveaxis(cur, 1, 0)  # (T, B, N)

    def step(v, i_t):
        v = v - jnp.right_shift(v, ls) + i_t
        return v, v

    _, vs = jax.lax.scan(step, jnp.zeros(cur.shape[1:], jnp.int32), cur)
    return np.asarray(jnp.max(vs, axis=0))


def calibrate_thresholds(w_int8: np.ndarray, meta: dict,
                         calib_images: np.ndarray, calib_labels: np.ndarray,
                         quantiles=(0.85, 0.9), scales=(0.7, 0.8, 0.9)
                         ) -> np.ndarray:
    """Per-neuron threshold calibration (EXPERIMENTS.md §Perf-SNN, +9.2 pp
    over a global threshold): theta_n = quantile_q over calibration images of
    neuron n's peak membrane, scaled; the (q, scale, leak) triple with best
    calibration TTFS accuracy wins. The chosen leak_shift is written back
    into the metadata (the artifact carries the deployed dynamics).
    Deterministic; returns per-neuron int32."""
    T = meta["encode"]["T"]
    x_min = meta["encode"]["x_min"]
    best = (None, -1.0, meta["lif"]["leak_shift"])
    for ls in sorted({meta["lif"]["leak_shift"], 31}):
        peaks = _per_neuron_peaks(w_int8, T, x_min, ls, calib_images)
        for q in quantiles:
            base = np.quantile(peaks, q, axis=0)
            for s in scales:
                thr = np.maximum(1, base * s).astype(np.int32)
                acc = _ttfs_accuracy(
                    w_int8, thr, ls, T, x_min, calib_images, calib_labels,
                    meta["readout"]["n_groups"], meta["readout"]["per_group"],
                    meta["readout"]["fallback"])
                if acc > best[1]:
                    best = (thr, acc, ls)
    meta["lif"]["leak_shift"] = int(best[2])
    meta["lif"]["calibration"] = {"method": "per-neuron-peak-quantile",
                                  "calib_accuracy": float(best[1])}
    return best[0]


def export(model: snn.SNN, path: str | None = None, *,
           calib_images: np.ndarray, calib_labels: np.ndarray,
           e_max_headroom: float = 1.0) -> Artifact:
    meta = gen_config(model)
    lin = model.linear_layers()[0]
    if lin.params is None:
        raise RuntimeError("model has no trained parameters; train first")
    w_f32 = np.asarray(lin.params["w"], np.float32)
    w_int8, scale = quant.quantize_weights(w_f32)
    meta["quant"] = {"scale": scale, "bits": 8, "scheme": "symmetric-per-tensor"}

    thr = calibrate_thresholds(w_int8, meta, calib_images, calib_labels)

    T = meta["encode"]["T"]
    times = np.asarray(ttfs.encode_ttfs(
        jnp.asarray(calib_images, jnp.float32), T, meta["encode"]["x_min"]))
    e_max = events.calibrate_e_max(times, T, headroom=e_max_headroom)
    meta["events"] = {"e_max": e_max, "pad": events.PAD}

    report = codesign.plan(lin.in_features, lin.out_features)
    meta["codesign"] = {"lane": report.lane, "n_pad": report.n_pad,
                        "n_blocks": report.n_blocks,
                        "vmem_util": report.vmem_util,
                        "limiter": report.limiter}
    gids = ttfs.group_map(meta["readout"]["n_groups"], meta["readout"]["per_group"])
    layout = codesign.blocked_layout(w_int8, thr, gids, report.lane)

    arrays = {"w_float": w_f32, "w_int8": w_int8, "thresholds": thr,
              "group_ids": gids, **layout}
    art = Artifact(meta, arrays)
    # calibration gate: every export must lower — run the single lowering
    # stage (uncached: no point warming the process cache with a fingerprint
    # that save() is about to restamp) so a malformed export fails HERE, at
    # the producer, not inside whichever runtime first consumes it
    from repro.core.lowering import lower
    lower(art, cache=False)
    if path is not None:
        art.save(path)
    else:
        art.meta["manifest"] = {k: "" for k in arrays}  # filled on save
    return art
