"""Seeded fault plans — the deterministic description of WHAT goes wrong.

A ``FaultPlan`` is an immutable, seed-driven recipe for every hardware and
host failure mode the resilience layer is built to survive. The plan itself
never touches a runtime: ``faults.models`` interprets it at three injection
sites (the artifact's BRAM-resident arrays, the board emulator's AER/neuron
datapath, the serving tier's worker lanes), and every draw is derived from
``(seed, stream, lane)`` so a fault sweep is exactly reproducible — the same
plan corrupts the same bits, drops the same events, crashes the same batch.

Fault classes (each maps to a detector in ``faults.detect``):

  static   — SEU bit flips in the int8 weight blocks / int32 thresholds of
             the deployment artifact's in-memory copy (the BRAM image).
             Applied by ``core.runtimes.make_runtime(..., faults=)`` to ANY
             runtime family via ``models.corrupt_artifact``; detected by the
             artifact's own per-array SHA-256 manifest.
  dynamic  — board-datapath faults the per-image scheduler (``board-py``)
             emulates event-by-event: membrane SEUs (with the BRAM parity /
             ECC detector modeled alongside, as on real FPGAs), stuck-at
             neuron groups, AER link drop/duplicate/reorder, and a forced
             FIFO depth (pure backpressure — semantically clean, stalls
             only). Other families reject dynamic plans loudly.
  lane     — host-side worker faults the serving scheduler injects around
             ``_Lane.serve``: crash (raises ``InjectedFault``), hang
             (sleeps past the watchdog), slowdown.

``FaultPlan.none()`` is the pinned clean plan: every runtime constructed
under it must stay bit-exact with the unfaulted build (asserted against the
PR 4 golden traces), so the injection hooks can never fork the clean path.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

#: membrane-SEU flips hit any of the 32 bits of an int32 membrane word
MEMBRANE_BITS = 32

#: fields a *dynamic* (board-datapath) plan may set — only ``board-py``
#: emulates these; ``make_runtime`` rejects them for every other spec
DYNAMIC_FIELDS = ("seu_membrane_rate", "stuck_groups", "aer_drop_rate",
                  "aer_dup_rate", "aer_reorder_rate", "fifo_depth")

#: fields a *static* (artifact-resident) plan may set — any runtime family
STATIC_FIELDS = ("seu_weight_flips", "seu_threshold_flips")

#: fields interpreted by the serving tier's lane injector only
LANE_FIELDS = ("crash_batches", "hang_batches", "slow_s")

#: spec-grammar aliases for ``FaultPlan.parse``
_PARSE_ALIASES = {
    "seu_weight": "seu_weight_flips", "seu_thr": "seu_threshold_flips",
    "membrane": "seu_membrane_rate", "stuck": "stuck_groups",
    "aer_drop": "aer_drop_rate", "aer_dup": "aer_dup_rate",
    "aer_reorder": "aer_reorder_rate", "fifo": "fifo_depth",
    "crash": "crash_batches", "hang": "hang_batches", "slow": "slow_s",
}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One seeded fault recipe. All-defaults == the clean plan."""

    seed: int = 0
    # ---- static: SEU bit flips in the artifact's BRAM-resident arrays ----
    seu_weight_flips: int = 0        # bits flipped across the weight blocks
    seu_threshold_flips: int = 0     # bits flipped across the threshold blocks
    # ---- dynamic: board-datapath faults (board-py emulates these) --------
    seu_membrane_rate: float = 0.0   # P(one membrane bit flips) per tick
    stuck_groups: int = 0            # hardware groups forced stuck-at
    stuck_mode: str = "saturated"    # "saturated" (fires at tick 0) | "silent"
    aer_drop_rate: float = 0.0       # P(event lost on the AER link)
    aer_dup_rate: float = 0.0        # P(event duplicated)
    aer_reorder_rate: float = 0.0    # P(event displaced across a tick edge)
    fifo_depth: int | None = None    # force the ingress FIFO depth (stalls)
    # ---- lane: host-side worker faults (serving scheduler injects) ------
    crash_batches: tuple[int, ...] = ()   # lane-local batch indices that crash
    hang_batches: tuple[int, ...] = ()    # lane-local batch indices that hang
    hang_s: float = 2.0                   # how long a hang sleeps
    slow_s: float = 0.0                   # added latency per batch
    lanes: tuple[int, ...] | None = None  # restrict faults to these lanes
    # ---- lifecycle -------------------------------------------------------
    persistent: bool = False         # re-apply on lane rebuild (unscrubable)

    # ------------------------------------------------------------- queries
    @property
    def has_static(self) -> bool:
        return any(getattr(self, f) for f in STATIC_FIELDS)

    @property
    def has_dynamic(self) -> bool:
        return any(getattr(self, f) not in (0, 0.0, None)
                   for f in DYNAMIC_FIELDS)

    @property
    def has_lane_faults(self) -> bool:
        return any(getattr(self, f) for f in LANE_FIELDS)

    @property
    def is_clean(self) -> bool:
        return not (self.has_static or self.has_dynamic
                    or self.has_lane_faults)

    @property
    def has_aer_faults(self) -> bool:
        return bool(self.aer_drop_rate or self.aer_dup_rate
                    or self.aer_reorder_rate)

    # ------------------------------------------------------------- factory
    @classmethod
    def none(cls, seed: int = 0) -> "FaultPlan":
        """The pinned clean plan — injection hooks active, zero faults."""
        return cls(seed=seed)

    @classmethod
    def coerce(cls, obj) -> "FaultPlan | None":
        """None | FaultPlan | spec string | kwargs dict -> FaultPlan | None."""
        if obj is None or isinstance(obj, cls):
            return obj
        if isinstance(obj, str):
            return cls.parse(obj)
        if isinstance(obj, dict):
            return cls(**obj)
        raise TypeError(f"cannot build a FaultPlan from {type(obj).__name__}")

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Spec-grammar extension: ``"seu_weight=4,aer_drop=0.02,seed=7"``.

        Keys are field names or the short aliases in ``_PARSE_ALIASES``;
        ``crash``/``hang`` take ``:``-separated batch indices (``crash=0:1``).
        An empty string parses to the clean plan."""
        kw: dict = {}
        fields = {f.name: f for f in dataclasses.fields(cls)}
        for part in filter(None, (p.strip() for p in text.split(","))):
            key, sep, val = part.partition("=")
            name = _PARSE_ALIASES.get(key, key)
            if name not in fields:
                raise ValueError(f"unknown fault-plan key {key!r} in {text!r}")
            if not sep:
                raise ValueError(f"fault-plan entry {part!r} needs '=value'")
            if name in ("crash_batches", "hang_batches", "lanes"):
                kw[name] = tuple(int(v) for v in val.split(":"))
            elif name in ("stuck_mode",):
                kw[name] = val
            elif name == "persistent":
                kw[name] = val.lower() in ("1", "true", "yes")
            elif name in ("seed", "seu_weight_flips", "seu_threshold_flips",
                          "stuck_groups", "fifo_depth"):
                kw[name] = int(val)
            else:
                kw[name] = float(val)
        return cls(**kw)

    # ------------------------------------------------------------ lifecycle
    def for_lane(self, lane_id: int) -> "FaultPlan":
        """The plan as one worker lane sees it: lanes outside ``lanes`` get
        the clean plan; in-scope lanes get a lane-decorrelated seed so two
        lanes never draw identical fault schedules."""
        if self.lanes is not None and lane_id not in self.lanes:
            return FaultPlan.none(seed=self.seed)
        return dataclasses.replace(self, seed=self.seed * 1000 + lane_id)

    def after_scrub(self) -> "FaultPlan":
        """The plan that survives a lane rebuild: a persistent fault
        (unscrubable — e.g. a stuck-at logic defect) re-applies; a transient
        one is gone once the BRAM image is reloaded from the golden copy."""
        return self if self.persistent else FaultPlan.none(seed=self.seed)

    # ------------------------------------------------------------- drawing
    def rng(self, *stream) -> np.random.RandomState:
        """Derived RandomState for one named injection stream — stable under
        plan-field changes that don't touch the seed, decorrelated across
        streams (hash of seed + stream path)."""
        h = hashlib.sha256(repr((self.seed,) + stream).encode()).digest()
        return np.random.RandomState(int.from_bytes(h[:4], "little"))

    def describe(self) -> str:
        active = [f"{f.name}={getattr(self, f.name)!r}"
                  for f in dataclasses.fields(self)
                  if f.name not in ("seed", "hang_s", "stuck_mode", "lanes",
                                    "persistent")
                  and getattr(self, f.name) not in (0, 0.0, None, ())]
        return (f"FaultPlan(seed={self.seed}, "
                + (", ".join(active) if active else "clean")
                + (", persistent" if self.persistent else "") + ")")
