"""Time-batched spike matmul — the MXU-native event pipeline.

The FPGA serializes events through a router because its datapath is scalar-
per-cycle. A systolic MXU wants the opposite: batch the whole T-step spike
window into a dense 0/1 int8 matrix and evaluate all synaptic currents as ONE
hardware-shaped matmul. This is the central hardware adaptation of the paper
(DESIGN.md §2): same integer semantics, reshaped for the target's compute
geometry.

    raster (M, K) int8 {0,1}  x  W (K, N) int8  ->  currents (M, N) int32
    M = B*T flattened spike rows, K = N_in (padded to 128), N = N_pad.

Tiling: grid (M/bm, N/bn, K/bk), K innermost with an int32 VMEM accumulator
initialized at k==0 — MXU-aligned (128 multiples), accumulation stays on-chip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smm_kernel(x_ref, w_ref, o_ref, *, k_blocks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def spike_matmul_kernel(raster: jnp.ndarray, w: jnp.ndarray, *,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    """raster (M, K) int8, w (K, N) int8 -> (M, N) int32. Dims must be padded
    to block multiples by the ops wrapper."""
    M, K = raster.shape
    K2, N = w.shape
    assert K == K2 and M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    grid = (M // block_m, N // block_n, K // block_k)
    kernel = functools.partial(_smm_kernel, k_blocks=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(raster, w)
