"""Mixture-of-Experts FFN with capacity-based dispatch (GSPMD-friendly).

Design notes (dry-run-safe on 256/512 devices):
  * No (tokens, E, C) one-hot dispatch tensor (the Mesh-TF formulation) —
    at Qwen3-MoE scale that is O(10^12) elements. Instead assignments are
    turned into (expert, position) integer coordinates via a cumsum over a
    (tokens, E) one-hot, and tokens are scatter-added into a (B, E, C, d)
    expert buffer. Scatter/gather are differentiable and GSPMD partitions
    them with reduce-scatter/all-gather collectives (visible in the roofline).
  * Capacity C = S * top_k / E * capacity_factor per batch row; overflow
    tokens are dropped (standard Switch behavior) — their combine weight is
    effectively zero, keeping semantics deterministic.
  * The `constrain` callback lets the distributed layer inject sharding
    constraints (E or C on "model") without models importing mesh code.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

Constrain = Callable[[jnp.ndarray, tuple], jnp.ndarray]
_noop: Constrain = lambda x, axes: x


def capacity(S: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(S * top_k / n_experts * factor))
    return max(8, ((c + 7) // 8) * 8)   # sublane-align


def moe_ffn(x: jnp.ndarray, p: dict, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.0,
            constrain: Constrain = _noop,
            buf_mode: str = "e_sharded") -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d); p: router (d, E), w_gate/w_up (E, d, f), w_down (E, f, d).

    buf_mode (§Perf knob):
      * "e_sharded"  — dispatch buffer sharded (batch, experts). GSPMD cannot
        partition the multi-dim scatter against a model-sharded E and
        replicates the FULL dispatch tensor (measured 137 GB f32 per MoE
        layer on qwen3-moe train — the worst collective term in the sweep).
      * "local"      — buffer sharded on batch only (E replicated): the
        scatter is device-local; the expert einsum treats E as a batch dim
        and slices it against the model-sharded weights for free; only the
        combine-gather pays one (B,S*k,d)-sized all-reduce over "model".

    Returns (out (B, S, d), aux_loss scalar) — aux is the Switch load-balance
    loss, to be added to the task loss by the caller."""
    B, S, d = x.shape
    E, k = n_experts, top_k
    C = capacity(S, k, E, capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                    # (B,S,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # load-balance aux (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    assign1 = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # --- dispatch coordinates -------------------------------------------
    flat_e = top_i.reshape(B, S * k)                           # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (B, S*k, E)
    onehot = constrain(onehot, ("data", None, "model"))
    pos = jnp.sum((jnp.cumsum(onehot, axis=1) - 1) * onehot, axis=-1)  # (B, S*k)
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)

    tok_of_assign = jnp.arange(S * k, dtype=jnp.int32) // k
    xk = jnp.take(x, tok_of_assign, axis=1)                    # (B, S*k, d)
    vals = jnp.where(keep[..., None], xk, 0)

    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
    buf_axes = ("data", None, None, None) if buf_mode == "local" \
        else ("data", "model", None, None)
    buf = jnp.zeros((B, E, C, d), x.dtype)
    buf = constrain(buf, buf_axes)
    buf = buf.at[b_idx, flat_e, pos_c].add(vals, mode="drop")
    buf = constrain(buf, buf_axes)

    # --- expert computation (SwiGLU) ------------------------------------
    h = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(h) * u
    h = constrain(h, ("data", "model", None, None))
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y = constrain(y, ("data", "model", None, None))

    # --- combine ----------------------------------------------------------
    out_k = y[b_idx, flat_e, pos_c]                            # (B, S*k, d)
    out_k = jnp.where(keep[..., None], out_k, 0)
    out_k = out_k * top_w.reshape(B, S * k)[..., None].astype(x.dtype)
    out = jnp.sum(out_k.reshape(B, S, k, d), axis=2)
    return out, aux.astype(jnp.float32)


def moe_ffn_shard_map(x: jnp.ndarray, p: dict, *, n_experts: int, top_k: int,
                      capacity_factor: float, mesh,
                      model_axis: str = "model"
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """True expert parallelism via shard_map — the §Perf A3 iteration.

    GSPMD cannot partition the dispatch scatter / combine gather against a
    model-sharded expert dim and falls back to replicating the GLOBAL
    (B, S*k, d) tensor (measured: 13-26 TB per MoE layer on qwen3-moe).
    Here the communication pattern is written explicitly instead:

      * x enters sharded on the data axes only, so every model rank already
        holds its data-shard's tokens (replicated over "model");
      * each model rank owns E/model_size experts, builds its (B, E_loc, C, d)
        dispatch buffer with a purely LOCAL scatter, runs its experts, and
        combines locally (masked to its own experts);
      * one psum over "model" of the (B, S, d) partial outputs merges the
        expert contributions — the only collective in the layer.

    Requires E % model_size == 0 (qwen3-moe 128, jamba 16: yes; mixtral 8:
    falls back to buf_mode="local" — the caller guards)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    E, k = n_experts, top_k
    msize = int(mesh.shape[model_axis])
    E_loc = E // msize
    C = capacity(S, k, E, capacity_factor)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local_moe(x_l, router, wg, wu, wd):
        # x_l (B_l, S, d) replicated over model; wg/wu/wd (E_loc, ...)
        B_l = x_l.shape[0]
        rank = jax.lax.axis_index(model_axis)
        logits = x_l.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                  # (B_l,S,E)
        top_w, top_i = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32),
                      axis=(0, 1))
        aux = E * jnp.sum(me * ce)

        flat_e = top_i.reshape(B_l, S * k)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=1) - 1) * onehot, axis=-1)
        keep = pos < C
        mine = (flat_e // E_loc) == rank
        e_loc = jnp.where(mine, flat_e - rank * E_loc, 0)
        pos_c = jnp.minimum(pos, C - 1)

        tok = jnp.take(x_l, jnp.arange(S * k, dtype=jnp.int32) // k, axis=1)
        vals = jnp.where((keep & mine)[..., None], tok, 0)
        b_idx = jnp.arange(B_l, dtype=jnp.int32)[:, None]
        buf = jnp.zeros((B_l, E_loc, C, d), x_l.dtype)
        buf = buf.at[b_idx, e_loc, pos_c].add(vals, mode="drop")  # LOCAL

        h = jnp.einsum("becd,edf->becf", buf, wg)
        u = jnp.einsum("becd,edf->becf", buf, wu)
        y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, wd)

        out_k = y[b_idx, e_loc, pos_c]                            # LOCAL
        out_k = jnp.where((keep & mine)[..., None], out_k, 0)
        out_k = out_k * top_w.reshape(B_l, S * k)[..., None].astype(x_l.dtype)
        out = jnp.sum(out_k.reshape(B_l, S, k, d), axis=2)
        out = jax.lax.psum(out, model_axis)       # the ONE collective
        # aux is identical on every model rank (x replicated) — average the
        # data axes contribution outside via the normal loss reduction.
        return out, aux

    fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_ffn_dense_oracle(x: jnp.ndarray, p: dict, *, n_experts: int,
                         top_k: int) -> jnp.ndarray:
    """Reference: evaluate every expert densely, combine top-k (no capacity
    drops). Tests compare moe_ffn against this with capacity_factor large
    enough that nothing drops."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    h = jnp.einsum("bsd,edf->besf", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->besf", x, p["w_up"])
    y = jnp.einsum("besf,efd->besd", jax.nn.silu(h) * u, p["w_down"])  # (B,E,S,d)
    mask = jax.nn.one_hot(top_i, n_experts, dtype=jnp.float32)          # (B,S,k,E)
    w_e = jnp.einsum("bske,bsk->bse", mask, top_w)                      # (B,S,E)
    return jnp.einsum("besd,bse->bsd", y, w_e.astype(x.dtype))
