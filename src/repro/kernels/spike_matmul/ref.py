"""Pure-jnp oracle for the time-batched spike matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spike_matmul_ref(raster: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """raster (..., K) int8, w (K, N) int8 -> (..., N) int32."""
    return jax.lax.dot_general(
        raster, w, (((raster.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
