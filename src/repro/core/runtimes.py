"""Runtime registry — one place that maps a spec string to a runner.

Every runtime consumes the SAME deployment artifact and exposes
``forward(images) -> SNNOutput``; the registry replaces the if/elif chains
that used to live in the agreement harness and the serving engine with a
declarative table, so adding a runtime (the board emulator is the third) is
one ``@register`` away.

Spec grammar: ``family[-mode[-kernel]]`` — the kernel suffix parses the
same way in every family (``opts.partition("-")``), so a spec that the
docstring advertises always constructs:

    reference                      software reference (the oracle)
    accelerator-batch[-jnp|pallas] time-batched MXU path
    accelerator-event[-jnp|pallas|fused]
                                   packed-event path (kernel picked via the
                                   suffix or the ``kernel=`` keyword)
    board[-batched[-jnp|pallas]]   board emulator, vectorized fast path
                                   (kernel suffix selects the LIF impl)
    board-py                       board emulator, per-image Python scheduler
                                   (no kernel suffix — it is plain python)

``ADVERTISED_SPECS`` enumerates every concrete spec above; the grammar
roundtrip test constructs each one, so docstring and parser cannot drift.

Factories ignore keywords they don't understand so harness-level defaults
(e.g. ``kernel=``) can be passed uniformly across families.
"""

from __future__ import annotations

from typing import Callable

from repro.core.artifact import Artifact

_REGISTRY: dict[str, Callable] = {}

#: every spec the module docstring advertises, fully expanded — each must
#: construct against any exported artifact (pinned by the roundtrip test).
ADVERTISED_SPECS = (
    "reference",
    "accelerator-batch", "accelerator-batch-jnp", "accelerator-batch-pallas",
    "accelerator-event", "accelerator-event-jnp", "accelerator-event-pallas",
    "accelerator-event-fused",
    "board", "board-batched", "board-batched-jnp", "board-batched-pallas",
    "board-py",
)


def register(family: str):
    def deco(factory: Callable) -> Callable:
        _REGISTRY[family] = factory
        return factory
    return deco


def available() -> list[str]:
    return sorted(_REGISTRY)


def make_runtime(artifact: Artifact, spec: str, **kw):
    """Build the runtime named by ``spec`` over ``artifact``."""
    family, _, opts = spec.partition("-")
    if family not in _REGISTRY:
        raise ValueError(f"unknown runtime family {family!r} in spec "
                         f"{spec!r}; available: {available()}")
    return _REGISTRY[family](artifact, opts, **kw)


@register("reference")
def _reference(art: Artifact, opts: str, **_):
    from repro.core.reference import SNNReference
    if opts:
        raise ValueError(f"reference runtime takes no options, got {opts!r}")
    return SNNReference(art)


@register("accelerator")
def _accelerator(art: Artifact, opts: str, kernel: str = "jnp", **_):
    from repro.core.accelerator import SNNAccelerator
    mode, _, k = opts.partition("-")
    return SNNAccelerator(art, mode=mode or "batch", kernel=k or kernel)


@register("board")
def _board(art: Artifact, opts: str, latency_mode: bool = False,
           kernel: str = "jnp", **_):
    from repro.board import SNNBoard, SNNBoardBatched
    mode, _, k = opts.partition("-")
    if mode in ("", "batched"):
        # kernel suffix parses uniformly with the accelerator family
        # ("board-batched-pallas"); forwarded, not swallowed: the batched
        # path understands jnp/pallas and rejects kernels it doesn't
        # (e.g. the accelerator-only "fused")
        return SNNBoardBatched(art, latency_mode=latency_mode,
                               kernel=k or kernel)
    if mode == "py":
        if k:
            raise ValueError(f"board-py takes no kernel suffix, got {k!r} "
                             "(the per-image scheduler is plain python)")
        return SNNBoard(art, latency_mode=latency_mode)  # plain python path
    raise ValueError(f"unknown board option {mode!r} "
                     "(use '', 'batched', 'py')")
