"""Divisibility-aware sharding resolver (MaxText-style logical rules, but
with explicit fallback chains so EVERY assigned config shards cleanly).

Why fallbacks are load-bearing here (DESIGN.md §5):
  * GQA KV heads are 4/6/8 across the pool — none divide the 16-way model
    axis. Fallback: shard head_dim (128/16=8) instead; attention contractions
    over head_dim become partial-sum + all-reduce, which GSPMD inserts.
  * qwen2.5-32b has 40 query heads (!%16). Same fallback.
  * whisper vocab 51865 and internvl2 vocab 92553 are not 16-divisible:
    embedding/logits fall back to replicated vocab + data-sharded d_model.
  * Mixtral has 8 experts (!%16): expert FFN shards d_ff_expert instead.

Parameters use TP("model") x FSDP(data axes): one dim on "model", a second
dim on ("pod","data") — ZeRO-3 semantics (XLA all-gathers weight shards per
layer and reduce-scatters grads). Stacked-layer leading dims are never
sharded (they are scanned over).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------- helpers
def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def resolve_axis(mesh: Mesh, dim: int, logical):
    """logical: None | 'model' | 'data' | tuple of fallback candidates.
    'data' means the full data-parallel prefix (pod+data)."""
    if logical is None:
        return None
    candidates = logical if isinstance(logical, tuple) else (logical,)
    for cand in candidates:
        if cand is None:
            return None
        mesh_axes = dp_axes(mesh) if cand == "data" else (cand,)
        mesh_axes = tuple(a for a in mesh_axes if a in mesh.axis_names)
        if not mesh_axes:
            continue
        if _fits(dim, mesh, mesh_axes):
            return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
    return None


def spec(mesh: Mesh, shape, logical_axes) -> P:
    """Build a PartitionSpec with per-dim divisibility fallback, ensuring no
    mesh axis is used twice."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set[str] = set()
    out = []
    for dim, logical in zip(shape, logical_axes):
        r = resolve_axis(mesh, dim, logical)
        flat = (r,) if isinstance(r, str) else (r or ())
        if r is not None and not (set(flat) & used):
            out.append(r)
            used.update(flat)
        else:
            out.append(None)
    return P(*out)


def make_constrainer(mesh: Mesh):
    """The callback models use: constrain(x, logical_axes). Carries the mesh
    (``constrain.mesh``) so shard_map-based layers can bind to it without
    models importing mesh construction."""
    def constrain(x, logical_axes):
        s = spec(mesh, x.shape, tuple(logical_axes))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
    constrain.mesh = mesh
    return constrain


# ------------------------------------------------- parameter sharding rules
# Suffix-matched rules: (regex on the flattened path) -> logical axes for the
# TRAILING dims (leading stack dims are replicated automatically).
_PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / head
    (r"embed$",         (("model", None), "data")),
    (r"lm_head$",       ("data", ("model", None))),
    # attention projections (d, F) / (F, d)
    (r"(wq|wk|wv|x_wq|x_wk|x_wv)$", ("data", ("model", None))),
    (r"(wo|x_wo)$",     (("model", None), "data")),
    # dense FFN
    (r"(w_gate|w_up|w_in)$",  ("data", ("model", None))),
    (r"(w_down|w_out)$",      (("model", None), "data")),
    # MoE experts (E, d, f) / (E, f, d) — E first, fall back to f
    (r"experts.*",      ()),   # placeholder, handled dimension-wise below
    (r"router$",        ("data", None)),
    # mamba
    (r"in_proj$",       ("data", ("model", None))),
    (r"out_proj$",      (("model", None), "data")),
    (r"conv_w$",        (None, ("model", None))),
    # biases / norms / scalars -> replicated
]


def _param_logical(path: str, shape) -> tuple:
    nd = len(shape)
    base = None
    for pat, rule in _PARAM_RULES:
        if re.search(pat, path):
            base = rule
            break
    # MoE expert tensors are 4D: (n_periods, E, d, f). The ndim>=4 guard is
    # load-bearing: dense stacked FFN weights are 3D (L, d, f), and treating
    # L as an expert dim sharded the layer stack over "model" — every use
    # then regathered the FULL stack inside the scan loop (found via the
    # §Perf HLO audit; dominated every dense train cell's collective term).
    if re.search(r"(w_gate|w_up|w_down)$", path) and nd >= 4 \
            and "blocks" in path:
        # (..., E, a, b): prefer E on model; fallback to the wide dim
        if re.search(r"w_down$", path):
            tail = (("model", None), (None,), "data")
            tail = (("model", None), ("model", None), "data")
        else:
            tail = (("model", None), "data", ("model", None))
        lead = (None,) * (nd - 3)
        return lead + tail
    if base is None or len(base) == 0:
        if nd >= 2:
            base = ("data", ("model", None))     # generic (in, out)
        else:
            return (None,) * nd
    lead = (None,) * (nd - len(base))
    return lead + tuple(base)


def _dedup(mesh: Mesh, shape, logical) -> P:
    return spec(mesh, shape, logical)


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _strip_data(logical) -> tuple:
    """Remove FSDP ('data') requests from a logical-axes tuple (TP-only)."""
    out = []
    for lg in logical:
        if lg == "data":
            out.append(None)
        elif isinstance(lg, tuple):
            kept = tuple(x for x in lg if x != "data")
            out.append(kept if kept else None)
        else:
            out.append(lg)
    return tuple(out)


def param_pspecs(mesh: Mesh, params_tree, *, fsdp: bool = True,
                 fsdp_mode: str = "hidden") -> Any:
    """PartitionSpec pytree for a (possibly abstract) params/opt-state tree.

    fsdp=True, fsdp_mode="hidden" (baseline): TP("model") x ZeRO-3 on a
    hidden weight dim. Measured pathology: XLA all-gathers the FULL stacked
    weight inside the layer loop (per iteration!) when the sliced stack's
    hidden dim is data-sharded — dominating every baseline train cell.

    fsdp_mode="stack" (§Perf variant): shard the layer-STACK dim (axis 0 of
    blocks/*) over the data axes instead. dynamic_slice then addresses one
    layer shard and the per-iteration gather is O(params/L), not O(params).

    fsdp=False ('tp_only'): weights shard on "model" only — valid whenever
    params + optimizer state fit per-chip HBM (tp_only_fits decides)."""
    def per(path, leaf):
        p = path_str(path)
        if leaf.ndim == 0:
            return P()
        logical = _param_logical(p, leaf.shape)
        if not fsdp:
            logical = _strip_data(logical)
        elif fsdp_mode == "stack" and "blocks" in p and leaf.ndim >= 3:
            stack = leaf.shape[0]
            logical = ("data",) + _strip_data(logical)[1:]
            if resolve_axis(mesh, stack, "data") is None:
                # stack not divisible (e.g. 9 jamba periods): keep hidden FSDP
                logical = _param_logical(p, leaf.shape)
        return _dedup(mesh, leaf.shape, logical)
    return jax.tree_util.tree_map_with_path(per, params_tree)


def param_shardings(mesh: Mesh, params_tree, *, fsdp: bool = True):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(mesh, params_tree, fsdp=fsdp))


def tp_only_fits(cfg, mesh, hbm_bytes: int, frac: float = 0.35) -> bool:
    """Co-design check (the planner's job, same spirit as the paper's
    BRAM-limited verdict): do TP-only params + optimizer state fit the HBM
    budget? If yes, FSDP's collective cost buys nothing."""
    model_ways = axis_size(mesh, ("model",))
    p_bytes = 2.0 * cfg.param_count() / model_ways             # bf16
    opt_mult = {"adamw": 4.0, "adafactor": 0.1, "sgd": 2.0}[cfg.optimizer]
    state = opt_mult * 2.0 * cfg.param_count() / model_ways
    return (p_bytes + state) <= frac * hbm_bytes


# ------------------------------------------------------------ cache/batch
def batch_pspec(mesh: Mesh, batch_tree) -> Any:
    def per(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        ax = resolve_axis(mesh, b, "data")
        return P(ax, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(per, batch_tree)


def cache_pspecs(mesh: Mesh, cache_tree, *, seq_shard: bool = False) -> Any:
    """KV/SSM cache sharding. Layout: attn k/v (periods, B, Hkv, S, D);
    mamba state (periods, B, H, N, P), conv (periods, B, K-1, ch).
    Preference: batch on data; heads on model (fallback head_dim/state-dim);
    if batch can't shard (B=1 long-context), shard the sequence dim on data.

    seq_shard=True (the §Perf "flash-decode" variant): shard the cache
    SEQUENCE dim on "model" instead of head_dim. Decode attention then
    reduces over the sharded S — GSPMD turns the softmax/PV into partial
    sums + tiny (B,H,1,*) all-reduces instead of repartitioning the whole
    cache (the 'involuntary full rematerialization' the baseline hits)."""
    def per(path, leaf):
        p = path_str(path)
        if leaf.ndim == 0:
            return P()
        if p.endswith("len"):
            return P()
        if "state" in p:   # (periods, B, H, N, Pdim)
            return spec(mesh, leaf.shape,
                        (None, "data", ("model", None), None, None))
        if "conv" in p:    # (periods, B, K-1, ch)
            return spec(mesh, leaf.shape,
                        (None, "data", None, ("model", None)))
        # attention caches (periods, B, Hkv, S, D)
        b, s = leaf.shape[1], leaf.shape[3]
        if seq_shard:
            batch_ax = "data" if resolve_axis(mesh, b, "data") else None
            return spec(mesh, leaf.shape,
                        (None, batch_ax, None, ("model", None), None))
        if resolve_axis(mesh, b, "data") is not None:
            return spec(mesh, leaf.shape,
                        (None, "data", ("model", None), None,
                         (None if _fits(leaf.shape[2], mesh, ("model",))
                          else "model")))
        # B=1: sequence-shard the cache on the data axes
        return spec(mesh, leaf.shape,
                    (None, None, ("model", None), "data",
                     (None if _fits(leaf.shape[2], mesh, ("model",))
                      else "model")))
    return jax.tree_util.tree_map_with_path(per, cache_tree)


def to_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))
