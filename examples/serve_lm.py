"""Batched LM serving demo — the engine behind the decode_* dry-run cells,
with the paper's scope-aware measurement discipline applied to serving:
accelerator-scope (jitted decode step) vs system-scope (queueing, batching,
host transfers) reported separately.

    PYTHONPATH=src python examples/serve_lm.py --arch yi-6b --requests 12
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.models.model import LM
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(lm, params, max_batch=args.max_batch, s_max=256)

    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, rng.randint(8, 24)).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=args.max_new)
    wall = time.perf_counter() - t0

    for i, o in enumerate(outs[:4]):
        print(f"req{i}: prompt[{len(prompts[i])}] -> {o}")
    st = engine.stats()
    total_tok = sum(len(o) for o in outs)
    print(f"\n{args.requests} requests, {total_tok} tokens in {wall:.2f}s "
          f"({total_tok / wall:.1f} tok/s, batch={args.max_batch})")
    print(f"accelerator-scope: {st['accelerator_s']:.2f}s   "
          f"system-scope: {st['system_s']:.2f}s   "
          f"host overhead: {st['host_overhead_s']:.2f}s")
    print("(same artifact->runtime discipline as the SNN path: the engine "
          "consumes the exported params unchanged)")


if __name__ == "__main__":
    main()
