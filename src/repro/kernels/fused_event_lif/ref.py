"""Pure-jnp mirror of the fused event→LIF megakernel.

Op-for-op the same recurrence the Pallas kernel runs: per timestep, gather
the weight rows of that step's events, sum them, then the LIF update and
first-spike latch. Two formulation tricks keep the mirror fast on CPU while
staying bit-exact (integer addition is associative and int8 values widened
to an int32 accumulator sum to the same result):

  * the weight matrix is augmented with one zero row and PAD ids are
    remapped to it, so masked-out events contribute exactly zero WITHOUT a
    select over materialized rows;
  * gathered rows stay int8 and are reduced with an int32 accumulator
    (4x less traffic than widening the gather).

For small problems the whole (B, T, E) gather is done in one vectorized op
(the per-step scan's dispatch overhead dominates there); past a size
threshold the T-loop scan takes over so the (B, T, E, N_pad) row tensor is
never materialized — which is exactly the megakernel's memory story and
where the large-batch speedup comes from.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# one-shot gather materializes B*T*E rows of int8; past this many bytes the
# per-step scan formulation is cheaper (and has bounded peak memory)
_ONE_SHOT_ROW_BYTES = 48 * 1024 * 1024


def _augment(w: jnp.ndarray) -> jnp.ndarray:
    """(N_in, N_pad) int8 -> (N_in + 1, N_pad) with a zero row for PAD."""
    return jnp.concatenate([w, jnp.zeros((1, w.shape[1]), w.dtype)], axis=0)


def _safe_ids(ids: jnp.ndarray, n_in: int) -> jnp.ndarray:
    return jnp.where(ids < 0, n_in, ids)


def _step_currents(safe_t: jnp.ndarray, w_aug: jnp.ndarray) -> jnp.ndarray:
    """safe_t (..., E) remapped ids -> (..., N_pad) int32 currents."""
    return jnp.sum(w_aug[safe_t], axis=-2, dtype=jnp.int32)


def fused_event_lif_ref(ids: jnp.ndarray, w: jnp.ndarray,
                        thresholds: jnp.ndarray, leak_shift: int
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ids (B, T, E_max) int32, w (N_in, N_pad) int8, thresholds (N_pad,)
    -> (first_spike (B, N_pad), v_final (B, N_pad)) int32."""
    B, T, E = ids.shape
    N_in, N = w.shape
    w_aug = _augment(w)
    safe = _safe_ids(ids, N_in)
    v0 = jnp.zeros((B, N), jnp.int32)
    first0 = jnp.full((B, N), T, jnp.int32)

    if B * T * E * N <= _ONE_SHOT_ROW_BYTES:
        currents = _step_currents(safe, w_aug)            # (B, T, N)

        def step(carry, xs):
            v, first = carry
            t, i_t = xs
            v = v - jnp.right_shift(v, leak_shift) + i_t
            fired = (v >= thresholds) & (first == T)
            first = jnp.where(fired, t, first)
            return (v, first), None

        ts = jnp.arange(T, dtype=jnp.int32)
        (v, first), _ = jax.lax.scan(step, (v0, first0),
                                     (ts, jnp.moveaxis(currents, 1, 0)))
        return first, v

    def step(carry, xs):
        v, first = carry
        t, safe_t = xs
        i_t = _step_currents(safe_t, w_aug)
        v = v - jnp.right_shift(v, leak_shift) + i_t
        fired = (v >= thresholds) & (first == T)
        first = jnp.where(fired, t, first)
        return (v, first), None

    ts = jnp.arange(T, dtype=jnp.int32)
    (v, first), _ = jax.lax.scan(step, (v0, first0),
                                 (ts, jnp.moveaxis(safe, 1, 0)))
    return first, v


def fused_event_lif_early_exit_ref(ids: jnp.ndarray, w: jnp.ndarray,
                                   thresholds: jnp.ndarray, leak_shift: int
                                   ) -> tuple[jnp.ndarray, jnp.ndarray,
                                              jnp.ndarray]:
    """Latency mode mirror: per example, integrate until ANY neuron fires —
    only the steps actually executed are gathered (work follows the TTFS
    decision point, not the window length). ids (B, T, E_max) ->
    (first (B, N_pad), v_at_exit (B, N_pad), steps (B,)); same contract as
    ``core.lif_dynamics.lif_scan_early_exit``."""
    B, T, E = ids.shape
    N_in, N = w.shape
    w_aug = _augment(w)
    safe = _safe_ids(ids, N_in)

    def one(safe_one):                                  # (T, E)
        def cond(state):
            t, v, first = state
            return (t < T) & jnp.all(first == T)

        def body(state):
            t, v, first = state
            safe_t = jax.lax.dynamic_index_in_dim(safe_one, t, axis=0,
                                                  keepdims=False)
            i_t = _step_currents(safe_t, w_aug)
            v = v - jnp.right_shift(v, leak_shift) + i_t
            fired = (v >= thresholds) & (first == T)
            first = jnp.where(fired, t, first)
            return (t + 1, v, first)

        t0 = jnp.int32(0)
        v0 = jnp.zeros((N,), jnp.int32)
        f0 = jnp.full((N,), T, jnp.int32)
        t, v, first = jax.lax.while_loop(cond, body, (t0, v0, f0))
        return first, v, t

    return jax.vmap(one)(safe)
