"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf]: 94L, d4096,
64H GQA(kv=4), 128 experts top-8 (expert d_ff 1536), vocab 151936, qk_norm.
Optimizer: adafactor (AdamW m/v at 235B exceeds the single-pod HBM budget —
the co-design planner's verdict; see EXPERIMENTS.md §Dry-run)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, vocab=151936,
    n_heads=64, n_kv_heads=4, d_head=128,
    n_experts=128, top_k=8, d_ff_expert=1536,
    qk_norm=True, rope_theta=1e6,
    optimizer="adafactor",
)
