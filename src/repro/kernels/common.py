"""Shared kernel plumbing: interpret-mode selection and padding helpers.

All kernels TARGET TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
VALIDATED on CPU via interpret=True, per the container contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pad_dim(x: jnp.ndarray, axis: int, multiple: int, value=0) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
