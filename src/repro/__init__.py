"""repro — event-driven SNN co-design framework rebuilt as a multi-pod JAX system.

Reproduction of: "Hardware-Software Co-Design for Event-Driven SNN Deployment on
Low-Cost Neuromorphic FPGAs" (Lee, Alam, Chakraborty, Park — CS.AR 2026),
adapted from PYNQ-Z2 (Zynq-7020) to TPU v5e-class hardware.

Public API surface (paper Table 2):

    from repro import snn, deploy
    from repro.core.accelerator import SNNAccelerator
    from repro.core.reference import SNNReference

    model = snn.SNN(snn.Sequential(snn.Linear(784, 150), snn.LIF(...)), ...)
    art   = deploy.export(model, "model.npz", calib=images)
    acc   = SNNAccelerator(art)
    labels = acc(images)            # module-style forward
"""

__version__ = "1.0.0"


def __getattr__(name):
    # Lazy so that `import repro` stays cheap and never touches jax device state.
    if name in ("snn", "deploy"):
        import importlib
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(name)
