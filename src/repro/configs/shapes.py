"""Assigned input-shape set (LM transformer shapes, seq_len x global_batch).

decode_* / long_* lower ``serve_step`` (one new token against a KV/SSM cache
of seq_len), NOT ``train_step``. long_500k requires sub-quadratic attention
and is skipped for pure full-attention archs (recorded in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeCell("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeCell("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeCell("long_500k",   524_288, 1,   "decode"),
}


def applicable(arch_cfg, shape_name: str) -> tuple[bool, str]:
    """Assignment skip rules. Returns (runs, reason-if-skipped)."""
    cell = SHAPES[shape_name]
    if cell.name == "long_500k" and not arch_cfg.subquadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{arch_cfg.name} is pure full-attention "
                       "(skip recorded in DESIGN.md §4)")
    return True, ""
