"""PyTorch-aligned SNN model construction (paper Table 2, left column).

    snn.SNN, snn.Sequential, snn.Linear, snn.LIF
        ~ nn.Module, nn.Sequential, nn.Linear + activation stage

Modules are torch-like to *hold* (shapes, hyperparameters, initialized
parameters) and jax-functional to *run*: `module.init(key)` returns a params
pytree and `module.apply(params, x)` is pure, so jax.grad/jit work untouched.
`module(x)` uses the module's own params for torch-style convenience.

The deployed subset matches the paper: Linear (dense synapse matrix) + LIF
(integrate-and-fire stage). The export companion (`repro.core.deploy`) turns
an `snn.SNN` into the single deployment artifact.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Module:
    """Minimal nn.Module-style base: subclasses define init/apply."""

    def init(self, key: jax.Array) -> Any:
        raise NotImplementedError

    def apply(self, params: Any, x: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if getattr(self, "params", None) is None:
            raise RuntimeError("module has no bound params; call .bind(params) "
                               "or construct with a key")
        return self.apply(self.params, x)

    def bind(self, params: Any) -> "Module":
        self.params = params
        return self


class Linear(Module):
    """Dense synapse matrix: y = x @ W.  No bias — the deployed classifier
    carries weights and thresholds only (paper §2.2)."""

    def __init__(self, in_features: int, out_features: int, key: jax.Array | None = None):
        self.in_features = in_features
        self.out_features = out_features
        self.params = None if key is None else self.init(key)

    def init(self, key: jax.Array):
        # Kaiming-uniform-ish, matching torch's default fan-in scaling.
        bound = 1.0 / np.sqrt(self.in_features)
        w = jax.random.uniform(key, (self.in_features, self.out_features),
                               jnp.float32, -bound, bound)
        return {"w": w}

    def apply(self, params, x):
        return x @ params["w"]


@dataclasses.dataclass
class LIFSpec:
    """LIF stage hyper-parameters (all deployment-artifact fields)."""
    threshold: float = 1.0          # float threshold used during training
    tau: float = 16.0               # leak time constant in steps (-> leak_shift)
    t_steps: int = 32               # simulation window T


class LIF(Module):
    """Leaky integrate-and-fire stage. In the *training* graph this acts as a
    dense surrogate (identity on synaptic currents — the TTFS decision rule is
    trained through the dense proxy, exactly how the paper's dense GPU/CPU
    baselines execute the same exported parameters). The *deployed* spiking
    dynamics live in the integer runtimes (reference.py / accelerator.py)."""

    def __init__(self, spec: LIFSpec | None = None, **kw):
        self.spec = spec or LIFSpec(**kw)
        self.params = {}

    def init(self, key):
        return {}

    def apply(self, params, x):
        return x  # dense-proxy surrogate; spiking semantics are runtime-side


class Sequential(Module):
    def __init__(self, *layers: Module):
        self.layers = list(layers)
        if all(getattr(layer, "params", None) is not None
               for layer in self.layers):
            self.params = [layer.params for layer in self.layers]
        else:
            self.params = None

    def init(self, key):
        keys = jax.random.split(key, len(self.layers))
        return [layer.init(k) for layer, k in zip(self.layers, keys)]

    def apply(self, params, x):
        for layer, p in zip(self.layers, params):
            x = layer.apply(p, x)
        return x


@dataclasses.dataclass
class ReadoutSpec:
    """Grouped TTFS readout metadata (paper §2.3: 10 classes x 15 neurons)."""
    n_groups: int = 10
    per_group: int = 15
    fallback: str = "membrane"


class SNN(Module):
    """Top-level model: a Sequential body + readout metadata. This is the
    object `deploy.export` consumes."""

    def __init__(self, body: Sequential, readout: ReadoutSpec | None = None,
                 encode_t: int = 32, x_min: float = 1.0 / 255.0):
        self.body = body
        self.readout = readout or ReadoutSpec()
        self.encode_t = encode_t
        self.x_min = x_min
        self.params = body.params

    def init(self, key):
        return self.body.init(key)

    def apply(self, params, x):
        return self.body.apply(params, x)

    # -- introspection used by deploy.export -------------------------------
    def linear_layers(self) -> Sequence[Linear]:
        return [layer for layer in self.body.layers
                if isinstance(layer, Linear)]

    def lif_layers(self) -> Sequence[LIF]:
        return [layer for layer in self.body.layers
                if isinstance(layer, LIF)]
