"""Integer quantization for deterministic deployment.

The board is fixed-point; so are we. Weights are symmetric-per-tensor int8,
membrane accumulation is int32, thresholds are int32, leak is a power-of-two
right shift. Both runtimes share these exact integer semantics, which is what
lets reference <-> accelerator agreement be *bit-exact* (the paper's
10,000/10,000 full-test-set match), not allclose.
"""

from __future__ import annotations

import numpy as np

INT8_MAX = 127
INT32_NEVER_FIRE = np.int32(2**31 - 1)  # threshold for padded lanes


def quantize_weights(w: np.ndarray, *, bits: int = 8) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor quantization. Returns (w_int8, scale) with
    w_float ~= w_int8 * scale."""
    qmax = 2 ** (bits - 1) - 1
    amax = float(np.max(np.abs(w)))
    if amax == 0.0:
        return np.zeros_like(w, dtype=np.int8), 1.0
    scale = amax / qmax
    w_q = np.clip(np.round(w / scale), -qmax, qmax).astype(np.int8)
    return w_q, scale


def dequantize(w_q: np.ndarray, scale: float) -> np.ndarray:
    return w_q.astype(np.float32) * scale


def leak_shift_from_tau(tau_steps: float) -> int:
    """Map a float leak time-constant (in steps) to the nearest power-of-two
    shift: v <- v - (v >> s) realizes decay factor (1 - 2**-s) per step.

    Edge cases (all deterministic, covered by tests):
      * tau <= 0 or tau == inf — the "leak disabled" sentinels model configs
        use; returns 31 (v >> 31 == 0 for plausible membranes, so no leak).
      * NaN — rejected loudly; a NaN tau is a training bug, and silently
        picking a shift would bake it into the deployed artifact.
      * very large finite tau — decay -> 1; saturates at the largest
        representable shift (15), the weakest realizable leak.
    """
    if np.isnan(tau_steps):
        raise ValueError("tau_steps is NaN — refusing to pick a leak shift")
    if tau_steps <= 0 or np.isinf(tau_steps):
        return 31  # effectively no leak (v >> 31 == 0 for plausible v)
    decay = np.exp(-1.0 / tau_steps)
    # choose s minimizing |(1 - 2^-s) - decay|
    candidates = np.arange(1, 16)
    s = int(candidates[np.argmin(np.abs((1 - 2.0 ** -candidates) - decay))])
    return s
