"""Pure-jnp oracle for event-driven accumulation."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.events import PAD


def event_accum_ref(ids: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """ids (T, E_max) int32 (PAD=-1), w (N_in, N_pad) int8 -> (T, N_pad) int32."""
    safe = jnp.maximum(ids, 0)
    rows = w[safe].astype(jnp.int32)                 # (T, E, N_pad)
    mask = (ids != PAD)[..., None]
    return jnp.sum(jnp.where(mask, rows, 0), axis=1)
