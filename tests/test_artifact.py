"""Deployment artifact: roundtrip, integrity, single-artifact discipline."""

import io
import json

import numpy as np
import pytest

from repro.core.artifact import Artifact, IntegrityError


def _mk():
    rng = np.random.RandomState(0)
    return Artifact(
        meta={"model": {"n_in": 8, "n_out": 4}, "encode": {"T": 8}},
        arrays={"w_int8": rng.randint(-127, 128, (8, 4)).astype(np.int8),
                "thresholds": rng.randint(1, 100, (4,)).astype(np.int32)})


def test_roundtrip(tmp_path):
    art = _mk()
    p = str(tmp_path / "a.npz")
    fp = art.save(p)
    art2 = Artifact.load(p)
    assert art2.meta["fingerprint"] == fp
    for k in art.arrays:
        assert np.array_equal(art.arrays[k], art2.arrays[k])
    assert art2.m("model", "n_in") == 8
    assert art2.m("missing", "key", default=42) == 42


def test_tamper_detection(tmp_path):
    art = _mk()
    p = str(tmp_path / "a.npz")
    art.save(p)
    loaded = Artifact.load(p, verify=False)
    loaded.arrays["w_int8"] = loaded.arrays["w_int8"].copy()
    loaded.arrays["w_int8"][0, 0] += 1
    with pytest.raises(IntegrityError):
        loaded.verify()


def test_missing_array_detection(tmp_path):
    art = _mk()
    p = str(tmp_path / "a.npz")
    art.save(p)
    loaded = Artifact.load(p, verify=False)
    del loaded.arrays["thresholds"]
    with pytest.raises(IntegrityError):
        loaded.verify()


def _rewrite_npz(path, mutate):
    """Load the raw npz payload, apply ``mutate(meta_dict, arrays_dict)``, and
    write it back — simulating on-disk corruption/tampering of a saved
    artifact without going through Artifact.save's re-hashing."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        arrays = {k: z[k].copy() for k in z.files if k != "__meta__"}
    mutate(meta, arrays)
    buf = io.BytesIO()
    np.savez(buf, __meta__=np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8), **arrays)
    with open(path, "wb") as f:
        f.write(buf.getvalue())


def test_load_rejects_bit_flipped_array_naming_it(tmp_path):
    """A single flipped bit on disk must fail loudly AND name the array."""
    p = str(tmp_path / "a.npz")
    _mk().save(p)

    def flip(meta, arrays):
        arrays["w_int8"][0, 0] ^= 1

    _rewrite_npz(p, flip)
    with pytest.raises(IntegrityError, match="w_int8"):
        Artifact.load(p)


def test_load_rejects_tampered_manifest_naming_array(tmp_path):
    """Editing a manifest digest inside __meta__ is tampering too — the load
    must fail and name the offending array, not just 'mismatch'."""
    p = str(tmp_path / "a.npz")
    _mk().save(p)

    def tamper(meta, arrays):
        meta["manifest"]["thresholds"] = "0" * 64

    _rewrite_npz(p, tamper)
    with pytest.raises(IntegrityError, match="thresholds"):
        Artifact.load(p)


def test_load_rejects_meta_tamper_outside_manifest(tmp_path):
    """Semantics-bearing meta (e.g. encode.T) is covered by the fingerprint."""
    p = str(tmp_path / "a.npz")
    _mk().save(p)

    def tamper(meta, arrays):
        meta["encode"]["T"] = 9999

    _rewrite_npz(p, tamper)
    with pytest.raises(IntegrityError, match="fingerprint"):
        Artifact.load(p)


def test_verify_names_missing_and_orphaned_arrays(tmp_path):
    p = str(tmp_path / "a.npz")
    _mk().save(p)
    loaded = Artifact.load(p, verify=False)
    del loaded.arrays["thresholds"]
    loaded.arrays["rogue"] = np.zeros(3)
    with pytest.raises(IntegrityError) as ei:
        loaded.verify()
    assert "thresholds" in str(ei.value) and "rogue" in str(ei.value)


def test_fingerprint_covers_meta(tmp_path):
    art = _mk()
    p = str(tmp_path / "a.npz")
    fp1 = art.save(p)
    art.meta["encode"]["T"] = 16
    assert art.fingerprint() != fp1


def test_zero_d_and_empty_arrays_roundtrip(tmp_path):
    """0-d scalars and 0-length arrays are legal payloads: they hash, save,
    reload, and verify like any other array (the manifest must not choke on
    an empty tobytes())."""
    art = Artifact(meta={"k": 1},
                   arrays={"scalar": np.array(3.5, np.float32),
                           "empty2d": np.zeros((0, 5), np.int32),
                           "empty1d": np.zeros((0,), np.int8)})
    p = str(tmp_path / "edge.npz")
    fp = art.save(p)
    art2 = Artifact.load(p)                     # verify=True path
    assert art2["scalar"].shape == () and float(art2["scalar"]) == 3.5
    assert art2["empty2d"].shape == (0, 5) and art2["empty2d"].dtype == np.int32
    assert art2["empty1d"].shape == (0,)
    assert art2.fingerprint() == fp
    # same values under a different shape/dtype must NOT collide: the hash
    # covers dtype and shape, not just bytes (both serialize to 0 bytes)
    reshaped = Artifact(meta={"k": 1},
                        arrays={"scalar": np.array(3.5, np.float32),
                                "empty2d": np.zeros((5, 0), np.int32),
                                "empty1d": np.zeros((0,), np.int16)})
    assert reshaped.fingerprint() != art2.fingerprint()


def test_fingerprint_stable_across_save_load_resave(tmp_path):
    """The fingerprint is a durable identity: save -> load -> fingerprint,
    and a second save of the loaded artifact, all agree bit-for-bit (the
    volatile manifest/fingerprint meta keys are excluded from hashing)."""
    art = _mk()
    p1, p2 = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
    fp1 = art.save(p1)
    loaded = Artifact.load(p1)
    assert loaded.fingerprint() == fp1
    fp2 = loaded.save(p2)
    assert fp2 == fp1
    assert Artifact.load(p2).fingerprint() == fp1


def test_m_path_lookup_edge_cases():
    art = _mk()
    # whole-subtree lookup and the empty path
    assert art.m("model") == {"n_in": 8, "n_out": 4}
    assert art.m() is art.meta
    # descending THROUGH a scalar is a miss, not a crash
    assert art.m("model", "n_in", "deeper") is None
    assert art.m("model", "n_in", "deeper", default=7) == 7
    # missing heads, with and without defaults
    assert art.m("absent") is None
    assert art.m("absent", "x", default="fb") == "fb"
    # present values win over provided defaults
    assert art.m("model", "n_out", default=99) == 4


def test_export_has_all_deployment_fields(trained_artifact):
    art, path, _ = trained_artifact
    # weights, thresholds, connectivity descriptors, decode metadata:
    for k in ("w_float", "w_int8", "thresholds", "w_padded", "thr_padded",
              "gid_padded", "block_table", "group_ids"):
        assert k in art.arrays, k
    assert art.m("readout", "n_groups") == 10
    assert art.m("readout", "per_group") == 15
    assert art.m("encode", "T") == 32
    assert art.m("events", "e_max") % 128 == 0
    assert art.m("codesign", "n_pad") == 256          # 150 -> 2 x 128 lanes
    # padded lanes can never fire
    assert np.all(art["thr_padded"][150:] == np.int32(2**31 - 1))
    assert np.all(art["gid_padded"][150:] == -1)
