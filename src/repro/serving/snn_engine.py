"""Batched SNN serving engine — the classifier's request-queue front-end.

Mirrors ``ServeEngine``'s measurement discipline (the paper's §2.3 split):
  * accelerator-scope — jitted device execution only (block_until_ready
    around the compiled forward);
  * system-scope — everything a request actually pays: queueing, TTFS
    encode, host-side spike packing, micro-batching, dispatch, readback.

Micro-batching pads every chunk to the engine's fixed ``max_batch`` so ONE
compiled program (the artifact's padded shapes) serves all traffic — no
recompiles as request counts vary, which is what "serve heavy traffic" needs.
Rows whose event frames exceed the artifact's calibrated E_max are NOT
dropped: the engine falls back to the dense time-batched path for exactly
those rows (the co-design overflow policy — the FPGA would backpressure, we
reroute), and counts the reroutes in stats.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ttfs
from repro.core.accelerator import SNNAccelerator
from repro.core.artifact import Artifact
from repro.core.events import EventFrames, pack_events_batched


@dataclasses.dataclass
class SNNRequest:
    rid: int
    image: np.ndarray            # (N_in,) float in [0, 1]
    label: int | None = None     # filled by flush()
    steps: int | None = None     # timesteps consumed (latency mode)
    fallback_dense: bool = False  # True if served via the dense path


class SNNServeEngine:
    """Request-queue classifier serving: submit() → flush() → labels.

    ``backend`` selects the runtime behind the queue:
      * "accelerator" (default) — the packed-event TPU path; ``kernel``
        selects its implementation ("fused" = the event→LIF→decode
        megakernel, the default; "jnp"/"pallas" = the staged pipeline).
      * "board" — the board-runtime emulator's batched fast path; every
        flush additionally accounts PL cycles and dynamic energy (the
        Table-3 analogue), surfaced in ``stats()``. The board never drops
        overflow events (FIFO backpressure costs cycles instead), so the
        dense reroute path does not apply.

    ``latency_mode`` serves with per-row early exit at the first output
    spike (the paper's TTFS decision latency)."""

    def __init__(self, artifact: Artifact, *, max_batch: int = 64,
                 kernel: str = "fused", latency_mode: bool = False,
                 backend: str = "accelerator"):
        if backend not in ("accelerator", "board"):
            raise ValueError(f"unknown backend {backend!r}")
        self.art = artifact
        self.backend = backend
        self.max_batch = int(max_batch)
        self.latency_mode = bool(latency_mode)
        if backend == "board":
            from repro.core.runtimes import make_runtime
            self.accel = make_runtime(artifact, "board",
                                      latency_mode=latency_mode)
        else:
            self.accel = SNNAccelerator(artifact, mode="event", kernel=kernel)
        self._dense = None                    # built lazily on first overflow
        self.T = int(artifact.m("encode", "T"))
        self.x_min = float(artifact.m("encode", "x_min"))
        self.e_max = int(artifact.m("events", "e_max"))
        self._queue: list[SNNRequest] = []
        self._next_rid = 0
        self.accel_s = 0.0
        self.system_s = 0.0
        self.images_out = 0
        self.overflow_fallbacks = 0
        self.batches = 0
        self.board_cycles = 0
        self.board_nj = 0.0
        self.board_stalls = 0

    # ----------------------------------------------------------------- queue
    def submit(self, image: np.ndarray) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(SNNRequest(rid, np.asarray(image, np.float32)))
        return rid

    def flush(self) -> dict[int, SNNRequest]:
        """Serve every queued request; returns {rid: completed request}."""
        t_sys0 = time.perf_counter()
        done: dict[int, SNNRequest] = {}
        q, self._queue = self._queue, []
        for i in range(0, len(q), self.max_batch):
            chunk = q[i:i + self.max_batch]
            self._serve_chunk(chunk)
            done.update({r.rid: r for r in chunk})
        self.system_s += time.perf_counter() - t_sys0
        return done

    def classify(self, images: Sequence[np.ndarray] | np.ndarray
                 ) -> np.ndarray:
        """Convenience batch API: images (B, N_in) -> labels (B,) int32."""
        rids = [self.submit(img) for img in np.asarray(images, np.float32)]
        done = self.flush()
        return np.asarray([done[r].label for r in rids], np.int32)

    # ------------------------------------------------------------ micro-batch
    def _pack(self, images: np.ndarray) -> EventFrames:
        """Host-side encode + spike packing (system-scope work, the paper's
        Fig-2 'spike packing' stage)."""
        times = np.asarray(ttfs.encode_ttfs(
            jnp.asarray(images, jnp.float32), self.T, self.x_min))
        return pack_events_batched(times, self.T, self.e_max)

    def _serve_chunk(self, chunk: list[SNNRequest]) -> None:
        k = len(chunk)
        images = np.zeros((self.max_batch, chunk[0].image.shape[-1]),
                          np.float32)
        for j, r in enumerate(chunk):
            images[j] = r.image                 # zero-pad to the fixed shape
        if self.backend == "board":
            self._serve_chunk_board(chunk, images)
            return
        frames = self._pack(images)
        overflow = np.asarray(frames.overflow)  # checked ONCE, on host arrays

        t0 = time.perf_counter()
        out = self.accel.forward(frames=frames,
                                 latency_mode=self.latency_mode,
                                 check_overflow=False)
        jax.block_until_ready(out.labels)
        self.accel_s += time.perf_counter() - t0
        labels = np.array(out.labels)           # writable copies (fallback
        steps = np.array(out.steps)             # rows are patched below)
        self.batches += 1

        bad = np.nonzero(overflow[:k])[0]
        if bad.size:
            # overflow policy: reroute those rows through the dense
            # time-batched path (same artifact, same semantics, no E_max
            # cap). Runs on the full fixed-shape padded buffer so the dense
            # program compiles once, not per distinct overflow-row count.
            if self._dense is None:
                self._dense = SNNAccelerator(self.art, mode="batch",
                                             kernel="jnp")
            t0 = time.perf_counter()
            dense_out = self._dense.forward(images=images)
            jax.block_until_ready(dense_out.labels)
            self.accel_s += time.perf_counter() - t0
            labels[bad] = np.asarray(dense_out.labels)[bad]
            steps[bad] = np.asarray(dense_out.steps)[bad]
            self.overflow_fallbacks += int(bad.size)

        for j, r in enumerate(chunk):
            r.label = int(labels[j])
            r.steps = int(steps[j])
            r.fallback_dense = bool(overflow[j])
        self.images_out += k

    def _serve_chunk_board(self, chunk: list[SNNRequest],
                           images: np.ndarray) -> None:
        """Board-emulator backend: one batched emulator run per chunk, with
        the PL cycle/energy account accumulated over the REAL rows only
        (pad rows clock too, but they are not served traffic)."""
        k = len(chunk)
        t0 = time.perf_counter()
        out = self.accel.forward(images)
        jax.block_until_ready(out.labels)
        self.accel_s += time.perf_counter() - t0
        labels = np.asarray(out.labels)
        steps = np.asarray(out.steps)
        tr = self.accel.last_trace
        self.board_cycles += int(np.sum(tr.cycles[:k]))
        self.board_nj += float(np.sum(tr.energy_nj[:k]))
        self.board_stalls += int(np.sum(tr.stalls[:k]))
        self.batches += 1
        for j, r in enumerate(chunk):
            r.label = int(labels[j])
            r.steps = int(steps[j])
        self.images_out += k

    # ----------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        """Zero the counters (e.g. after a warmup pass, so compile time does
        not pollute the measured trajectory)."""
        self.accel_s = self.system_s = 0.0
        self.images_out = self.overflow_fallbacks = self.batches = 0
        self.board_cycles = 0
        self.board_nj = 0.0
        self.board_stalls = 0

    def stats(self) -> dict:
        st = {
            "backend": self.backend,
            "accelerator_s": self.accel_s,
            "system_s": self.system_s,
            "host_overhead_s": max(0.0, self.system_s - self.accel_s),
            "images_out": self.images_out,
            "overflow_fallbacks": self.overflow_fallbacks,
            "batches": self.batches,
            "accel_us_per_image": (1e6 * self.accel_s / self.images_out
                                   if self.images_out else 0.0),
            "system_us_per_image": (1e6 * self.system_s / self.images_out
                                    if self.images_out else 0.0),
        }
        if self.backend == "board":
            n = max(1, self.images_out)
            clock = self.accel.cost.clock_hz
            st.update({
                "board_cycles": self.board_cycles,
                "board_stalls": self.board_stalls,
                "board_cycles_per_image": self.board_cycles / n,
                "board_model_us_per_image": 1e6 * self.board_cycles / n / clock,
                "board_nj_per_image": self.board_nj / n,
            })
        return st
