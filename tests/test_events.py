"""Event packing: roundtrip, determinism, overflow policy, calibration."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import events, ttfs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.RandomState(seed % 2**32)
    B, N, T = 3, 60, 8
    times = rng.randint(0, T + 1, (B, N)).astype(np.int32)
    e_max = events.calibrate_e_max(times, T, lane=8)
    frames = events.pack_events_batched(times, T, e_max)
    assert not np.any(np.asarray(frames.overflow))
    raster = np.asarray(events.unpack_to_raster(frames, N))
    expect = np.asarray(ttfs.frames_from_times(jnp.asarray(times), T))
    assert np.array_equal(raster, expect)


def test_batched_equals_loop_packer():
    rng = np.random.RandomState(0)
    times = rng.randint(0, 9, (4, 50)).astype(np.int32)
    a = events.pack_events(times, 8, 64)
    b = events.pack_events_batched(times, 8, 64)
    # same sets of ids per (b, t) — order within a step is id-sorted in both
    for bi in range(4):
        for t in range(8):
            ia = np.sort(np.asarray(a.ids[bi, t]))
            ib = np.sort(np.asarray(b.ids[bi, t]))
            assert np.array_equal(ia, ib)
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))


def test_overflow_flagged():
    times = np.zeros((1, 40), np.int32)        # all spike at t=0
    frames = events.pack_events_batched(times, 4, 16)
    assert bool(frames.overflow[0])
    full = events.pack_events_batched(times, 4, 64)
    assert not bool(full.overflow[0])


def test_calibrate_e_max_lane_aligned():
    rng = np.random.RandomState(1)
    times = rng.randint(0, 17, (16, 784)).astype(np.int32)
    e = events.calibrate_e_max(times, 16, lane=128)
    assert e % 128 == 0
    peak = max(int((times == t).sum(1).max()) for t in range(16))
    assert e >= peak
