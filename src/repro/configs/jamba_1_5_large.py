"""Jamba-1.5-Large 398B [arXiv:2403.19887]: hybrid Mamba+attention, 72L as
9 periods of [1 attn + 7 mamba] (the 1:7 interleave), MoE 16 experts top-2
every 2nd sublayer (d_ff 24576; dense SwiGLU of the same width otherwise),
d8192, 64H GQA(kv=8), vocab 65536. Param check: 16e*3*8192*24576*36 = 348B
experts + 21.7B dense FFN + 26B mamba + ~2B attn/embed ~= 398B total,
~94B active — matches the published 398B/94B. Optimizer: adafactor
(AdamW state alone would be 3.2 TB). Sub-quadratic via the mamba majority:
long_500k runs; the 9 attn layers keep full 500k KV caches, sharded on the
data axis."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, vocab=65536,
    n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, n_experts=16, top_k=2, d_ff_expert=24576, moe_period=2,
    layer_period=("attn",) + ("mamba",) * 7,
    ssm_d_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    rope_theta=1e6, optimizer="adafactor",
    subquadratic=True,
)
