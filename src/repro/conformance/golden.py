"""Golden-trace oracles — pinned-seed reference snapshots under tests/golden/.

The differential oracles catch a runtime drifting from the reference; they
cannot catch the REFERENCE ITSELF drifting (all runtimes moving together — a
semantics change in encode/LIF/decode would still be "bit-exact agreement").
Goldens close that hole: for a pinned seed set, the reference outputs (labels,
first-spike times, final membranes, steps) and the board cost account
(cycles, energy, events, stalls) are snapshotted to ``tests/golden/`` and
committed; ``check()`` regenerates each case from its seed and compares
array-for-array bit-exactly. The manifest additionally pins each seed's
**program fingerprint** (a cache-bypassing ``lower()`` of the fuzzed
artifact), so a lowering-semantics change — new scalar, different coercion,
reordered fingerprint input — surfaces as a reviewed golden diff even when
every runtime output is unchanged.

Regeneration (after an INTENTIONAL semantics change):

    PYTHONPATH=src python -m repro.conformance.golden --regen
    # or: python -m benchmarks.bench_conformance --regen

then commit the updated ``tests/golden/`` files; the diff IS the review
surface for the semantics change.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.conformance.fuzz import fuzz_case
from repro.core.runtimes import make_runtime

#: default seed set — disjoint from the bench fuzzer's seed base (1000+)
PINNED_SEEDS = tuple(range(8))

GOLDEN_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "tests", "golden"))

MANIFEST = "manifest.json"
FORMAT = 2


def golden_path(seed: int, dirpath: str = GOLDEN_DIR) -> str:
    return os.path.join(dirpath, f"conformance_seed{seed}.npz")


def compute_golden(seed: int) -> tuple[dict[str, np.ndarray], str, str]:
    """Regenerate the golden arrays for one pinned seed. Returns
    (arrays, artifact_fingerprint, program_fingerprint)."""
    from repro.core.lowering import lower

    case = fuzz_case(seed)
    prog_fp = lower(case.artifact, cache=False).fingerprint
    ref = make_runtime(case.artifact, "reference")
    out = ref.forward(case.images)
    board = make_runtime(case.artifact, "board")
    board.forward(case.images)
    tr = board.last_trace
    arrays = {
        "times": np.asarray(case.times, np.int32),
        "labels": np.asarray(out.labels, np.int32),
        "first_spike": np.asarray(out.first_spike, np.int32),
        "v_final": np.asarray(out.v_final, np.int32),
        "steps": np.asarray(out.steps, np.int32),
        "board_cycles": np.asarray(tr.cycles, np.int64),
        "board_events": np.asarray(tr.events, np.int64),
        "board_stalls": np.asarray(tr.stalls, np.int64),
        "board_energy_nj": np.asarray(tr.energy_nj, np.float64),
    }
    return arrays, case.artifact.fingerprint(), prog_fp


def regen(seeds=PINNED_SEEDS, dirpath: str = GOLDEN_DIR) -> dict:
    """(Re)write the golden snapshots + manifest. Returns the manifest."""
    os.makedirs(dirpath, exist_ok=True)
    manifest = {"format": FORMAT, "seeds": list(seeds), "fingerprints": {},
                "program_fingerprints": {}}
    for seed in seeds:
        arrays, fp, prog_fp = compute_golden(seed)
        np.savez(golden_path(seed, dirpath), **arrays)
        manifest["fingerprints"][str(seed)] = fp
        manifest["program_fingerprints"][str(seed)] = prog_fp
    with open(os.path.join(dirpath, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.write("\n")
    return manifest


@dataclasses.dataclass
class GoldenDiff:
    seed: int
    array: str          # which golden array drifted (or "<missing>"/"<meta>")
    detail: str

    def __str__(self) -> str:
        return f"seed {self.seed}: {self.array}: {self.detail}"


def check(seeds=None, dirpath: str = GOLDEN_DIR) -> list[GoldenDiff]:
    """Regenerate every pinned seed in memory and compare bit-exactly against
    the committed snapshots. Returns a list of diffs; empty means no drift."""
    mpath = os.path.join(dirpath, MANIFEST)
    if not os.path.exists(mpath):
        return [GoldenDiff(-1, "<missing>",
                           f"no golden manifest at {mpath} — run --regen "
                           f"and commit tests/golden/")]
    with open(mpath) as f:
        manifest = json.load(f)
    if seeds is None:
        seeds = manifest["seeds"]
    diffs: list[GoldenDiff] = []
    for seed in seeds:
        path = golden_path(seed, dirpath)
        if not os.path.exists(path):
            diffs.append(GoldenDiff(seed, "<missing>",
                                    f"snapshot {path} not found"))
            continue
        arrays, fp, prog_fp = compute_golden(seed)
        want_fp = manifest["fingerprints"].get(str(seed))
        if want_fp != fp:
            diffs.append(GoldenDiff(
                seed, "<meta>",
                f"artifact fingerprint {fp[:12]}… != manifest "
                f"{str(want_fp)[:12]}… — the fuzzer or artifact format "
                f"changed; rerun --regen if intentional"))
        want_prog = manifest.get("program_fingerprints", {}).get(str(seed))
        if want_prog != prog_fp:
            diffs.append(GoldenDiff(
                seed, "<program>",
                f"program fingerprint {prog_fp[:12]}… != manifest "
                f"{str(want_prog)[:12]}… — lowering semantics changed; "
                f"rerun --regen if intentional"))
        with np.load(path) as z:
            stored = {k: z[k] for k in z.files}
        for name, fresh in arrays.items():
            if name not in stored:
                diffs.append(GoldenDiff(seed, name, "absent from snapshot"))
                continue
            old = stored[name]
            if old.shape != fresh.shape or old.dtype != fresh.dtype:
                diffs.append(GoldenDiff(
                    seed, name, f"shape/dtype drift: snapshot "
                    f"{old.dtype}{old.shape} vs fresh {fresh.dtype}{fresh.shape}"))
            elif not np.array_equal(old, fresh):
                n = int(np.sum(old != fresh))
                diffs.append(GoldenDiff(
                    seed, name, f"{n}/{fresh.size} elements drifted "
                    f"(e.g. snapshot {old.ravel()[np.argmax((old != fresh).ravel())]} "
                    f"vs fresh {fresh.ravel()[np.argmax((old != fresh).ravel())]})"))
        for name in stored:
            if name not in arrays:
                diffs.append(GoldenDiff(seed, name,
                                        "snapshot has an array check no "
                                        "longer computes"))
    return diffs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--regen", action="store_true",
                    help="rewrite tests/golden/ from the pinned seeds")
    ap.add_argument("--seeds", type=int, nargs="*", default=None,
                    help="override the pinned seed set")
    ap.add_argument("--dir", default=GOLDEN_DIR,
                    help="golden directory (default: tests/golden/)")
    a = ap.parse_args(argv)
    seeds = tuple(a.seeds) if a.seeds else PINNED_SEEDS
    if a.regen:
        manifest = regen(seeds, a.dir)
        print(f"regenerated {len(manifest['seeds'])} golden snapshots "
              f"under {a.dir}")
        return 0
    diffs = check(None if a.seeds is None else seeds, a.dir)
    for d in diffs:
        print(f"GOLDEN DRIFT {d}")
    print(f"golden check: {'OK' if not diffs else f'{len(diffs)} drifts'}")
    return 1 if diffs else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
