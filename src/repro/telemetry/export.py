"""Telemetry exporters: JSONL trace dumps and Prometheus text exposition.

JSONL — one span per line in ``Span.full()`` form (canonical fields + wall
clocks + host meta), sorted by (trace, sid) so a dump of a deterministic run
is itself deterministic modulo the wall/meta fields. ``read_jsonl`` loads a
dump back into plain dicts; ``canonical_lines`` strips the nondeterministic
fields for cross-run diffing.

Prometheus — ``prometheus_text(registry)`` renders every counter, gauge and
histogram in the standard exposition format (``# TYPE`` headers, cumulative
``_bucket{le=...}`` counts, ``_sum``/``_count``), ready for a scrape
endpoint or a textfile collector:

    curl localhost:9000/metrics     # if served
    repro_lane_faults 3
    repro_request_latency_us_bucket{le="500.0"} 117

``program_cache_text()`` projects the active ``ProgramCache`` — residency,
byte gauge, eviction and hit/miss counters — through the same renderer, so
the LRU budget is scrapeable next to the serving metrics:

    repro_program_cache_bytes 33629
    repro_program_cache_evictions 2
"""

from __future__ import annotations

import json
import os

from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.trace import Tracer


# --------------------------------------------------------------------- JSONL
def write_jsonl(tracer: Tracer, path: str) -> int:
    """Dump every recorded span, one JSON object per line; returns the span
    count. Creates parent directories as needed."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    spans = tracer.sorted_spans()
    with open(path, "w") as f:
        for s in spans:
            f.write(json.dumps(s.full(), sort_keys=True,
                               separators=(",", ":")) + "\n")
    return len(spans)


def read_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def canonical_lines(path: str) -> list[dict]:
    """The dump with wall clocks and host meta stripped — two seeded runs'
    dumps must agree on this exactly."""
    out = []
    for d in read_jsonl(path):
        out.append({k: d[k] for k in
                    ("trace", "sid", "parent", "name", "scope", "attrs")})
    return out


# ---------------------------------------------------------------- Prometheus
def _fmt(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def _name(prefix: str, name: str) -> str:
    safe = "".join(ch if ch.isalnum() or ch == "_" else "_" for ch in name)
    return f"{prefix}_{safe}" if prefix else safe


def prometheus_text(registry: MetricsRegistry, prefix: str = "repro") -> str:
    """Render the registry in Prometheus exposition format. Histogram bucket
    counts are cumulative and always end with the ``+Inf`` bucket, per the
    format spec."""
    counters, gauges, hists = registry.collect()
    lines: list[str] = []
    for c in sorted(counters, key=lambda x: x.name):
        n = _name(prefix, c.name)
        lines.append(f"# TYPE {n} counter")
        lines.append(f"{n} {_fmt(c.value)}")
    for g in sorted(gauges, key=lambda x: x.name):
        n = _name(prefix, g.name)
        lines.append(f"# TYPE {n} gauge")
        lines.append(f"{n} {_fmt(g.value)}")
    for h in sorted(hists, key=lambda x: x.name):
        n = _name(prefix, h.name)
        lines.append(f"# TYPE {n} histogram")
        cum = 0
        for b, cnt in zip(h.buckets, h.counts):
            cum += cnt
            lines.append(f'{n}_bucket{{le="{b}"}} {cum}')
        cum += h.counts[-1]
        lines.append(f'{n}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{n}_sum {_fmt(h.sum)}")
        lines.append(f"{n}_count {h.count}")
    return "\n".join(lines) + "\n"


def program_cache_text(cache=None, prefix: str = "repro") -> str:
    """Prometheus exposition for a ``ProgramCache`` (default: the active
    one). Monotonic totals render as counters, residency as gauges."""
    from repro.core.lowering import get_cache
    st = (cache if cache is not None else get_cache()).stats()
    reg = MetricsRegistry()
    for name in ("evictions", "program_hits", "program_misses",
                 "bundle_hits", "bundle_misses"):
        reg.inc(f"program_cache_{name}", st[name])
    reg.set_gauge("program_cache_bytes", st["bytes"])
    reg.set_gauge("program_cache_programs", st["programs"])
    reg.set_gauge("program_cache_bundles", st["bundles"])
    if st["max_bytes"] is not None:
        reg.set_gauge("program_cache_max_bytes", st["max_bytes"])
    return prometheus_text(reg, prefix)
