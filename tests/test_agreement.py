"""The paper's headline validation: bit-exact reference<->accelerator
agreement over the test set, plus the repeatability protocol (§3.3)."""

import numpy as np

from _fakes import divergent_family
from repro.core.accelerator import SNNAccelerator
from repro.core.agreement import AgreementReport, full_agreement, repeatability
from repro.core.reference import SNNReference


def test_full_agreement_all_runtimes(trained_artifact):
    """Default harness is now three-way: reference / accelerator / board."""
    art, _, (xte, yte) = trained_artifact
    rep = full_agreement(art, xte[:512], yte[:512], chunk=256)
    assert rep.exact_match, rep.summary()
    assert rep.runtimes == ["reference", "accelerator-batch",
                            "accelerator-event", "board"]
    for rt in ("accelerator-batch", "accelerator-event", "board"):
        assert rep.label_mismatches[rt] == 0
        assert rep.spike_time_mismatches[rt] == 0


def test_pallas_kernel_path_agreement(trained_artifact):
    art, _, (xte, yte) = trained_artifact
    ref = SNNReference(art)
    out_ref = ref.forward(xte[:96])
    for mode in ("batch", "event"):
        acc = SNNAccelerator(art, mode=mode, kernel="pallas")
        out = acc.forward(xte[:96])
        assert np.array_equal(np.asarray(out.labels), np.asarray(out_ref.labels))
        assert np.array_equal(np.asarray(out.first_spike),
                              np.asarray(out_ref.first_spike))


def test_repeatability_protocol(trained_artifact):
    art, _, (xte, yte) = trained_artifact
    r = repeatability(art, xte[:256], yte[:256], runs=5, chunk=256)
    assert r["mismatches"] == 0
    assert r["image_run_pairs"] == 5 * 256
    assert r["accuracy_stable"]


def test_early_exit_labels_match_full_run(trained_artifact):
    """Event-driven early exit (decision at first spike) must decode the
    same labels as the full-T evaluation."""
    art, _, (xte, _) = trained_artifact
    acc = SNNAccelerator(art, mode="event")
    full = acc.forward(xte[:64])
    lat = acc.forward(xte[:64], latency_mode=True)
    assert np.array_equal(np.asarray(full.labels), np.asarray(lat.labels))
    # early exit must never take MORE steps than the window
    assert np.all(np.asarray(lat.steps) <= art.m("encode", "T"))


def test_agreement_report_summary_renders_every_field():
    """summary() is the harness's user-facing evidence — pin its shape for
    both the exact and the mismatching case without running any runtime."""
    rep = AgreementReport(
        n_images=4, runtimes=["reference", "fake-rt"],
        label_mismatches={"fake-rt": 2}, spike_time_mismatches={"fake-rt": 1},
        accuracy={"reference": 1.0, "fake-rt": 0.5},
        exact_match=False, wall_s=0.25)
    s = rep.summary()
    assert "agreement over 4 images" in s
    assert "reference" in s and "fake-rt" in s
    assert "label_mismatch=2" in s and "spike_time_mismatch=1" in s
    assert "acc=50.0000%" in s and "EXACT MATCH: False" in s

    ok = AgreementReport(n_images=2, runtimes=["reference"],
                         label_mismatches={}, spike_time_mismatches={},
                         accuracy={"reference": 1.0},
                         exact_match=True, wall_s=0.0)
    assert "EXACT MATCH: True" in ok.summary()


def test_divergent_runtime_reported_not_swallowed(trained_artifact):
    """A runtime that flips one label and one first-spike time must show up
    in the report's counts and summary — mismatches are never swallowed."""
    art, _, (xte, yte) = trained_artifact
    with divergent_family():
        rep = full_agreement(art, xte[:32], yte[:32],
                             runtimes=("divergent",), chunk=32)
        assert not rep.exact_match
        assert rep.label_mismatches["divergent"] == 1
        assert rep.spike_time_mismatches["divergent"] == 1
        assert "label_mismatch=1" in rep.summary()
        assert "EXACT MATCH: False" in rep.summary()


def test_repeatability_on_fuzzed_artifact():
    """The §3.3 protocol must hold for ANY valid artifact, not just the
    trained MNIST one — run it on a conformance-fuzzed artifact."""
    from repro.conformance import fuzz_case
    case = fuzz_case(21)
    labels = np.zeros(len(case.images), np.int64)   # accuracy values arbitrary
    r = repeatability(case.artifact, case.images, labels, runs=3, chunk=8)
    assert r["mismatches"] == 0
    assert r["image_run_pairs"] == 3 * len(case.images)
    assert len(r["accuracy_per_run"]) == 3 and r["accuracy_stable"]


def test_dense_baselines_execute_same_parameters(trained_artifact):
    """Table 3 discipline: dense rows reuse the exported parameters."""
    art, _, (xte, yte) = trained_artifact
    ref = SNNReference(art)
    acc_fp32 = float(np.mean(np.asarray(ref.dense_labels(xte, "fp32")) == yte))
    acc_int8 = float(np.mean(np.asarray(ref.dense_labels(xte, "int8")) == yte))
    ttfs = full_agreement(art, xte[:512], yte[:512], runtimes=(), chunk=256)
    # dense executions of the same weights are at least as accurate as TTFS
    # (the paper's ordering: 87.69/87.70 dense vs 87.40 TTFS)
    assert acc_fp32 >= ttfs.accuracy["reference"] - 0.02
    assert acc_int8 >= ttfs.accuracy["reference"] - 0.02
