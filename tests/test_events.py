"""Event packing: roundtrip, determinism, overflow policy, calibration."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import events, ttfs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.RandomState(seed % 2**32)
    B, N, T = 3, 60, 8
    times = rng.randint(0, T + 1, (B, N)).astype(np.int32)
    e_max = events.calibrate_e_max(times, T, lane=8)
    frames = events.pack_events_batched(times, T, e_max)
    assert not np.any(np.asarray(frames.overflow))
    raster = np.asarray(events.unpack_to_raster(frames, N))
    expect = np.asarray(ttfs.frames_from_times(jnp.asarray(times), T))
    assert np.array_equal(raster, expect)


def test_batched_equals_loop_packer():
    rng = np.random.RandomState(0)
    times = rng.randint(0, 9, (4, 50)).astype(np.int32)
    a = events.pack_events(times, 8, 64)
    b = events.pack_events_batched(times, 8, 64)
    # same sets of ids per (b, t) — order within a step is id-sorted in both
    for bi in range(4):
        for t in range(8):
            ia = np.sort(np.asarray(a.ids[bi, t]))
            ib = np.sort(np.asarray(b.ids[bi, t]))
            assert np.array_equal(ia, ib)
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))


def test_overflow_flagged():
    times = np.zeros((1, 40), np.int32)        # all spike at t=0
    frames = events.pack_events_batched(times, 4, 16)
    assert bool(frames.overflow[0])
    full = events.pack_events_batched(times, 4, 64)
    assert not bool(full.overflow[0])


def test_overflow_boundary_exact_fit_is_not_overflow():
    """count == e_max exactly fills the buffer — NOT an overflow; one more
    event flips the flag. Guards the off-by-one at the buffer boundary."""
    T, e_max = 4, 16
    exact = np.full((1, e_max), 0, np.int32)       # 16 events at t=0
    frames = events.pack_events_batched(exact, T, e_max)
    assert not bool(frames.overflow[0])
    assert int(frames.count[0, 0]) == e_max
    assert not np.any(np.asarray(frames.ids[0, 0]) == events.PAD)

    over = np.full((1, e_max + 1), 0, np.int32)    # 17 events at t=0
    frames = events.pack_events_batched(over, T, e_max)
    assert bool(frames.overflow[0])
    assert int(frames.count[0, 0]) == e_max        # deterministic truncation
    # the kept ids are the e_max lowest (stable (time, id) order)
    assert np.array_equal(np.asarray(frames.ids[0, 0]), np.arange(e_max))


def test_overflow_boundary_loop_packer_matches():
    """The reference loop packer applies the same boundary rule."""
    T, e_max = 3, 8
    times = np.zeros((2, e_max + 1), np.int32)
    times[0, -1] = T                               # row 0: exactly e_max at t=0
    a = events.pack_events(times, T, e_max)
    b = events.pack_events_batched(times, T, e_max)
    assert np.array_equal(np.asarray(a.overflow), np.asarray(b.overflow))
    assert np.array_equal(np.asarray(a.overflow), [False, True])
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))


def test_calibrate_e_max_exact_lane_boundary_rounding():
    """A peak exactly on a lane multiple must NOT round up a whole extra
    lane; one past it must."""
    lane = 8
    times = np.zeros((1, lane), np.int32)          # peak == lane exactly
    assert events.calibrate_e_max(times, T=2, lane=lane) == lane
    times = np.zeros((1, lane + 1), np.int32)      # peak == lane + 1
    assert events.calibrate_e_max(times, T=2, lane=lane) == 2 * lane
    # headroom scaling rounds up through the boundary too
    times = np.zeros((1, lane), np.int32)
    assert events.calibrate_e_max(times, T=2, lane=lane,
                                  headroom=1.25) == 2 * lane


def test_calibrate_e_max_lane_aligned():
    rng = np.random.RandomState(1)
    times = rng.randint(0, 17, (16, 784)).astype(np.int32)
    e = events.calibrate_e_max(times, 16, lane=128)
    assert e % 128 == 0
    peak = max(int((times == t).sum(1).max()) for t in range(16))
    assert e >= peak
