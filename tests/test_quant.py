"""Quantization: roundtrip bounds and leak mapping."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.RandomState(seed % 2**32)
    w = rng.randn(32, 16).astype(np.float32)
    q, scale = quant.quantize_weights(w)
    assert q.dtype == np.int8
    err = np.max(np.abs(quant.dequantize(q, scale) - w))
    assert err <= scale / 2 + 1e-7          # round-to-nearest bound


def test_quantize_zero_weights():
    q, scale = quant.quantize_weights(np.zeros((4, 4), np.float32))
    assert np.all(q == 0) and scale == 1.0


def test_leak_shift_monotone():
    shifts = [quant.leak_shift_from_tau(t) for t in (2.0, 8.0, 32.0, 128.0)]
    assert shifts == sorted(shifts)          # longer tau -> weaker leak
    assert quant.leak_shift_from_tau(np.inf) == 31


def test_leak_shift_nonpositive_tau_is_no_leak_sentinel():
    """tau <= 0 is the 'leak disabled' config sentinel: shift 31 means
    v >> 31 == 0 for any plausible membrane, i.e. no leak. Pinned so the
    deployed dynamics can't silently change under a config typo."""
    for tau in (0.0, -1.0, -np.inf):
        assert quant.leak_shift_from_tau(tau) == 31


def test_leak_shift_nan_rejected():
    with pytest.raises(ValueError, match="NaN"):
        quant.leak_shift_from_tau(float("nan"))


def test_leak_shift_very_large_tau_saturates():
    """decay -> 1 as tau grows; the shift saturates at the largest
    representable candidate (15), the weakest realizable leak."""
    assert quant.leak_shift_from_tau(1e6) == 15
    assert quant.leak_shift_from_tau(1e300) == 15
    # and the saturation is stable: larger finite tau cannot decrease it
    assert quant.leak_shift_from_tau(1e12) == 15


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(-24, 24))
def test_quantize_roundtrip_bound_across_magnitudes(seed, log2_mag):
    """Property: the scale/2 round-to-nearest bound holds across 48 octaves
    of weight magnitude (tiny nets, heavy-tailed nets, near-denormal nets),
    and the int8 range is symmetric (|q| <= 127, no -128)."""
    rng = np.random.RandomState(seed % 2**32)
    w = (rng.randn(16, 8) * 2.0 ** log2_mag).astype(np.float32)
    q, scale = quant.quantize_weights(w)
    assert scale > 0
    assert int(np.max(np.abs(q.astype(np.int32)))) <= 127
    err = float(np.max(np.abs(quant.dequantize(q, scale) - w)))
    assert err <= scale * (0.5 + 1e-5)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 200), st.integers(0, 200))
def test_leak_shift_monotone_property(i, j):
    """Property: leak_shift_from_tau is monotone nondecreasing in tau over
    the whole finite range (longer time constant can never mean a STRONGER
    realized leak), and every finite shift stays below the no-leak
    sentinel (31)."""
    tau_a, tau_b = sorted((2.0 ** (i / 8.0 - 2.0), 2.0 ** (j / 8.0 - 2.0)))
    s_a, s_b = (quant.leak_shift_from_tau(tau_a),
                quant.leak_shift_from_tau(tau_b))
    assert s_a <= s_b
    assert 1 <= s_a <= 15 and 1 <= s_b <= 15      # finite tau: realizable shift
    assert s_b <= quant.leak_shift_from_tau(np.inf)  # sentinel dominates


def test_leak_shift_tiny_positive_tau_is_strongest_leak():
    """tau -> 0+ gives decay -> 0; the closest realizable decay is
    1 - 2**-1 = 0.5, i.e. shift 1 (the strongest hardware leak)."""
    assert quant.leak_shift_from_tau(1e-9) == 1
