"""Cross-process program distribution: serialize/deserialize + broadcast.

The envelope is the multi-host companion to the lowering stage: the leader
lowers once, every follower reconstructs the program from (envelope, local
artifact) without ever calling ``_lower_uncached``. These tests pin the
roundtrip's bit-exactness, every rejection path (wrong artifact, tampered
scalars/hashes, dropped keys, truncation — via the conformance envelope
mutator), cache seeding, and the leader/follower broadcast hook over both
an in-memory and the shared-file transport.
"""

import copy
import json
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.conformance.fuzz import fuzz_case, fuzz_envelope_mutations
from repro.core.artifact import Artifact
from repro.core.lowering import ProgramCache, install, lower
from repro.core.program_io import (FORMAT_VERSION, ProgramIOError,
                                   deserialize_program, serialize_program)
from repro.launch.mesh import (ProgramBroadcastError, broadcast_program,
                               file_fetcher, file_publisher)

ARRAYS = ("w_float", "w_int8", "thresholds", "w_padded", "thr_padded")


def _clone(art: Artifact) -> Artifact:
    return Artifact(copy.deepcopy(art.meta), dict(art.arrays))


@pytest.fixture()
def scoped_cache():
    cache = ProgramCache()
    prev = install(cache)
    yield cache
    install(prev)


# ------------------------------------------------------------- roundtrip
def test_roundtrip_is_bit_identical_to_fresh_lower(trained_artifact):
    art, _, _ = trained_artifact
    fresh = lower(art, cache=False)
    blob = serialize_program(fresh)
    rt = deserialize_program(blob, art, cache=False)
    assert rt.fingerprint == fresh.fingerprint
    for f in ("T", "x_min", "e_max", "leak_shift", "n_in", "n_out",
              "n_groups", "per_group", "fallback", "scale", "n_pad", "lane"):
        assert getattr(rt, f) == getattr(fresh, f), f
    assert rt.encode == fresh.encode
    assert rt.decode == fresh.decode
    for name in ARRAYS:
        a, b = np.asarray(getattr(rt, name)), np.asarray(getattr(fresh, name))
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)
    # canonical: serializing the reconstruction reproduces the exact bytes
    assert serialize_program(rt) == blob


def test_roundtrip_across_fuzzed_artifacts():
    for seed in (0, 3, 7):
        art = fuzz_case(seed).artifact
        fresh = lower(art, cache=False)
        rt = deserialize_program(serialize_program(fresh), art, cache=False)
        assert rt.fingerprint == fresh.fingerprint, f"seed {seed}"


def test_serialize_rejects_non_program():
    with pytest.raises(TypeError):
        serialize_program({"not": "a program"})
    with pytest.raises(TypeError):
        deserialize_program(b"{}", {"not": "an artifact"})


# ------------------------------------------------------------- rejection
def test_wrong_artifact_rejected(trained_artifact):
    art, _, _ = trained_artifact
    blob = serialize_program(lower(art, cache=False))
    other = _clone(art)
    other.meta["events"]["e_max"] = int(other.meta["events"]["e_max"]) + 1
    with pytest.raises(ProgramIOError, match="artifact fingerprint"):
        deserialize_program(blob, other, cache=False)


def test_every_envelope_mutation_is_rejected(trained_artifact):
    art, _, _ = trained_artifact
    blob = serialize_program(lower(art, cache=False))
    muts = fuzz_envelope_mutations(blob, seed=5)
    assert len(muts) == 5
    for desc, bad in muts:
        with pytest.raises(ProgramIOError):
            deserialize_program(bad, art, cache=False)
        # and none of them half-applied anything: the pristine blob still works
    assert deserialize_program(blob, art,
                               cache=False).fingerprint \
        == lower(art, cache=False).fingerprint


def test_tampered_array_hash_names_the_array(trained_artifact):
    art, _, _ = trained_artifact
    env = json.loads(serialize_program(lower(art, cache=False)))
    digest = env["arrays"]["w_padded"]
    env["arrays"]["w_padded"] = ("0" if digest[0] != "0" else "1") + digest[1:]
    bad = json.dumps(env, sort_keys=True, separators=(",", ":")).encode()
    with pytest.raises(ProgramIOError, match="w_padded"):
        deserialize_program(bad, art, cache=False)


# ---------------------------------------------------------- cache seeding
def test_deserialize_seeds_the_active_cache(trained_artifact, scoped_cache):
    art, _, _ = trained_artifact
    blob = serialize_program(lower(art, cache=False))
    prog = deserialize_program(blob, art)
    st = scoped_cache.stats()
    assert st["programs"] == 1
    # a later lower() on this host is a pure cache hit — no lowering
    assert lower(art) is prog
    assert scoped_cache.stats()["program_misses"] == st["program_misses"]


def test_seed_first_installer_wins(trained_artifact, scoped_cache):
    art, _, _ = trained_artifact
    resident = lower(art)                     # installed by lowering
    blob = serialize_program(resident)
    seeded = deserialize_program(blob, art)   # seed finds the resident entry
    assert seeded is resident


# ------------------------------------------------------------- broadcast
def test_broadcast_leader_publishes_follower_never_lowers(
        trained_artifact, scoped_cache, monkeypatch):
    import repro.core.lowering as lowering_mod
    art, _, _ = trained_artifact
    box: dict = {}
    leader_prog = broadcast_program(art, leader=True,
                                    publish=lambda b: box.update(blob=b))
    assert box["blob"]

    # follower: a pristine cache AND a lowering stage that refuses to run —
    # deserialization must be the only path to a program
    follower_cache = ProgramCache()
    prev = install(follower_cache)

    def explode(a):
        raise AssertionError("follower called _lower_uncached")

    monkeypatch.setattr(lowering_mod, "_lower_uncached", explode)
    try:
        follower_prog = broadcast_program(art, leader=False,
                                          fetch=lambda: box["blob"])
    finally:
        install(prev)
    assert follower_prog.fingerprint == leader_prog.fingerprint
    assert follower_cache.stats()["programs"] == 1


def test_broadcast_follower_requires_fetch(trained_artifact):
    art, _, _ = trained_artifact
    with pytest.raises(ValueError, match="fetch"):
        broadcast_program(art, leader=False)


def test_broadcast_over_shared_file(trained_artifact, scoped_cache, tmp_path):
    art, _, _ = trained_artifact
    path = str(tmp_path / "program.envelope.json")

    # follower starts FIRST and polls; the leader publishes concurrently —
    # the file transport must hand the follower a complete envelope
    result: dict = {}

    def follower():
        fetch = file_fetcher(path, timeout_s=10.0, poll_s=0.005)
        result["prog"] = broadcast_program(art, leader=False, fetch=fetch)

    t = threading.Thread(target=follower)
    t.start()
    leader_prog = broadcast_program(art, leader=True,
                                    publish=file_publisher(path))
    t.join(timeout=30)
    assert not t.is_alive()
    assert result["prog"].fingerprint == leader_prog.fingerprint


def test_file_fetcher_times_out(tmp_path):
    fetch = file_fetcher(str(tmp_path / "never.json"), timeout_s=0.05,
                         poll_s=0.01)
    with pytest.raises(TimeoutError, match="leader"):
        fetch()


# ------------------------------------------------------ envelope edge cases
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_roundtrip_fingerprint_property(seed):
    """Property: for ANY valid fuzzed artifact, serialize -> deserialize is
    bit-identical — same program fingerprint AND byte-identical envelope."""
    art = fuzz_case(seed % 1000).artifact
    fresh = lower(art, cache=False)
    blob = serialize_program(fresh)
    rt = deserialize_program(blob, art, cache=False)
    assert rt.fingerprint == fresh.fingerprint
    assert serialize_program(rt) == blob


def test_truncated_json_rejected(trained_artifact):
    art, _, _ = trained_artifact
    blob = serialize_program(lower(art, cache=False))
    with pytest.raises(ProgramIOError, match="not valid JSON"):
        deserialize_program(blob[:10], art, cache=False)
    with pytest.raises(ProgramIOError, match="not valid JSON"):
        deserialize_program(b"", art, cache=False)


def test_unknown_envelope_version_rejected(trained_artifact):
    art, _, _ = trained_artifact
    env = json.loads(serialize_program(lower(art, cache=False)))
    env["format"] = FORMAT_VERSION + 1
    bad = json.dumps(env, sort_keys=True, separators=(",", ":")).encode()
    with pytest.raises(ProgramIOError, match="format"):
        deserialize_program(bad, art, cache=False)


def test_empty_array_manifest_rejected(trained_artifact):
    art, _, _ = trained_artifact
    env = json.loads(serialize_program(lower(art, cache=False)))
    env["arrays"] = {}
    bad = json.dumps(env, sort_keys=True, separators=(",", ":")).encode()
    with pytest.raises(ProgramIOError, match="array set"):
        deserialize_program(bad, art, cache=False)


# ---------------------------------------------------- broadcast semantics
def test_leader_publishes_exactly_once_with_concurrent_followers(
        trained_artifact, scoped_cache):
    art, _, _ = trained_artifact
    published: list = []
    ready = threading.Event()

    def publish(blob):
        published.append(blob)
        ready.set()

    def fetch():
        assert ready.wait(timeout=30), "leader never published"
        return published[0]

    results: list = []
    followers = [threading.Thread(
        target=lambda: results.append(
            broadcast_program(art, leader=False, fetch=fetch)))
        for _ in range(4)]
    for t in followers:
        t.start()
    leader_prog = broadcast_program(art, leader=True, publish=publish)
    for t in followers:
        t.join(timeout=30)
    assert not any(t.is_alive() for t in followers)
    assert len(published) == 1, "leader must publish exactly once"
    assert len(results) == 4
    assert all(p.fingerprint == leader_prog.fingerprint for p in results)


def test_prewarmed_follower_never_fetches(trained_artifact, scoped_cache):
    art, _, _ = trained_artifact
    resident = lower(art)                     # pre-warm the local cache

    def explode():
        raise AssertionError("pre-warmed follower called fetch()")

    prog = broadcast_program(art, leader=False, fetch=explode)
    assert prog is resident


def test_follower_fetch_failure_is_typed_not_a_hang(trained_artifact,
                                                    scoped_cache):
    art, _, _ = trained_artifact

    def broken():
        raise ConnectionResetError("leader went away")

    with pytest.raises(ProgramBroadcastError) as ei:
        broadcast_program(art, leader=False, fetch=broken)
    assert ei.value.role == "follower"
    assert isinstance(ei.value.cause, ConnectionResetError)
    assert "leader went away" in str(ei.value)
