"""Mamba2-780M [arXiv:2405.21060]: attention-free SSD, 48L, d1536,
d_state 128, head_dim 64 (expand 2 -> d_inner 3072, 48 SSM heads),
vocab 50280, tied embeddings. Sub-quadratic: long_500k runs with O(1)
per-token state."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, vocab=50280,
    ssm_d_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    ssm_chunk=256, tie_embeddings=True,
    subquadratic=True,
)
