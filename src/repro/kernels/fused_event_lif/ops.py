"""Jitted public wrappers for the fused event→LIF→decode megakernel.

Backend policy (this is a PERF kernel, so it differs from the validation-only
kernels): on TPU the Pallas megakernel runs natively; everywhere else the
dispatch falls through to the jnp mirror, which implements the identical
recurrence and is the fast portable path (Pallas interpret mode is for
correctness tests, not production CPU serving). ``backend=`` forces either
path explicitly — the kernel test suite pins ``backend="pallas"`` (interpret
on CPU) against the mirror.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lif_dynamics import LIFResult
from repro.kernels.common import use_interpret
from repro.kernels.fused_event_lif import ref as _ref
from repro.kernels.fused_event_lif.kernel import (
    fused_event_lif_decode_kernel,
    fused_event_lif_early_exit_kernel,
    fused_event_lif_kernel,
)


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


@functools.partial(jax.jit, static_argnames=("leak_shift", "backend"))
def fused_event_lif(ids: jnp.ndarray, count: jnp.ndarray, w: jnp.ndarray,
                    thresholds: jnp.ndarray, leak_shift: int,
                    backend: str = "auto") -> LIFResult:
    """Full-T fused pass. ids (B, T, E_max) int32, count (B, T) int32,
    w (N_in, N_pad) int8 -> LIFResult over (B, N_pad)."""
    if _resolve(backend) == "pallas":
        first, v = fused_event_lif_kernel(ids, count, w, thresholds,
                                          leak_shift,
                                          interpret=use_interpret())
    else:
        first, v = _ref.fused_event_lif_ref(ids, w, thresholds, leak_shift)
    return LIFResult(first_spike=first, v_final=v)


@functools.partial(jax.jit, static_argnames=("leak_shift", "backend"))
def fused_event_lif_early_exit(ids: jnp.ndarray, count: jnp.ndarray,
                               w: jnp.ndarray, thresholds: jnp.ndarray,
                               leak_shift: int, backend: str = "auto"
                               ) -> tuple[LIFResult, jnp.ndarray]:
    """Latency mode: stop at the first output spike. Returns
    (LIFResult, steps (B,))."""
    if _resolve(backend) == "pallas":
        first, v, steps = fused_event_lif_early_exit_kernel(
            ids, count, w, thresholds, leak_shift, interpret=use_interpret())
    else:
        first, v, steps = _ref.fused_event_lif_early_exit_ref(
            ids, w, thresholds, leak_shift)
    return LIFResult(first_spike=first, v_final=v), steps


@functools.partial(jax.jit, static_argnames=(
    "leak_shift", "n_out", "n_groups", "per_group", "fallback", "backend"))
def fused_event_lif_decode(ids: jnp.ndarray, count: jnp.ndarray,
                           w: jnp.ndarray, thresholds: jnp.ndarray,
                           leak_shift: int, *, n_out: int, n_groups: int,
                           per_group: int, fallback: str = "membrane",
                           backend: str = "auto"
                           ) -> tuple[LIFResult, jnp.ndarray]:
    """Megakernel with the grouped-TTFS comparator tree fused after the
    T-loop (single neuron block per row). Returns (LIFResult, labels (B,))."""
    T = ids.shape[1]
    if _resolve(backend) == "pallas":
        first, v, labels = fused_event_lif_decode_kernel(
            ids, count, w, thresholds, leak_shift, n_out=n_out,
            n_groups=n_groups, per_group=per_group, fallback=fallback,
            interpret=use_interpret())
    else:
        from repro.core import ttfs
        first, v = _ref.fused_event_lif_ref(ids, w, thresholds, leak_shift)
        labels = ttfs.decode_labels(
            first[..., :n_out], v[..., :n_out], n_groups=n_groups,
            per_group=per_group, sentinel=T, fallback=fallback)
    return LIFResult(first_spike=first, v_final=v), labels
