"""Elastic scaling + straggler mitigation logic."""

from hypothesis import given, settings, strategies as st

from repro.training.elastic import (StragglerMonitor, rebalance,
                                    shard_assignment)


def test_assignment_deterministic_and_total():
    hosts = [f"host{i}" for i in range(8)]
    a1 = shard_assignment(hosts, 64)
    a2 = shard_assignment(hosts, 64)
    assert a1 == a2
    assert set(a1.keys()) == set(range(64))
    assert set(a1.values()) <= set(hosts)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(1, 100))
def test_rebalance_minimal_movement(n_hosts, n_shards):
    hosts = [f"h{i}" for i in range(n_hosts)]
    a = shard_assignment(hosts, n_shards)
    dead = hosts[0]
    live = hosts[1:]
    new, moved = rebalance(a, live)
    # only the dead host's shards moved
    assert set(moved) == {s for s, h in a.items() if h == dead}
    for s in set(a) - set(moved):
        assert new[s] == a[s]
    assert all(h in live for h in new.values())


def test_rejoin_restores_original_assignment():
    """Rendezvous property: when the failed host rejoins, recomputing the
    assignment lands exactly back on the original (no thrash)."""
    hosts = [f"h{i}" for i in range(6)]
    orig = shard_assignment(hosts, 48)
    after = shard_assignment(hosts, 48)      # same membership -> identical
    assert orig == after


def test_straggler_detection_and_shares():
    mon = StragglerMonitor(window=10, threshold=1.5)
    for _ in range(10):
        for h in ("a", "b", "c"):
            mon.record(h, 1.0)
        mon.record("slow", 3.0)
    assert mon.stragglers() == ["slow"]
    shares = mon.work_shares(["a", "b", "c", "slow"])
    assert abs(sum(shares.values()) - 1.0) < 1e-9
    assert shares["slow"] < shares["a"]      # straggler gets less work


def test_no_straggler_flagged_when_uniform():
    mon = StragglerMonitor()
    for _ in range(5):
        for h in ("a", "b", "c"):
            mon.record(h, 1.0 + 0.01 * hash(h) % 3 * 0.01)
    assert mon.stragglers() == []
