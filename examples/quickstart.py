"""Quickstart — the paper's Table-2 workflow, end to end in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py

Model definition  ->  snn.SNN / snn.Sequential / snn.Linear / snn.LIF
Artifact export   ->  deploy.export (one shared deployment artifact)
Runtime invoke    ->  make_runtime(art, spec).forward(x)  (registry specs:
                      reference / accelerator-* / board — all three consume
                      the SAME artifact; the board emulator also accounts
                      PL cycles and dynamic energy, the Table-3 analogue)
"""

import numpy as np

from repro import snn, deploy
from repro.core.runtimes import make_runtime
from repro.data import mnist
from repro.training.ttfs_trainer import train_dense_proxy

# 1. data (procedural MNIST stand-in: this container is offline)
xtr, ytr = mnist.generate(8192, seed=1)
xte, yte = mnist.generate(2048, seed=2)

# 2. model definition + training (dense proxy of the grouped TTFS readout)
result = train_dense_proxy(xtr, ytr, test_images=xte, test_labels=yte,
                           epochs=2)
model = result.model          # snn.SNN(snn.Sequential(Linear(784,150), LIF))
print(f"trained: dense test accuracy {result.test_acc:.2%}")

# 3. single-artifact export: weights + thresholds + connectivity +
#    grouped TTFS decode metadata, integrity-hashed
art = deploy.export(model, "/tmp/quickstart_artifact.npz",
                    calib_images=xtr[:2048], calib_labels=ytr[:2048])
print(f"exported artifact: threshold={art['thresholds'][0]} "
      f"E_max={art.m('events', 'e_max')} "
      f"blocks={art.m('codesign', 'n_blocks')}x128 lanes")

# 4. the SAME artifact drives all three runtimes (model(x)-style forward):
#    software reference, TPU-style accelerator, and the board-runtime
#    emulator (the paper's PL datapath with cycle/energy accounting)
reference = make_runtime(art, "reference")
accelerator = make_runtime(art, "accelerator-batch")
board = make_runtime(art, "board")
out_ref = reference(xte)
out_acc = accelerator(xte)
out_board = board(xte)

acc = float(np.mean(np.asarray(out_acc.labels) == yte))
print(f"TTFS accuracy {acc:.2%}; three-way agreement on all {len(xte)} images:")
for name, out in (("accelerator", out_acc), ("board-emu", out_board)):
    agree = np.array_equal(np.asarray(out_ref.labels), np.asarray(out.labels))
    exact = np.array_equal(np.asarray(out_ref.first_spike),
                           np.asarray(out.first_spike))
    print(f"  reference<->{name:<12} labels {'MATCH' if agree else 'MISMATCH'}, "
          f"spike times {'BIT-EXACT' if exact else 'DIFFER'}")
    assert agree and exact

# 5. the board emulator's cycle/energy account (Table-3 analogue, 80 MHz PL)
print(f"board cycle/energy model: {board.last_trace.summary()}")
lat = make_runtime(art, "board", latency_mode=True)
lat(xte[:256])
print(f"  TTFS decision latency : {lat.last_trace.summary()}")
