"""The paper's own deployed workload: 784-to-150 TTFS classifier, 10 class
groups x 15 neurons, T=32, int8 weights + int32 thresholds. Not an
ArchConfig — the SNN family has its own core runtime (repro.core)."""
SNN_CONFIG = {
    "n_in": 784, "n_out": 150,
    "n_groups": 10, "per_group": 15,
    "T": 32, "leak_tau": 16.0,
    "fallback": "membrane",
}
