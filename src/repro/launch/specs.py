"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell —
weak-type-correct, shardable, zero allocation. The dry-run lowers against
these; smoke tests materialize small concrete versions of the same structure.

Frontend stubs per assignment: [audio] gets precomputed frame embeddings
(B, S, d); [vlm] gets precomputed patch embeddings (B, n_patches, d).
Whisper stream mapping (DESIGN.md §4): the seq_len of a cell applies to the
encoder frame stream; the decoder text stream is dec_max_len (448) for
train/prefill and the seq_len-long self-attention cache for decode cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES
from repro.models.config import ArchConfig
from repro.models.model import LM

I32 = jnp.int32
BF16 = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape_name: str) -> dict:
    cell = SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    if cfg.family == "audio":
        return {"tokens": sds((B, cfg.dec_max_len), I32),
                "labels": sds((B, cfg.dec_max_len), I32),
                "enc_frames": sds((B, S, cfg.d_model), BF16)}
    out = {"tokens": sds((B, S), I32), "labels": sds((B, S), I32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model), BF16)
    return out


def prefill_specs(cfg: ArchConfig, shape_name: str) -> dict:
    b = train_batch_specs(cfg, shape_name)
    b.pop("labels")
    return b


def decode_specs(cfg: ArchConfig, shape_name: str, lm: LM) -> dict:
    """Abstract (cache, tokens) for serve_step: one new token against a
    KV/SSM cache of seq_len."""
    cell = SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    enc_len = cfg.cross_len if cfg.enc_layers else None
    cache = lm.init_cache(B, S, dtype=BF16, abstract=True, enc_len=enc_len)
    return {"cache": cache, "tokens": sds((B, 1), I32)}
