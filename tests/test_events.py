"""Event packing: roundtrip, determinism, overflow policy, calibration."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import events, ttfs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.RandomState(seed % 2**32)
    B, N, T = 3, 60, 8
    times = rng.randint(0, T + 1, (B, N)).astype(np.int32)
    e_max = events.calibrate_e_max(times, T, lane=8)
    frames = events.pack_events_batched(times, T, e_max)
    assert not np.any(np.asarray(frames.overflow))
    raster = np.asarray(events.unpack_to_raster(frames, N))
    expect = np.asarray(ttfs.frames_from_times(jnp.asarray(times), T))
    assert np.array_equal(raster, expect)


def test_batched_equals_loop_packer():
    rng = np.random.RandomState(0)
    times = rng.randint(0, 9, (4, 50)).astype(np.int32)
    a = events.pack_events(times, 8, 64)
    b = events.pack_events_batched(times, 8, 64)
    # same sets of ids per (b, t) — order within a step is id-sorted in both
    for bi in range(4):
        for t in range(8):
            ia = np.sort(np.asarray(a.ids[bi, t]))
            ib = np.sort(np.asarray(b.ids[bi, t]))
            assert np.array_equal(ia, ib)
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))


def test_overflow_flagged():
    times = np.zeros((1, 40), np.int32)        # all spike at t=0
    frames = events.pack_events_batched(times, 4, 16)
    assert bool(frames.overflow[0])
    full = events.pack_events_batched(times, 4, 64)
    assert not bool(full.overflow[0])


def test_overflow_boundary_exact_fit_is_not_overflow():
    """count == e_max exactly fills the buffer — NOT an overflow; one more
    event flips the flag. Guards the off-by-one at the buffer boundary."""
    T, e_max = 4, 16
    exact = np.full((1, e_max), 0, np.int32)       # 16 events at t=0
    frames = events.pack_events_batched(exact, T, e_max)
    assert not bool(frames.overflow[0])
    assert int(frames.count[0, 0]) == e_max
    assert not np.any(np.asarray(frames.ids[0, 0]) == events.PAD)

    over = np.full((1, e_max + 1), 0, np.int32)    # 17 events at t=0
    frames = events.pack_events_batched(over, T, e_max)
    assert bool(frames.overflow[0])
    assert int(frames.count[0, 0]) == e_max        # deterministic truncation
    # the kept ids are the e_max lowest (stable (time, id) order)
    assert np.array_equal(np.asarray(frames.ids[0, 0]), np.arange(e_max))


def test_overflow_boundary_loop_packer_matches():
    """The reference loop packer applies the same boundary rule."""
    T, e_max = 3, 8
    times = np.zeros((2, e_max + 1), np.int32)
    times[0, -1] = T                               # row 0: exactly e_max at t=0
    a = events.pack_events(times, T, e_max)
    b = events.pack_events_batched(times, T, e_max)
    assert np.array_equal(np.asarray(a.overflow), np.asarray(b.overflow))
    assert np.array_equal(np.asarray(a.overflow), [False, True])
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))


def test_calibrate_e_max_exact_lane_boundary_rounding():
    """A peak exactly on a lane multiple must NOT round up a whole extra
    lane; one past it must."""
    lane = 8
    times = np.zeros((1, lane), np.int32)          # peak == lane exactly
    assert events.calibrate_e_max(times, T=2, lane=lane) == lane
    times = np.zeros((1, lane + 1), np.int32)      # peak == lane + 1
    assert events.calibrate_e_max(times, T=2, lane=lane) == 2 * lane
    # headroom scaling rounds up through the boundary too
    times = np.zeros((1, lane), np.int32)
    assert events.calibrate_e_max(times, T=2, lane=lane,
                                  headroom=1.25) == 2 * lane


def test_calibrate_e_max_lane_aligned():
    rng = np.random.RandomState(1)
    times = rng.randint(0, 17, (16, 784)).astype(np.int32)
    e = events.calibrate_e_max(times, 16, lane=128)
    assert e % 128 == 0
    peak = max(int((times == t).sum(1).max()) for t in range(16))
    assert e >= peak


# ----------------------------------------- packer equivalence, adversarial
def _assert_packers_identical(times: np.ndarray, T: int, e_max: int) -> None:
    """ids, count AND overflow must match elementwise — not just as sets:
    the serving tier relies on deterministic (time, id)-ordered packing."""
    a = events.pack_events(times, T, e_max)
    b = events.pack_events_batched(times, T, e_max)
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert np.array_equal(np.asarray(a.count), np.asarray(b.count))
    assert np.array_equal(np.asarray(a.overflow), np.asarray(b.overflow))


def test_packers_identical_all_spikes_one_timestep():
    """Every input lands in a single step — first, last, and an interior
    one — at 3x the buffer depth, so truncation order matters."""
    T, e_max, N = 6, 16, 48
    for t in (0, T // 2, T - 1):
        times = np.full((3, N), t, np.int32)
        _assert_packers_identical(times, T, e_max)


def test_packers_identical_exact_emax_boundary():
    """Rows straddling the buffer boundary: e_max-1, e_max, and e_max+1
    events in one step (only the last may overflow)."""
    T, e_max = 4, 8
    for n_ev in (e_max - 1, e_max, e_max + 1):
        times = np.full((1, e_max + 4), T, np.int32)   # never-spike filler
        times[0, :n_ev] = 1
        _assert_packers_identical(times, T, e_max)


def test_packers_identical_all_never_spike_rows():
    """Rows of pure sentinel (time == T) mixed with live rows: no events,
    no counts, no overflow — and no contamination of neighbours."""
    T, e_max = 5, 8
    times = np.full((4, 20), T, np.int32)
    times[2, :5] = np.arange(5) % T                    # one live row
    _assert_packers_identical(times, T, e_max)
    frames = events.pack_events_batched(times, T, e_max)
    assert int(np.asarray(frames.count)[0].sum()) == 0
    assert np.all(np.asarray(frames.ids)[0] == events.PAD)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_packers_identical_tie_heavy_property(seed):
    """Property sweep biased toward ties: times drawn from a tiny palette
    {0, 1, T-1, T} so nearly every event collides with many others, with a
    deliberately small e_max so overflow is common."""
    rng = np.random.RandomState(seed % 2**32)
    B, N, T, e_max = 3, 40, 7, 8
    palette = np.array([0, 1, T - 1, T], np.int32)
    times = palette[rng.randint(0, len(palette), (B, N))]
    # sprinkle a few uniform times so steps besides the palette are hit too
    mask = rng.rand(B, N) < 0.2
    times = np.where(mask, rng.randint(0, T + 1, (B, N)), times)
    times = times.astype(np.int32)
    _assert_packers_identical(times, T, e_max)
