"""The one lowering stage: ``Artifact → LoweredProgram``.

The paper's single-artifact contract says ONE exported object carries
weights, thresholds, connectivity and grouped TTFS decode metadata unchanged
from software definition to board execution. This module is where that
contract becomes code: ``lower(artifact)`` validates and coerces the meta
ONCE into a frozen, fingerprinted ``LoweredProgram``, and every runtime
family (reference, accelerator batch/event, board-py, board-batched, the
serving scheduler's host packer, the fault detectors) consumes the program
instead of re-reading ``artifact.m(...)`` at seven-plus sites.

Two cache tiers hang off the lowering stage, both process-wide and keyed by
content, never by object identity:

  * program cache — ``artifact.fingerprint() → LoweredProgram``. The
    fingerprint is recomputed from the actual array bytes + volatile-stripped
    meta, so a fault-pass clone (different bytes) can never alias the
    pristine program.
  * bundle cache — ``(family, program fingerprint, mode/kernel/latency/cost)
    → jitted-callable bundle``. jax caches compiled executables on the
    FUNCTION OBJECT, so sharing the bundle across runtime instances (e.g.
    every serving lane, including watchdog-spawned replacements) means one
    compile per distinct config per process instead of one per lane.

Static fault plans are a lowering pass: ``lower_with_faults`` corrupts an
in-memory CLONE of the artifact (pristine artifact untouched — it backs the
scrub/reload recovery path) and lowers the clone; dynamic plans stay a
board-py runtime concern and never enter this module.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.core.artifact import Artifact
from repro.core.hw import PYNQ_COST, BoardCostModel
from repro.core.types import DecodePlan, EncodePlan


class LoweringError(ValueError):
    """The artifact's metadata or arrays do not lower to a valid program."""


_MISSING = object()


def _meta(art: Artifact, path: tuple[str, ...], kind: str):
    """One coercion point for every execution parameter the runtimes used to
    read ad hoc: missing paths and junk values fail HERE, at lowering time,
    with the offending meta path named — not deep inside a jitted forward."""
    val = art.m(*path, default=_MISSING)
    if val is _MISSING:
        raise LoweringError(f"artifact meta missing {'.'.join(path)!r}")
    if kind == "int":
        if isinstance(val, bool):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to int")
        if isinstance(val, (int, np.integer)):
            return int(val)
        if isinstance(val, (float, np.floating)):
            if float(val).is_integer():
                return int(val)
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to int")
        if isinstance(val, str):
            try:
                return int(val, 10)
            except ValueError:
                raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                    f"does not lower to int") from None
        raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                            f"does not lower to int")
    if kind == "float":
        if isinstance(val, bool):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to float")
        try:
            out = float(val)
        except (TypeError, ValueError):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to float") from None
        if not np.isfinite(out):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"is not finite")
        return out
    if kind == "str":
        if not isinstance(val, str):
            raise LoweringError(f"meta {'.'.join(path)!r}={val!r} "
                                f"does not lower to str")
        return val
    raise AssertionError(kind)


@dataclasses.dataclass(frozen=True, eq=False)
class LoweredProgram:
    """Frozen execution view of one deployment artifact.

    Everything a runtime needs to execute — typed scalars, device-ready
    arrays, the encode/decode plans, the cost-model binding — validated and
    coerced once. ``artifact`` is the back-reference the integrity detectors
    re-hash; runtimes keep ``self.art = program.artifact`` for exactly that.
    """

    fingerprint: str          # program identity (derives from the artifact's)
    artifact: Artifact        # back-ref for integrity re-hashing / export
    # ---- typed scalars ----
    T: int
    x_min: float
    e_max: int
    leak_shift: int
    n_in: int
    n_out: int
    n_groups: int
    per_group: int
    fallback: str
    scale: float              # quantization scale (dense int8 baseline)
    n_pad: int                # padded output width (lane-aligned)
    lane: int                 # blocked-layout lane width from the planner
    # ---- device-ready arrays ----
    w_float: jnp.ndarray      # (N_in, N_out) fp32
    w_int8: jnp.ndarray       # (N_in, N_out) int8
    thresholds: jnp.ndarray   # (N_out,) int32
    w_padded: jnp.ndarray     # (N_in, N_pad) int8 — blocked layout
    thr_padded: jnp.ndarray   # (N_pad,) int32
    # ---- stage plans + cost binding ----
    encode: EncodePlan
    decode: DecodePlan
    cost: BoardCostModel

    def host_arrays(self) -> dict[str, np.ndarray]:
        """The artifact's raw numpy arrays (host side, never device)."""
        return self.artifact.arrays


def _program_fingerprint(art_fp: str, scalars: dict[str, Any]) -> str:
    h = hashlib.sha256()
    h.update(art_fp.encode())
    h.update(json.dumps(scalars, sort_keys=True).encode())
    return h.hexdigest()


REQUIRED_ARRAYS = ("w_float", "w_int8", "thresholds", "w_padded",
                   "thr_padded")


def _lower_uncached(art: Artifact) -> LoweredProgram:
    missing = [n for n in REQUIRED_ARRAYS if n not in art.arrays]
    if missing:
        raise LoweringError(f"artifact is missing arrays {missing}")
    T = _meta(art, ("encode", "T"), "int")
    if T <= 0:
        raise LoweringError(f"encode.T={T} must be positive")
    x_min = _meta(art, ("encode", "x_min"), "float")
    e_max = _meta(art, ("events", "e_max"), "int")
    leak_shift = _meta(art, ("lif", "leak_shift"), "int")
    n_in = _meta(art, ("model", "n_in"), "int")
    n_out = _meta(art, ("model", "n_out"), "int")
    n_groups = _meta(art, ("readout", "n_groups"), "int")
    per_group = _meta(art, ("readout", "per_group"), "int")
    fallback = _meta(art, ("readout", "fallback"), "str")
    scale = _meta(art, ("quant", "scale"), "float")
    lane = _meta(art, ("codesign", "lane"), "int")
    if fallback not in ("membrane", "zero"):
        raise LoweringError(f"readout.fallback={fallback!r} is not a known "
                            f"no-spike policy ('membrane' | 'zero')")
    if n_groups * per_group != n_out:
        raise LoweringError(
            f"readout geometry n_groups*per_group = {n_groups}*{per_group} "
            f"!= model.n_out = {n_out}")
    n_pad = int(art["thr_padded"].shape[0])
    if art["w_padded"].shape != (n_in, n_pad):
        raise LoweringError(
            f"w_padded shape {art['w_padded'].shape} != "
            f"(n_in={n_in}, n_pad={n_pad})")
    if art["w_int8"].shape != (n_in, n_out):
        raise LoweringError(
            f"w_int8 shape {art['w_int8'].shape} != "
            f"(n_in={n_in}, n_out={n_out})")
    if n_pad < n_out:
        raise LoweringError(f"padded width {n_pad} < n_out {n_out}")
    scalars = {"T": T, "x_min": x_min, "e_max": e_max,
               "leak_shift": leak_shift, "n_in": n_in, "n_out": n_out,
               "n_groups": n_groups, "per_group": per_group,
               "fallback": fallback, "scale": scale, "n_pad": n_pad,
               "lane": lane}
    return LoweredProgram(
        fingerprint=_program_fingerprint(art.fingerprint(), scalars),
        artifact=art,
        T=T, x_min=x_min, e_max=e_max, leak_shift=leak_shift,
        n_in=n_in, n_out=n_out, n_groups=n_groups, per_group=per_group,
        fallback=fallback, scale=scale, n_pad=n_pad, lane=lane,
        w_float=jnp.asarray(art["w_float"]),
        w_int8=jnp.asarray(art["w_int8"]),
        thresholds=jnp.asarray(art["thresholds"]),
        w_padded=jnp.asarray(art["w_padded"]),
        thr_padded=jnp.asarray(art["thr_padded"]),
        encode=EncodePlan(T=T, x_min=x_min, e_max=e_max, n_in=n_in),
        decode=DecodePlan(n_groups=n_groups, per_group=per_group,
                          sentinel=T, fallback=fallback),
        cost=PYNQ_COST)


class ProgramCache:
    """Process-wide content-addressed caches for lowered programs and their
    compiled-callable bundles. Keys are content fingerprints plus the exact
    runtime config, never python object identity — a corrupted clone or a
    re-exported artifact gets its own entry, a watchdog-spawned replacement
    lane over the same artifact gets a hit."""

    def __init__(self):
        self._lock = threading.Lock()
        self._programs: dict[str, LoweredProgram] = {}
        self._bundles: dict[tuple, Any] = {}
        self.program_hits = 0
        self.program_misses = 0
        self.bundle_hits = 0
        self.bundle_misses = 0

    def program(self, art: Artifact) -> tuple[LoweredProgram, bool]:
        key = art.fingerprint()
        with self._lock:
            prog = self._programs.get(key)
            if prog is not None:
                self.program_hits += 1
                return prog, True
        prog = _lower_uncached(art)
        with self._lock:
            # first lowering wins (two racing lowers of the same artifact
            # produce equal programs anyway — determinism is the oracle)
            cached = self._programs.setdefault(key, prog)
            self.program_misses += 1
        return cached, False

    def bundle(self, key: tuple, build: Callable[[], Any]) -> tuple[Any, bool]:
        with self._lock:
            if key in self._bundles:
                self.bundle_hits += 1
                return self._bundles[key], True
        built = build()
        with self._lock:
            cached = self._bundles.setdefault(key, built)
            self.bundle_misses += 1
        return cached, False

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self._bundles.clear()
            self.program_hits = self.program_misses = 0
            self.bundle_hits = self.bundle_misses = 0

    def stats(self) -> dict:
        with self._lock:
            return {"programs": len(self._programs),
                    "bundles": len(self._bundles),
                    "program_hits": self.program_hits,
                    "program_misses": self.program_misses,
                    "bundle_hits": self.bundle_hits,
                    "bundle_misses": self.bundle_misses}


#: the process-wide cache every ``make_runtime`` / serving lane shares
PROGRAM_CACHE = ProgramCache()


def lower(artifact: Artifact | LoweredProgram, *,
          cache: bool = True) -> LoweredProgram:
    """Lower an artifact to its frozen execution program.

    Idempotent: passing an already-lowered program returns it unchanged.
    ``cache=False`` forces a fresh lowering (the determinism oracle compares
    two independent lowers; export-time validation avoids caching a program
    whose artifact ``save()`` is about to re-stamp)."""
    if isinstance(artifact, LoweredProgram):
        return artifact
    if not isinstance(artifact, Artifact):
        raise TypeError(f"cannot lower {type(artifact).__name__} "
                        f"(expected Artifact or LoweredProgram)")
    if cache:
        prog, _ = PROGRAM_CACHE.program(artifact)
        return prog
    return _lower_uncached(artifact)


def lower_with_faults(artifact: Artifact | LoweredProgram,
                      plan) -> LoweredProgram:
    """The static-fault lowering pass: corrupt an in-memory CLONE of the
    artifact per the plan's seeded SEU fields, then lower the clone. The
    pristine artifact (and its cached program) are untouched; the corrupted
    program gets its own content fingerprint, so cache entries never alias."""
    from repro.faults.models import corrupt_artifact
    art = artifact.artifact if isinstance(artifact, LoweredProgram) \
        else artifact
    return lower(corrupt_artifact(art, plan))
