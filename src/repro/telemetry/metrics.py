"""Metric registry — counters, gauges, fixed-bucket histograms, typed events.

This absorbs the ad-hoc ``stats()`` dicts that used to be scattered across
the serving scheduler (latency percentiles, queue depth), the board runtime
(cycle/energy accounts), and the resilience tier (detector/recovery ledger):
one registry per owner, every mutation under one internal lock, so a
``snapshot()`` is **consistent** — totals read together were true together,
and successive snapshots are monotone for counters (no torn reads while
worker lanes keep mutating).

  * ``Counter`` — monotone int/float accumulator (``inc``);
  * ``Gauge``   — last-write scalar, plus ``set_max`` for peak tracking;
  * ``Histogram`` — FIXED bucket boundaries (chosen at registration, never
    adapted — cross-run comparability is the point) plus a bounded exact-
    value window so the legacy exact percentiles (p50/p95/p99) survive;
  * typed events — lane state-machine transitions, detector firings and
    circuit-breaker trips become ``Event`` records with structured fields,
    not loose dict keys; a bounded ring keeps the most recent ones.

``export.prometheus_text`` renders a registry in Prometheus exposition
format; ``snapshot()`` is the scheduler-facing consistent read.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from collections import deque

#: default request-latency boundaries (us) — fixed across runs and PRs so
#: histograms stay comparable; the +inf bucket is implicit
LATENCY_BUCKETS_US = (50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0,
                      10000.0, 25000.0, 50000.0, 100000.0, 250000.0,
                      500000.0, 1000000.0)
#: recovery-latency boundaries (ms)
RECOVERY_BUCKETS_MS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                       1000.0, 2500.0)
#: queue-depth / batch-fill boundaries (requests)
DEPTH_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
                 1024.0)


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0


class Histogram:
    """Fixed-boundary histogram + bounded exact window for percentiles."""

    __slots__ = ("name", "buckets", "counts", "sum", "count", "window")

    def __init__(self, name: str, buckets: tuple,
                 window: int = 65536):
        if tuple(buckets) != tuple(sorted(buckets)):
            raise ValueError(f"histogram {name!r}: bucket boundaries must be "
                             f"sorted, got {buckets}")
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +1 = the +inf bucket
        self.sum = 0.0
        self.count = 0
        self.window: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.sum += v
        self.count += 1
        self.window.append(v)

    def percentile(self, q: float) -> float:
        """Exact percentile over the bounded window (the legacy p50/p95/p99
        semantics); falls back to 0.0 when empty."""
        if not self.window:
            return 0.0
        vals = sorted(self.window)
        if len(vals) == 1:
            return vals[0]
        # linear interpolation, matching numpy.percentile's default
        pos = (len(vals) - 1) * (q / 100.0)
        lo = math.floor(pos)
        hi = math.ceil(pos)
        if lo == hi:
            return vals[lo]
        return vals[lo] + (vals[hi] - vals[lo]) * (pos - lo)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


@dataclasses.dataclass(frozen=True)
class Event:
    """One typed occurrence (lane transition, detector firing, breaker trip).
    ``seq`` is the registry-global order; ``fields`` is structured data."""

    seq: int
    name: str
    fields: dict


class MetricsRegistry:
    """Get-or-create registry; every mutation and every read shares one
    lock, so snapshots are consistent and counter totals are monotone
    across successive reads even under concurrent writers."""

    EVENT_WINDOW = 8192

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}
        self.events: deque[Event] = deque(maxlen=self.EVENT_WINDOW)
        self._event_seq = 0
        self._events_dropped = 0

    # ------------------------------------------------------------- creation
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, buckets: tuple = LATENCY_BUCKETS_US,
                  window: int = 65536) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(name, buckets, window)
            elif tuple(h.buckets) != tuple(float(b) for b in buckets):
                raise ValueError(f"histogram {name!r} already registered "
                                 f"with boundaries {h.buckets}")
            return h

    # ------------------------------------------------------------- mutation
    def inc(self, name: str, n: float = 1) -> None:
        c = self.counter(name)
        with self._lock:
            c.value += n

    def set_gauge(self, name: str, v: float) -> None:
        g = self.gauge(name)
        with self._lock:
            g.value = v

    def set_max(self, name: str, v: float) -> None:
        g = self.gauge(name)
        with self._lock:
            if v > g.value:
                g.value = v

    def observe(self, name: str, v: float,
                buckets: tuple = LATENCY_BUCKETS_US) -> None:
        h = self.histogram(name, buckets)
        with self._lock:
            h.observe(v)

    def event(self, name: str, **fields) -> Event:
        """Record a typed event and bump its ``events_<name>`` counter —
        the counter survives the bounded ring, so totals stay exact."""
        c = self.counter(f"events_{name}")
        with self._lock:
            if len(self.events) == self.events.maxlen:
                self._events_dropped += 1
            ev = Event(self._event_seq, name, fields)
            self._event_seq += 1
            self.events.append(ev)
            c.value += 1
            return ev

    # ---------------------------------------------------------------- reads
    def get(self, name: str, default: float = 0):
        with self._lock:
            c = self._counters.get(name)
            if c is not None:
                return c.value
            g = self._gauges.get(name)
            if g is not None:
                return g.value
            return default

    def events_for(self, name: str) -> list[Event]:
        with self._lock:
            return [e for e in self.events if e.name == name]

    def snapshot(self) -> dict:
        """One consistent read of everything: counters, gauges, histogram
        summaries (count/sum/mean/p50/p95/p99), event totals. All values
        were true at the same instant — the torn-read fix for ``stats()``."""
        with self._lock:
            snap: dict = {}
            for name, c in self._counters.items():
                snap[name] = c.value
            for name, g in self._gauges.items():
                snap[name] = g.value
            for name, h in self._hists.items():
                snap[f"{name}_count"] = h.count
                snap[f"{name}_sum"] = h.sum
                snap[f"{name}_mean"] = h.mean()
                snap[f"{name}_p50"] = h.percentile(50)
                snap[f"{name}_p95"] = h.percentile(95)
                snap[f"{name}_p99"] = h.percentile(99)
            snap["events_total"] = self._event_seq
            snap["events_dropped"] = self._events_dropped
            return snap

    # the exporter needs typed access (not the flattened snapshot)
    def collect(self) -> tuple[list[Counter], list[Gauge], list[Histogram]]:
        with self._lock:
            return (list(self._counters.values()),
                    list(self._gauges.values()),
                    list(self._hists.values()))

    def reset(self) -> None:
        """Zero everything in place (post-warmup semantics). Registered
        metric OBJECTS survive — holders of a Counter/Histogram reference
        keep a live handle, only the accumulated values are cleared."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0
            for g in self._gauges.values():
                g.value = 0.0
            for h in self._hists.values():
                h.counts = [0] * (len(h.buckets) + 1)
                h.sum = 0.0
                h.count = 0
                h.window.clear()
            self.events.clear()
            self._event_seq = 0
            self._events_dropped = 0
