"""Gradient compression: symmetric int8 quantization with error feedback.

At 1000+-node scale the gradient all-reduce dominates step time for small
models / large data-parallel axes; int8 compression cuts the wire bytes 4x
(vs f32) while error feedback (Karimireddy et al. 2019) keeps the *sum* of
transmitted updates unbiased — the quantization residual is carried into the
next step locally, so convergence is preserved (tested on a real training
run in tests/training/test_compress.py).

Functional API mirrors how it slots into train_step: the residual pytree
lives next to the optimizer state.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: Any       # int8 pytree
    scale: Any   # f32 per-tensor scales


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residual) -> tuple[Compressed, Any]:
    """(grads + residual) -> int8; new residual = input - dequantized."""
    def per(g, r):
        x = g.astype(jnp.float32) + r
        amax = jnp.max(jnp.abs(x))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_r = x - q.astype(jnp.float32) * scale
        return q, scale, new_r

    out = jax.tree.map(per, grads, residual)
    def istup(x):
        return isinstance(x, tuple)
    q = jax.tree.map(lambda o: o[0], out, is_leaf=istup)
    s = jax.tree.map(lambda o: o[1], out, is_leaf=istup)
    new_r = jax.tree.map(lambda o: o[2], out, is_leaf=istup)
    return Compressed(q, s), new_r


def decompress(c: Compressed) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale)


def wire_bytes(c: Compressed) -> int:
    """Bytes that would cross the network (int8 payload + scales)."""
    qb = sum(x.size for x in jax.tree.leaves(c.q))
    sb = 4 * len(jax.tree.leaves(c.scale))
    return qb + sb
