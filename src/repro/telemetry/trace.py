"""Deterministic structured tracing — the span side of the telemetry tier.

A span records one step of a request's life (``submit -> admission ->
batch-form -> lane -> runtime -> kernel -> decode -> complete``) with

  * an explicit **scope tag** on every span — ``"accel"`` (device/datapath
    work only: the paper's accelerator-scope) or ``"system"`` (everything a
    request actually pays: queueing, encode, packing, dispatch, readback) —
    the §2.3 measurement discipline made structural, so accelerator-only and
    system-level numbers can never be conflated inside one trace;
  * **logical clocks** in ``attrs`` — tick / event / cycle counts taken from
    the board cost model (deterministic, seed-reproducible integers), the
    currency every cross-run comparison uses;
  * **wall clocks** in dedicated fields (``wall_ns_start`` / ``wall_ns_end``)
    and host-only context in ``meta`` (lane id, thread, runtime impl) —
    excluded from the canonical form, so two runs of the same seed produce
    **bit-identical canonical span trees** even though wall time and thread
    placement differ.

Span ids are sequential *per trace* (a trace is one request, one batch, or
one standalone forward), and parent/child causality is explicit — the tree
for a given trace is deterministic as long as the traced work is, regardless
of how traces from different threads interleave in the global buffer.

The module-level recorder is a shared no-op by default: an un-instrumented
process pays one attribute load and one method call per site, with **zero
per-event allocation** (``span()`` returns the same singleton context
manager every time; ``emit()``/``begin()`` return ``None``). Install a
``Tracer`` to start recording:

    from repro.telemetry import trace
    t = trace.Tracer()
    prev = trace.install(t)
    try:
        ...  # anything instrumented records into t
    finally:
        trace.install(prev)

Hot paths that must build attr dicts should guard on ``trace.enabled()``.
"""

from __future__ import annotations

import hashlib
import itertools
import json
import threading
import time

#: the only legal scope tags — every span carries exactly one (the paper's
#: accelerator-only vs system-level measurement split)
SCOPES = ("accel", "system")


class Span:
    """One recorded step. ``attrs`` holds deterministic logical-clock data
    (ints/floats/strs from seeded computation); ``meta`` and the wall fields
    hold host-nondeterministic context and are excluded from ``canonical``."""

    __slots__ = ("trace", "sid", "parent", "name", "scope", "attrs", "meta",
                 "wall_ns_start", "wall_ns_end")

    def __init__(self, trace: str, sid: int, parent: int | None, name: str,
                 scope: str, attrs: dict | None, meta: dict | None,
                 wall_ns_start: int):
        self.trace = trace
        self.sid = sid
        self.parent = parent
        self.name = name
        self.scope = scope
        self.attrs = attrs if attrs is not None else {}
        self.meta = meta if meta is not None else {}
        self.wall_ns_start = wall_ns_start
        self.wall_ns_end = wall_ns_start

    @property
    def wall_us(self) -> float:
        return (self.wall_ns_end - self.wall_ns_start) / 1e3

    def canonical(self) -> dict:
        """The deterministic projection: everything except wall clocks and
        ``meta``. Two seeded runs must agree on this bit for bit."""
        return {"trace": self.trace, "sid": self.sid, "parent": self.parent,
                "name": self.name, "scope": self.scope, "attrs": self.attrs}

    def full(self) -> dict:
        """The export form: canonical + wall clocks + host meta."""
        d = self.canonical()
        d["wall_ns_start"] = self.wall_ns_start
        d["wall_ns_end"] = self.wall_ns_end
        d["meta"] = self.meta
        return d


class _SpanCtx:
    """Context manager wrapping begin/end with thread-local nesting."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span | None):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span | None:
        if self._span is not None:
            self._tracer._push(self._span)
        return self._span

    def __exit__(self, *exc) -> bool:
        if self._span is not None:
            self._tracer._pop(self._span)
            self._span.wall_ns_end = time.perf_counter_ns()
        return False


class _NullSpanCtx:
    """The disabled-path singleton: no allocation, no state, no effect."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_CTX = _NullSpanCtx()


class NullRecorder:
    """Module default: every API is a no-op returning shared singletons."""

    enabled = False

    def span(self, name, scope, trace=None, parent=None, attrs=None,
             meta=None) -> _NullSpanCtx:
        return _NULL_CTX

    def begin(self, name, scope, trace=None, parent=None, attrs=None,
              meta=None) -> None:
        return None

    def end(self, span, attrs=None) -> None:
        return None

    def emit(self, name, scope, trace=None, parent=None, attrs=None,
             meta=None) -> None:
        return None


class Tracer:
    """A recording span buffer, bounded at ``max_spans`` (drops past the
    bound are counted in ``dropped``, never raised on the hot path)."""

    enabled = True

    def __init__(self, max_spans: int = 1 << 18):
        self.max_spans = int(max_spans)
        self.spans: list[Span] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._auto = itertools.count()        # standalone-trace id counter
        self._sids: dict[str, itertools.count] = {}
        self._tls = threading.local()

    # ------------------------------------------------------------ internals
    def _stack(self) -> list[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()

    def current(self) -> Span | None:
        """The innermost context-managed span on this thread, if any."""
        st = self._stack()
        return st[-1] if st else None

    def _record(self, name: str, scope: str, trace: str | None,
                parent: int | None, attrs: dict | None,
                meta: dict | None) -> Span | None:
        if scope not in SCOPES:
            raise ValueError(f"span scope must be one of {SCOPES}, got "
                             f"{scope!r} (every span carries an explicit "
                             "accel|system tag)")
        cur = self.current()
        if trace is None:
            if cur is not None:
                trace = cur.trace
                if parent is None:
                    parent = cur.sid
            else:
                with self._lock:
                    trace = f"t{next(self._auto)}"
        elif parent is None and cur is not None and cur.trace == trace:
            parent = cur.sid
        now = time.perf_counter_ns()
        with self._lock:
            if len(self.spans) >= self.max_spans:
                self.dropped += 1
                return None
            sid = next(self._sids.setdefault(trace, itertools.count()))
            span = Span(trace, sid, parent, name, scope, attrs, meta, now)
            self.spans.append(span)
        return span

    # ------------------------------------------------------------------ API
    def span(self, name: str, scope: str, trace: str | None = None,
             parent: int | None = None, attrs: dict | None = None,
             meta: dict | None = None) -> _SpanCtx:
        """Context-managed span: nests via a thread-local stack, so spans
        opened inside it (same thread) become its children automatically."""
        return _SpanCtx(self, self._record(name, scope, trace, parent,
                                           attrs, meta))

    def begin(self, name: str, scope: str, trace: str | None = None,
              parent: int | None = None, attrs: dict | None = None,
              meta: dict | None = None) -> Span | None:
        """Open a span WITHOUT touching the nesting stack — for spans that
        end on a different thread (e.g. a request span opened at submit and
        closed at completion). Close with ``end()``."""
        return self._record(name, scope, trace, parent, attrs, meta)

    def end(self, span: Span | None, attrs: dict | None = None) -> None:
        if span is None:
            return
        if attrs:
            span.attrs.update(attrs)
        span.wall_ns_end = time.perf_counter_ns()

    def emit(self, name: str, scope: str, trace: str | None = None,
             parent: int | None = None, attrs: dict | None = None,
             meta: dict | None = None) -> Span | None:
        """Record an already-finished (zero-wall-duration) span — used to
        project measured per-image accounts into the tree after the fact."""
        return self._record(name, scope, trace, parent, attrs, meta)

    # ------------------------------------------------------------- analysis
    def sorted_spans(self) -> list[Span]:
        with self._lock:
            return sorted(self.spans, key=lambda s: (s.trace, s.sid))

    def traces(self) -> dict[str, list[Span]]:
        out: dict[str, list[Span]] = {}
        for s in self.sorted_spans():
            out.setdefault(s.trace, []).append(s)
        return out

    def canonical(self, trace: str | None = None) -> list[dict]:
        """Deterministic form, sorted by (trace, sid) — the thing two seeded
        runs must agree on bit for bit (wall clocks and meta excluded)."""
        return [s.canonical() for s in self.sorted_spans()
                if trace is None or s.trace == trace]

    def fingerprint(self, trace: str | None = None) -> str:
        """SHA-256 over the canonical JSON — the repeatability check."""
        blob = json.dumps(self.canonical(trace), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def roots(self, name: str) -> list[Span]:
        """All parentless spans with the given name (one per forward/batch)."""
        return [s for s in self.sorted_spans()
                if s.parent is None and s.name == name]

    def children(self, span: Span) -> list[Span]:
        return [s for s in self.sorted_spans()
                if s.trace == span.trace and s.parent == span.sid]

    def find(self, name: str, trace: str | None = None) -> list[Span]:
        return [s for s in self.sorted_spans() if s.name == name
                and (trace is None or s.trace == trace)]


# ---------------------------------------------------------- module recorder
_NULL = NullRecorder()
_recorder: NullRecorder | Tracer = _NULL


def get() -> NullRecorder | Tracer:
    return _recorder


def enabled() -> bool:
    return _recorder.enabled


def install(tracer: Tracer | NullRecorder | None):
    """Swap the module-level recorder; returns the previous one so callers
    can restore it (``install(None)`` restores the shared no-op)."""
    global _recorder
    prev = _recorder
    _recorder = tracer if tracer is not None else _NULL
    return prev


def span(name: str, scope: str, **kw):
    return _recorder.span(name, scope, **kw)


def begin(name: str, scope: str, **kw):
    return _recorder.begin(name, scope, **kw)


def end(span_obj, attrs: dict | None = None) -> None:
    _recorder.end(span_obj, attrs)


def emit(name: str, scope: str, **kw):
    return _recorder.emit(name, scope, **kw)
