"""Full-test-set agreement harness — the paper's headline validation.

The paper's strongest claim is not the 87.40% accuracy; it is that all
10,000 board predictions match the software reference, across 5 repeated
runs (50,000 image-run pairs, 0 mismatches). This module reproduces that
protocol as a THREE-WAY harness: software reference, accelerator runtime(s),
and the board-runtime emulator all consume the same artifact; every non-
reference runtime's decoded labels AND first-spike times are compared
elementwise against the reference, and mismatch counts reported. Runtimes
are named by registry spec (``core.runtimes``), so adding one is a string.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.accelerator import SNNAccelerator
from repro.core.artifact import Artifact
from repro.core.reference import SNNReference
from repro.core.runtimes import make_runtime


@dataclasses.dataclass
class AgreementReport:
    n_images: int
    runtimes: list[str]
    label_mismatches: dict[str, int]        # vs reference
    spike_time_mismatches: dict[str, int]   # vs reference
    accuracy: dict[str, float]
    exact_match: bool
    wall_s: float

    def summary(self) -> str:
        lines = [f"agreement over {self.n_images} images:"]
        for r in self.runtimes:
            if r == "reference":
                lines.append(f"  reference            acc={self.accuracy[r]:.4%}")
            else:
                lines.append(
                    f"  {r:<20} acc={self.accuracy[r]:.4%} "
                    f"label_mismatch={self.label_mismatches[r]} "
                    f"spike_time_mismatch={self.spike_time_mismatches[r]}")
        lines.append(f"  EXACT MATCH: {self.exact_match}  ({self.wall_s:.1f}s)")
        return "\n".join(lines)


def _run_chunked(fn: Callable, images: np.ndarray, chunk: int):
    outs = [fn(images[i:i + chunk]) for i in range(0, len(images), chunk)]
    labels = np.concatenate([np.asarray(o.labels) for o in outs])
    first = np.concatenate([np.asarray(o.first_spike) for o in outs])
    return labels, first


def full_agreement(artifact: Artifact, images: np.ndarray, labels: np.ndarray,
                   runtimes=("accelerator-batch", "accelerator-event",
                             "board"),
                   kernel: str = "jnp", chunk: int = 1024) -> AgreementReport:
    t0 = time.perf_counter()
    ref = SNNReference(artifact)
    ref_labels, ref_first = _run_chunked(ref.forward, images, chunk)
    acc = {"reference": float(np.mean(ref_labels == labels))}
    lmm, smm = {}, {}
    for rt in runtimes:
        runner = make_runtime(artifact, rt, kernel=kernel)
        a_labels, a_first = _run_chunked(runner.forward, images, chunk)
        lmm[rt] = int(np.sum(a_labels != ref_labels))
        smm[rt] = int(np.sum(np.any(a_first != ref_first, axis=-1)))
        acc[rt] = float(np.mean(a_labels == labels))
    exact = all(v == 0 for v in lmm.values()) and all(v == 0 for v in smm.values())
    return AgreementReport(
        n_images=len(images), runtimes=["reference", *runtimes],
        label_mismatches=lmm, spike_time_mismatches=smm, accuracy=acc,
        exact_match=exact, wall_s=time.perf_counter() - t0)


def repeatability(artifact: Artifact, images: np.ndarray, labels: np.ndarray,
                  runs: int = 5, chunk: int = 1024) -> dict:
    """Paper §3.3: five repeated runs, 0/50,000 mismatches, stable accuracy.
    Determinism here is a *property* (same artifact, same integer ops), and
    this harness provides the evidence in the paper's own protocol."""
    base = None
    accs = []
    mismatch_pairs = 0
    for r in range(runs):
        accel = SNNAccelerator(artifact, mode="batch")
        a_labels, a_first = _run_chunked(accel.forward, images, chunk)
        accs.append(float(np.mean(a_labels == labels)))
        if base is None:
            base = (a_labels, a_first)
        else:
            mismatch_pairs += int(np.sum(a_labels != base[0]))
    return {"runs": runs, "image_run_pairs": runs * len(images),
            "mismatches": mismatch_pairs, "accuracy_per_run": accs,
            "accuracy_stable": len(set(np.round(accs, 6))) == 1}
