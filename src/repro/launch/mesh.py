"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees its 512 placeholder devices).

    single-pod:  (16, 16)      axes ("data", "model")       = 256 chips
    multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

The "pod" axis is pure data parallelism across pods (gradient all-reduce
over DCI); "data" is in-pod data parallel / FSDP; "model" is tensor/expert
parallel over ICI.
"""

from __future__ import annotations

import jax


def build_mesh(shape, axes):
    try:
        from jax.sharding import AxisType
    except ImportError:
        # jax < 0.5: make_mesh has no axis_types kwarg and axes default to
        # the same auto-sharding behavior AxisType.Auto selects
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return build_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) host devices)."""
    return build_mesh(shape, axes)
