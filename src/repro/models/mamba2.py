"""Mamba-2 (SSD — state-space duality) mixer, chunked, plus O(1) decode.

Faithful to the SSD algorithm (Dao & Gu, arXiv:2405.21060): the sequence is
split into chunks of length Q; within a chunk the output is a masked
quadratic form (the "attention-like" dual), across chunks a linear recurrence
carries the (H, N, P) state. Both terms are einsums — MXU-shaped — and the
inter-chunk scan is O(S/Q), which is what makes long_500k tractable.

Shapes: x (B,S,H,P) head inputs, a (B,S,H) log-decay (= A*dt, negative),
B_/C_ (B,S,G,N) input/output projections (G groups broadcast over H).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Constrain = Callable[[jnp.ndarray, tuple], jnp.ndarray]
_noop: Constrain = lambda x, axes: x


class SSMState(NamedTuple):
    state: jnp.ndarray      # (B, H, N, P)
    conv: jnp.ndarray       # (B, K-1, conv_ch) rolling conv window


def _expand_groups(t: jnp.ndarray, H: int) -> jnp.ndarray:
    """(B, S, G, N) -> (B, S, H, N) by repeating each group H/G times."""
    G = t.shape[2]
    if G == H:
        return t
    return jnp.repeat(t, H // G, axis=2)


def ssd_chunked(x: jnp.ndarray, a: jnp.ndarray, B_: jnp.ndarray, C_: jnp.ndarray,
                chunk: int, constrain: Constrain = _noop,
                init_state: jnp.ndarray | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P) float32, final_state (B,H,N,P) float32).

    x is assumed already scaled by dt (i.e. the B dt x term's dt)."""
    B, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    # self-pad S to a chunk multiple: a=0, x=0 padding is a no-op on the state
    # (decay exp(0)=1, zero input) and the padded outputs are sliced off.
    s_pad = (-S) % Q
    if s_pad:
        x = jnp.pad(x, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, s_pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    S_p = S + s_pad
    nc = S_p // Q

    Bh = _expand_groups(B_, H).astype(jnp.float32)
    Ch = _expand_groups(C_, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)

    # chunk views
    xc = xf.reshape(B, nc, Q, H, P)
    ac = af.reshape(B, nc, Q, H)
    Bc = Bh.reshape(B, nc, Q, H, N)
    Cc = Ch.reshape(B, nc, Q, H, N)
    xc = constrain(xc, ("data", None, None, "model", None))
    Bc = constrain(Bc, ("data", None, None, "model", None))
    Cc = constrain(Cc, ("data", None, None, "model", None))

    cum = jnp.cumsum(ac, axis=2)                                 # (B,nc,Q,H)

    # ---- intra-chunk (diagonal) term: masked quadratic form --------------
    # L[q, t] = exp(cum[q] - cum[t]) for q >= t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,Q,Q,H)
    qi = jnp.arange(Q)
    causal = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    # mask BEFORE exp: future positions have seg > 0 and would overflow; the
    # where-after-exp form is forward-safe but produces inf*0 = NaN in the VJP.
    L = jnp.exp(jnp.where(causal, seg, -1e30))
    scores = jnp.einsum("bcqhn,bcthn->bcqth", Cc, Bc)            # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bcqth,bcqth,bcthp->bcqhp", scores, L, xc)

    # ---- chunk states ----------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (B,nc,Q,H)
    states = jnp.einsum("bcthn,bcth,bcthp->bchnp", Bc, decay_to_end, xc)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp                                            # (B,H,N,P), (B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    s0 = (jnp.zeros((B, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                # (B,nc,H,N,P)

    # ---- inter-chunk output term ------------------------------------------
    state_decay = jnp.exp(cum)                                   # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp", Cc, state_decay, prev_states)

    y = (y_diag + y_off).reshape(B, S_p, H, P)[:, :S]
    return y, final_state


def mamba2_mixer(x: jnp.ndarray, p: dict, cfg, constrain: Constrain = _noop,
                 state: SSMState | None = None, return_state: bool = False):
    """Full Mamba-2 block on (B, S, d_model). p holds: in_proj, conv_w (K, ch),
    conv_b, A_log (H,), D (H,), dt_bias (H,), norm (d_inner,), out_proj."""
    B, S, d = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    d_in = cfg.d_inner
    K = cfg.ssm_conv
    conv_ch = d_in + 2 * G * N

    zxbcdt = x @ p["in_proj"]                    # (B,S, 2*d_in + 2GN + H)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + conv_ch], axis=-1)

    # causal depthwise conv over xBC (window K), silu
    if state is None:
        pad = jnp.zeros((B, K - 1, conv_ch), xBC.dtype)
    else:
        pad = state.conv.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)     # (B, S+K-1, ch)
    conv = sum(xp[:, j:j + S] * p["conv_w"][j][None, None, :] for j in range(K))
    xBC = jax.nn.silu(conv + p["conv_b"])
    new_conv = xp[:, S:, :]                      # last K-1 raw inputs

    x_in, B_, C_ = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    x_in = x_in.reshape(B, S, H, P)
    B_ = B_.reshape(B, S, G, N)
    C_ = C_.reshape(B, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    a = A[None, None, :] * dt                                    # (B,S,H) log decay
    x_dt = x_in.astype(jnp.float32) * dt[..., None]

    y, fstate = ssd_chunked(x_dt, a, B_, C_, cfg.ssm_chunk, constrain,
                            None if state is None else state.state)
    y = y + p["D"][None, None, :, None] * x_in.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)

    # gated RMSNorm (mamba2)
    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype)
    out = (g * p["norm"]) @ p["out_proj"]
    if return_state:
        return out, SSMState(state=fstate, conv=new_conv)
    return out


def mamba2_decode_step(x_t: jnp.ndarray, p: dict, cfg,
                       state: SSMState) -> tuple[jnp.ndarray, SSMState]:
    """Single-token decode: x_t (B, 1, d) -> (y (B, 1, d), new state). O(1) in
    context length — the reason SSM archs run the long_500k cell."""
    B = x_t.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    d_in = cfg.d_inner
    conv_ch = d_in + 2 * G * N

    zxbcdt = x_t[:, 0] @ p["in_proj"]            # (B, ...)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + conv_ch], axis=-1)

    win = jnp.concatenate([state.conv.astype(xBC.dtype), xBC[:, None, :]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", win, p["conv_w"])
    xBC = jax.nn.silu(conv + p["conv_b"])
    new_conv = win[:, 1:, :]

    x_in, B_, C_ = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    x_in = x_in.reshape(B, H, P)
    B_ = _expand_groups(B_.reshape(B, 1, G, N), H)[:, 0]          # (B,H,N)
    C_ = _expand_groups(C_.reshape(B, 1, G, N), H)[:, 0]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(A[None] * dt)                                 # (B,H)
    x_dt = x_in.astype(jnp.float32) * dt[..., None]               # (B,H,P)

    s = state.state * decay[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", B_.astype(jnp.float32), x_dt)
    y = jnp.einsum("bhn,bhnp->bhp", C_.astype(jnp.float32), s)
    y = y + p["D"][None, :, None] * x_in.astype(jnp.float32)
    y = y.reshape(B, d_in).astype(x_t.dtype)

    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x_t.dtype)
    out = ((g * p["norm"]) @ p["out_proj"])[:, None, :]
    return out, SSMState(state=s, conv=new_conv)


def ssd_naive_ref(x: jnp.ndarray, a: jnp.ndarray, B_: jnp.ndarray,
                  C_: jnp.ndarray) -> jnp.ndarray:
    """O(S^2-free) sequential-recurrence oracle for tests: step the SSM one
    token at a time. x (B,S,H,P) pre-scaled by dt, a (B,S,H) log decay."""
    B, S, H, P = x.shape
    N = B_.shape[-1]
    Bh = _expand_groups(B_, H).astype(jnp.float32)
    Ch = _expand_groups(C_, H).astype(jnp.float32)

    def step(s, t):
        dec = jnp.exp(a[:, t].astype(jnp.float32))                # (B,H)
        s = s * dec[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh[:, t], x[:, t].astype(jnp.float32))
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], s)
        return s, y

    s0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, s0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1)                                 # (B,S,H,P)
