"""TTFS encode/decode unit + property tests."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ttfs


def test_encode_brighter_is_earlier():
    x = jnp.asarray([[0.1, 0.5, 0.9, 1.0, 0.0]])
    t = np.asarray(ttfs.encode_ttfs(x, T=32))
    assert t[0, 3] <= t[0, 2] <= t[0, 1] <= t[0, 0]
    assert t[0, 4] == 32  # zero pixel never spikes


def test_encode_range_and_sentinel():
    x = jnp.asarray(np.linspace(0, 1, 100)[None])
    t = np.asarray(ttfs.encode_ttfs(x, T=16))
    assert t.min() >= 0 and t.max() <= 16
    assert np.all(t[np.asarray(x) >= 1 / 255] <= 15)


def test_frames_one_spike_per_neuron():
    x = jnp.asarray(np.random.RandomState(0).rand(4, 50))
    times = ttfs.encode_ttfs(x, T=8)
    frames = np.asarray(ttfs.frames_from_times(times, 8))
    assert frames.shape == (4, 8, 50)
    assert np.all(frames.sum(axis=1) <= 1)      # TTFS contract: <= 1 spike
    fired = frames.sum(axis=1)
    assert np.array_equal(fired == 1, np.asarray(times) < 8)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_decode_earliest_group_wins(seed):
    rng = np.random.RandomState(seed % 2**32)
    G, P, T = 10, 15, 32
    first = rng.randint(0, T + 1, (3, G * P)).astype(np.int32)
    v = rng.randint(-100, 1000, (3, G * P)).astype(np.int32)
    labels = np.asarray(ttfs.decode_labels(
        jnp.asarray(first), jnp.asarray(v), n_groups=G, per_group=P,
        sentinel=T, fallback="membrane"))
    gmin = first.reshape(3, G, P).min(-1)
    for b in range(3):
        if gmin[b].min() < T:
            assert gmin[b, labels[b]] == gmin[b].min()
            # first-index tiebreak
            assert labels[b] == int(np.argmin(gmin[b]))
        else:
            gv = v.reshape(3, G, P).max(-1)
            assert labels[b] == int(np.argmax(gv[b]))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_decode_permutation_within_group_invariant(seed):
    """Shuffling neurons WITHIN a group never changes the decoded label."""
    rng = np.random.RandomState(seed % 2**32)
    G, P, T = 4, 6, 16
    first = rng.randint(0, T + 1, (G, P)).astype(np.int32)
    v = rng.randint(-50, 500, (G, P)).astype(np.int32)
    l0 = int(ttfs.decode_labels(jnp.asarray(first.reshape(1, -1)),
                                jnp.asarray(v.reshape(1, -1)), n_groups=G,
                                per_group=P, sentinel=T)[0])
    perm = rng.permutation(P)
    l1 = int(ttfs.decode_labels(jnp.asarray(first[:, perm].reshape(1, -1)),
                                jnp.asarray(v[:, perm].reshape(1, -1)),
                                n_groups=G, per_group=P, sentinel=T)[0])
    assert l0 == l1
