import importlib.util
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too, so the benchmarks package (schema validation) is importable
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

if importlib.util.find_spec("hypothesis") is None:
    # container image has no hypothesis; register the deterministic stub so
    # property-test modules collect and run instead of erroring out
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__),
                                   "_hypothesis_stub.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

# NOTE: no XLA_FLAGS here — tests must see the default single CPU device.
# Only launch/dryrun.py forces 512 placeholder devices (in a subprocess).


@pytest.fixture(scope="session")
def small_data():
    """Small procedural-MNIST-like split for fast tests."""
    from repro.data import mnist
    xtr, ytr = mnist.generate(4096, seed=7)
    xte, yte = mnist.generate(1024, seed=8)
    return xtr, ytr, xte, yte


@pytest.fixture(scope="session")
def trained_artifact(small_data, tmp_path_factory):
    """A real (small-training-run) exported artifact shared across tests."""
    from repro.core import deploy
    from repro.training.ttfs_trainer import train_dense_proxy
    xtr, ytr, xte, yte = small_data
    res = train_dense_proxy(xtr, ytr, test_images=xte, test_labels=yte,
                            epochs=2, batch=256, seed=0)
    path = str(tmp_path_factory.mktemp("art") / "model.npz")
    art = deploy.export(res.model, path, calib_images=xtr[:1024],
                        calib_labels=ytr[:1024])
    return art, path, (xte, yte)
