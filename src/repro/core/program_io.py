"""Cross-process ``LoweredProgram`` distribution: serialize / deserialize.

The ROADMAP item this implements is "lower once per *process group*": in
multi-host serving every host holds the same exported artifact on disk, so
shipping device arrays over the wire would be pure waste. The envelope
therefore carries only what the arrays cannot reproduce — the typed scalars,
the encode/decode plans, and the content fingerprints — as canonical JSON:

    {"format": 1,
     "program_fingerprint": "...", "artifact_fingerprint": "...",
     "scalars": {"T": ..., "x_min": ..., ...},
     "encode": {...}, "decode": {...},
     "arrays": {"w_float": "<sha256>", ...}}

``deserialize_program`` re-maps the arrays from the *local* artifact and
re-verifies every one against the envelope's hashes, recomputes the program
fingerprint from (artifact fingerprint, scalars) and demands it match the
envelope's — so a follower either reconstructs a program bit-identical to
the leader's lower (skipping ``_lower_uncached`` entirely) or fails loudly
with the first mismatched field named. The conformance ``program-io`` oracle
pins the roundtrip on every fuzzed artifact.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

import jax.numpy as jnp

from repro.core.artifact import Artifact, array_hash
from repro.core.hw import PYNQ_COST
from repro.core.lowering import (REQUIRED_ARRAYS, LoweredProgram,
                                 get_cache, program_fingerprint)
from repro.core.types import DecodePlan, EncodePlan

FORMAT_VERSION = 1

#: envelope scalar order mirrors the ``scalars`` dict in ``_lower_uncached``
SCALAR_FIELDS = ("T", "x_min", "e_max", "leak_shift", "n_in", "n_out",
                 "n_groups", "per_group", "fallback", "scale", "n_pad",
                 "lane")


class ProgramIOError(ValueError):
    """The envelope does not reconstruct a valid program on this host."""


def serialize_program(prog: LoweredProgram) -> bytes:
    """Canonical JSON envelope for one lowered program (no array payload)."""
    if not isinstance(prog, LoweredProgram):
        raise TypeError(f"cannot serialize {type(prog).__name__} "
                        f"(expected LoweredProgram)")
    art = prog.artifact
    envelope = {
        "format": FORMAT_VERSION,
        "program_fingerprint": prog.fingerprint,
        "artifact_fingerprint": art.fingerprint(),
        "scalars": {f: getattr(prog, f) for f in SCALAR_FIELDS},
        "encode": dataclasses.asdict(prog.encode),
        "decode": dataclasses.asdict(prog.decode),
        "arrays": {n: array_hash(art.arrays[n]) for n in REQUIRED_ARRAYS},
    }
    return json.dumps(envelope, sort_keys=True,
                      separators=(",", ":")).encode()


def envelope_digest(blob: bytes) -> str:
    """SHA-256 hex over the raw envelope bytes — the content address the
    network transport stamps into its frame checksum and telemetry. Distinct
    from ``program_fingerprint`` (which binds scalars to the artifact): this
    digest names the exact serialized BYTES, so two hosts can agree they
    hold the same envelope without parsing it."""
    return hashlib.sha256(blob).hexdigest()


def _load_envelope(blob: bytes) -> dict:
    try:
        env = json.loads(blob)
    except (ValueError, UnicodeDecodeError) as e:
        raise ProgramIOError(f"envelope is not valid JSON: {e}") from None
    if not isinstance(env, dict):
        raise ProgramIOError(f"envelope must be a JSON object, "
                             f"got {type(env).__name__}")
    if env.get("format") != FORMAT_VERSION:
        raise ProgramIOError(f"envelope format {env.get('format')!r} != "
                             f"supported {FORMAT_VERSION}")
    for key in ("program_fingerprint", "artifact_fingerprint", "scalars",
                "encode", "decode", "arrays"):
        if key not in env:
            raise ProgramIOError(f"envelope is missing {key!r}")
    return env


def deserialize_program(blob: bytes, artifact: Artifact, *,
                        cache: bool = True) -> LoweredProgram:
    """Reconstruct a leader's program against the local artifact copy.

    Verification order is deliberate — cheapest and most diagnostic first:
    artifact fingerprint (whole-artifact identity), then per-array hashes
    (names the drifted array), then the recomputed program fingerprint
    (binds the scalars). With ``cache=True`` the program is seeded into the
    active cache so later ``lower(artifact)`` / ``make_runtime`` calls on
    this host hit without ever lowering."""
    if not isinstance(artifact, Artifact):
        raise TypeError(f"cannot deserialize against "
                        f"{type(artifact).__name__} (expected Artifact)")
    env = _load_envelope(blob)
    art_fp = artifact.fingerprint()
    if env["artifact_fingerprint"] != art_fp:
        raise ProgramIOError(
            f"local artifact fingerprint {art_fp[:12]}... != envelope's "
            f"{str(env['artifact_fingerprint'])[:12]}... — the follower's "
            f"artifact copy is not the one the leader lowered")
    if set(env["arrays"]) != set(REQUIRED_ARRAYS):
        raise ProgramIOError(
            f"envelope array set {sorted(env['arrays'])} != required "
            f"{sorted(REQUIRED_ARRAYS)}")
    for name in REQUIRED_ARRAYS:
        if name not in artifact.arrays:
            raise ProgramIOError(f"local artifact is missing array {name!r}")
        local = array_hash(artifact.arrays[name])
        if local != env["arrays"][name]:
            raise ProgramIOError(
                f"array {name!r} hash mismatch: local {local[:12]}... != "
                f"envelope {str(env['arrays'][name])[:12]}...")
    scalars = env["scalars"]
    if set(scalars) != set(SCALAR_FIELDS):
        raise ProgramIOError(
            f"envelope scalar set {sorted(scalars)} != expected "
            f"{sorted(SCALAR_FIELDS)}")
    expect_fp = program_fingerprint(art_fp, scalars)
    if expect_fp != env["program_fingerprint"]:
        raise ProgramIOError(
            f"recomputed program fingerprint {expect_fp[:12]}... != "
            f"envelope's {str(env['program_fingerprint'])[:12]}... — "
            f"scalars were altered in transit")
    try:
        encode = EncodePlan(**env["encode"])
        decode = DecodePlan(**env["decode"])
    except TypeError as e:
        raise ProgramIOError(f"envelope plan fields do not reconstruct "
                             f"encode/decode plans: {e}") from None
    # the plans are redundant with the scalars BY CONSTRUCTION (lowering
    # derives them); demand consistency so a tamperer cannot smuggle a
    # divergent plan past the fingerprint check (which binds scalars only)
    want_encode = EncodePlan(T=scalars["T"], x_min=scalars["x_min"],
                             e_max=scalars["e_max"], n_in=scalars["n_in"])
    want_decode = DecodePlan(n_groups=scalars["n_groups"],
                             per_group=scalars["per_group"],
                             sentinel=scalars["T"],
                             fallback=scalars["fallback"])
    if encode != want_encode:
        raise ProgramIOError(f"envelope encode plan {env['encode']} is "
                             f"inconsistent with its scalars — plan fields "
                             f"were altered independently")
    if decode != want_decode:
        raise ProgramIOError(f"envelope decode plan {env['decode']} is "
                             f"inconsistent with its scalars — plan fields "
                             f"were altered independently")
    prog = LoweredProgram(
        fingerprint=expect_fp,
        artifact=artifact,
        T=scalars["T"], x_min=scalars["x_min"], e_max=scalars["e_max"],
        leak_shift=scalars["leak_shift"], n_in=scalars["n_in"],
        n_out=scalars["n_out"], n_groups=scalars["n_groups"],
        per_group=scalars["per_group"], fallback=scalars["fallback"],
        scale=scalars["scale"], n_pad=scalars["n_pad"],
        lane=scalars["lane"],
        w_float=jnp.asarray(artifact["w_float"]),
        w_int8=jnp.asarray(artifact["w_int8"]),
        thresholds=jnp.asarray(artifact["thresholds"]),
        w_padded=jnp.asarray(artifact["w_padded"]),
        thr_padded=jnp.asarray(artifact["thr_padded"]),
        encode=encode, decode=decode,
        cost=PYNQ_COST)
    if cache:
        prog = get_cache().seed(art_fp, prog)
    return prog
