"""Runtime construction cost: cold lowering+compile vs the program cache.

The lowering refactor's operational claim is that runtime construction is
two-tier: a COLD build lowers the artifact and jit-compiles the family's
callable bundle, while every later build over the same (artifact, config)
comes out of the process-wide ``ProgramCache`` — the serving tier leans on
this when the watchdog replaces a hung lane mid-traffic (the rebuilt lane
must NOT pay XLA compile latency again while requests queue).

Two measurements, both system-scope (host wall clock):

  * per advertised family config: time-to-first-served-batch for a cold
    process-state build (``PROGRAM_CACHE.clear()`` first — fresh bundle
    closures force real recompilation) vs a cached rebuild. ``--check``
    gates cached >= 3x faster than cold for every jitted spec (board-py
    builds no jitted bundle and is reported ungated).
  * the watchdog scenario end-to-end: a one-lane scheduler whose lane hangs
    on its first batch; the replacement lane's ``runtime.build`` span must
    record ``cache_hit`` in its meta, proving lane recovery rides the cache.

Emits ``results/bench/runtime_build.json`` (schema-validated).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks import common as CM
from repro.core.lowering import PROGRAM_CACHE
from repro.core.runtimes import make_runtime
from repro.telemetry import trace as ttrace
from repro.telemetry.trace import Tracer

#: one spec per distinct compiled-bundle config; board-py is the uncompiled
#: control (pure-python scheduler — nothing to jit, so no 3x gate)
SPECS = ("reference", "accelerator-batch", "accelerator-event",
         "accelerator-event-fused", "board-batched", "board-py")
UNGATED = {"board-py"}
GATE_SPEEDUP = 3.0


def _build_and_serve_ms(art, spec: str, images: np.ndarray) -> float:
    """Time-to-first-served-batch: construct + one forward (the forward
    triggers jit tracing/compilation, which is the cost a replacement lane
    would otherwise pay while requests queue)."""
    t0 = time.perf_counter()
    rt = make_runtime(art, spec)
    rt.forward(images)
    return 1e3 * (time.perf_counter() - t0)


def _watchdog_row(art, images: np.ndarray) -> dict:
    """Serve through a hung lane with a Tracer installed; the watchdog's
    replacement lane must be a cache hit (visible in runtime.build meta)."""
    from repro.faults.plan import FaultPlan
    from repro.serving.scheduler import ServingScheduler

    make_runtime(art, "accelerator-event").forward(images[:1])  # warm cache
    plan = FaultPlan(seed=1, hang_batches=(0,), hang_s=2.0, lanes=(0,))
    tracer = Tracer()
    prev = ttrace.install(tracer)
    t0 = time.perf_counter()
    try:
        with ServingScheduler(art, spec="accelerator-event", workers=1,
                              max_batch=8, max_wait_us=500.0, faults=plan,
                              resilience={"watchdog_s": 0.2,
                                          "backoff_s": 0.001}) as s:
            for img in images[:8]:
                s.submit(img)
            s.drain()
            st = s.stats()
    finally:
        ttrace.install(prev)
    wall_ms = 1e3 * (time.perf_counter() - t0)
    builds = [sp for sp in tracer.spans if sp.name == "runtime.build"]
    hits = [sp for sp in builds if sp.meta.get("cache_hit") is True]
    return {"config": "watchdog-replacement-lane",
            "scope": "system (serving tier, host wall clock)",
            "wall_ms": wall_ms,
            "runtime_builds": len(builds),
            "cache_hit_builds": len(hits),
            "watchdog_timeouts": int(st.get("watchdog_timeouts", 0)),
            "lane_restarts": int(st.get("lane_restarts", 0)),
            "errors": int(st.get("errors", 0)),
            "telemetry": {"span_count": len(tracer.spans)}}


def main(quick: bool = False, check: bool = False) -> int:
    art, xte, _ = CM.get_artifact_and_data(quick=quick)
    images = xte[:16]
    rows: list[dict] = []
    print(f"runtime build cost, cold (lower + jit compile) vs cached "
          f"({len(images)} img first batch):")
    for spec in SPECS:
        serve = images[:4] if spec == "board-py" else images
        PROGRAM_CACHE.clear()
        cold_ms = _build_and_serve_ms(art, spec, serve)
        cached_ms = min(_build_and_serve_ms(art, spec, serve)
                        for _ in range(3))
        speedup = cold_ms / cached_ms if cached_ms > 0 else float("inf")
        rows.append({"runtime": spec,
                     "scope": "system (runtime construction, host wall "
                              "clock)",
                     "cold_build_ms": cold_ms,
                     "cached_build_ms": cached_ms,
                     "speedup": speedup,
                     "gated": spec not in UNGATED})
        gate = "" if spec in UNGATED else f"  (gate >= {GATE_SPEEDUP}x)"
        print(f"  {spec:28s} cold {cold_ms:8.1f} ms   cached "
              f"{cached_ms:7.1f} ms   {speedup:6.1f}x{gate}")

    wd = _watchdog_row(art, images)
    rows.append(wd)
    print(f"watchdog scenario: {wd['runtime_builds']} lane builds, "
          f"{wd['cache_hit_builds']} cache hits, "
          f"{wd['watchdog_timeouts']} timeouts, "
          f"{wd['lane_restarts']} restarts in {wd['wall_ms']:.0f} ms")

    CM.emit("runtime_build", rows)

    if check:
        bad = []
        for r in rows:
            if r.get("gated") and r["speedup"] < GATE_SPEEDUP:
                bad.append(f"{r['runtime']}: cached build only "
                           f"{r['speedup']:.1f}x faster than cold "
                           f"(gate {GATE_SPEEDUP}x)")
        if wd["watchdog_timeouts"] < 1:
            bad.append("watchdog never fired (timeouts == 0)")
        if wd["lane_restarts"] < 1:
            bad.append("hung lane was never replaced (lane_restarts == 0)")
        if wd["cache_hit_builds"] < 1:
            bad.append("no runtime.build span recorded cache_hit=True — "
                       "the replacement lane recompiled from scratch")
        if wd["errors"]:
            bad.append(f"{wd['errors']} requests errored during recovery")
        if bad:
            print("CHECK FAILED: " + "; ".join(bad), file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller eval slice (the CI configuration)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless cached builds are >= 3x faster than "
                         "cold for every jitted spec and the watchdog "
                         "replacement lane is a cache hit")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, check=a.check))
