"""Paper Table 1 analogue: deployment resource analysis.

The paper reports post-route utilization (BRAM 140/140 = the binding
constraint). Our planner answers the same co-design question for the TPU
budget: does the event-processing working set fit on-chip (VMEM = the BRAM
analogue), what binds first, and how far the topology could scale."""

from __future__ import annotations

from benchmarks import common as CM
from repro.core import codesign
from repro.core.hw import PYNQ_Z2


def run(quick: bool = False) -> list[dict]:
    art, _, _ = CM.get_artifact_and_data(quick)
    n_in = art.m("model", "n_in")
    n_out = art.m("model", "n_out")
    rows = []
    for label, ni, no in [
        ("deployed 784->150 (paper workload)", n_in, n_out),
        ("paper's direct-addressing limit (2048 neurons)", n_in, 2048),
        ("paper's encodable limit (4890 neurons)", n_in, 4890),
        ("VMEM-limit topology at n_in=784", n_in,
         codesign.plan(n_in, n_out).max_neurons_vmem),
    ]:
        r = codesign.plan(ni, no)
        rows.append({"config": label, "scope": "planner",
                     "n_out": no, "n_pad": r.n_pad,
                     "blocks": r.n_blocks, "synapses": r.synapses,
                     "vmem_bytes": r.vmem_bytes_total,
                     "vmem_util_pct": 100 * r.vmem_util,
                     "limiter": r.limiter})
    CM.emit("resources", rows)
    return rows


def main(quick: bool = False):
    art, _, _ = CM.get_artifact_and_data(quick)
    print(codesign.plan(art.m("model", "n_in"), art.m("model", "n_out")).table())
    print()
    for r in run(quick):
        print(f"{r['config']:<48} pad={r['n_pad']:>6} "
              f"VMEM={r['vmem_util_pct']:>7.3f}%  {r['limiter']}")
    print(f"\npaper reference: BRAM {PYNQ_Z2.bram_tiles}/{PYNQ_Z2.bram_tiles} "
          f"(100%) — BRAM-limited; {PYNQ_Z2.packed_synapses:,} packed synapses")


if __name__ == "__main__":
    main()
