"""Correctness of §Perf optimization paths: every variant must compute the
same function as its baseline (optimizations may not change semantics)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.models.layers import chunked_attention, decode_attention


def test_gqa_repeat_equals_grouped_chunked():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 8, 64, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 96, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 96, 16), jnp.float32)
    for kw in (dict(causal=True, q_offset=32), dict(causal=False),
               dict(causal=True, window=24, q_offset=32)):
        a = chunked_attention(q, k, v, bq=32, bk=32, gqa="grouped", **kw)
        b = chunked_attention(q, k, v, bq=32, bk=32, gqa="repeat", **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_gqa_repeat_equals_grouped_decode():
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(2, 8, 1, 16), jnp.float32)
    kc = jnp.asarray(rng.randn(2, 2, 32, 16), jnp.float32)
    vc = jnp.asarray(rng.randn(2, 2, 32, 16), jnp.float32)
    for kw in (dict(cache_len=jnp.int32(20)),
               dict(cache_len=jnp.int32(32), window=8, window_rotated=True)):
        a = decode_attention(q, kc, vc, gqa="grouped", **kw)
        b = decode_attention(q, kc, vc, gqa="repeat", **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_moe_local_buf_mode_equals_oracle():
    from repro.models.moe import moe_ffn, moe_ffn_dense_oracle
    rng = np.random.RandomState(2)
    p = {"router": jnp.asarray(rng.randn(16, 4) * 0.1, jnp.float32),
         "w_gate": jnp.asarray(rng.randn(4, 16, 32) * 0.1, jnp.float32),
         "w_up": jnp.asarray(rng.randn(4, 16, 32) * 0.1, jnp.float32),
         "w_down": jnp.asarray(rng.randn(4, 32, 16) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.randn(2, 8, 16), jnp.float32)
    y, _ = moe_ffn(x, p, n_experts=4, top_k=2, capacity_factor=8.0,
                   buf_mode="local")
    y2 = moe_ffn_dense_oracle(x, p, n_experts=4, top_k=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_remat_policies_same_loss():
    import dataclasses
    from repro.models.model import LM
    base = reduced(get_config("yi-6b"))
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(0, base.vocab, (2, 16)))
    batch = {"tokens": toks, "labels": toks}
    losses = {}
    for pol in ("full", "dots", "none"):
        cfg = dataclasses.replace(base, remat=pol != "none", remat_policy=pol)
        lm = LM(cfg)
        params = lm.init_params(jax.random.PRNGKey(0), jnp.float32)
        loss, _ = lm.loss(params, batch)
        g = jax.grad(lambda p: lm.loss(p, batch)[0])(params)
        losses[pol] = (float(loss),
                       float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(g))))
    for pol in ("dots", "none"):
        assert abs(losses[pol][0] - losses["full"][0]) < 1e-5
        assert abs(losses[pol][1] - losses["full"][1]) / losses["full"][1] < 1e-4


SHMAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.models.moe import moe_ffn_shard_map, moe_ffn_dense_oracle
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.RandomState(0)
E, k, d, f = 4, 2, 16, 32
p = {"router": jnp.asarray(rng.randn(d, E)*0.1, jnp.float32),
     "w_gate": jnp.asarray(rng.randn(E, d, f)*0.1, jnp.float32),
     "w_up": jnp.asarray(rng.randn(E, d, f)*0.1, jnp.float32),
     "w_down": jnp.asarray(rng.randn(E, f, d)*0.1, jnp.float32)}
x = jnp.asarray(rng.randn(4, 8, d), jnp.float32)
with mesh:
    fn = jax.jit(lambda x, p: moe_ffn_shard_map(
        x, p, n_experts=E, top_k=k, capacity_factor=8.0, mesh=mesh))
    y, aux = fn(x, p)
    y2 = moe_ffn_dense_oracle(x, p, n_experts=E, top_k=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    g = jax.grad(lambda x, p: jnp.sum(fn(x, p)[0] ** 2))(x, p)
    assert np.all(np.isfinite(np.asarray(g)))
print("SHMAP_OK")
"""


@pytest.mark.slow
def test_shard_map_moe_equals_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SHMAP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2500:]
    assert "SHMAP_OK" in proc.stdout
