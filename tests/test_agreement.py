"""The paper's headline validation: bit-exact reference<->accelerator
agreement over the test set, plus the repeatability protocol (§3.3)."""

import numpy as np

from repro.core.accelerator import SNNAccelerator
from repro.core.agreement import full_agreement, repeatability
from repro.core.reference import SNNReference


def test_full_agreement_all_runtimes(trained_artifact):
    """Default harness is now three-way: reference / accelerator / board."""
    art, _, (xte, yte) = trained_artifact
    rep = full_agreement(art, xte[:512], yte[:512], chunk=256)
    assert rep.exact_match, rep.summary()
    assert rep.runtimes == ["reference", "accelerator-batch",
                            "accelerator-event", "board"]
    for rt in ("accelerator-batch", "accelerator-event", "board"):
        assert rep.label_mismatches[rt] == 0
        assert rep.spike_time_mismatches[rt] == 0


def test_pallas_kernel_path_agreement(trained_artifact):
    art, _, (xte, yte) = trained_artifact
    ref = SNNReference(art)
    out_ref = ref.forward(xte[:96])
    for mode in ("batch", "event"):
        acc = SNNAccelerator(art, mode=mode, kernel="pallas")
        out = acc.forward(xte[:96])
        assert np.array_equal(np.asarray(out.labels), np.asarray(out_ref.labels))
        assert np.array_equal(np.asarray(out.first_spike),
                              np.asarray(out_ref.first_spike))


def test_repeatability_protocol(trained_artifact):
    art, _, (xte, yte) = trained_artifact
    r = repeatability(art, xte[:256], yte[:256], runs=5, chunk=256)
    assert r["mismatches"] == 0
    assert r["image_run_pairs"] == 5 * 256
    assert r["accuracy_stable"]


def test_early_exit_labels_match_full_run(trained_artifact):
    """Event-driven early exit (decision at first spike) must decode the
    same labels as the full-T evaluation."""
    art, _, (xte, _) = trained_artifact
    acc = SNNAccelerator(art, mode="event")
    full = acc.forward(xte[:64])
    lat = acc.forward(xte[:64], latency_mode=True)
    assert np.array_equal(np.asarray(full.labels), np.asarray(lat.labels))
    # early exit must never take MORE steps than the window
    assert np.all(np.asarray(lat.steps) <= art.m("encode", "T"))


def test_dense_baselines_execute_same_parameters(trained_artifact):
    """Table 3 discipline: dense rows reuse the exported parameters."""
    art, _, (xte, yte) = trained_artifact
    ref = SNNReference(art)
    acc_fp32 = float(np.mean(np.asarray(ref.dense_labels(xte, "fp32")) == yte))
    acc_int8 = float(np.mean(np.asarray(ref.dense_labels(xte, "int8")) == yte))
    ttfs = full_agreement(art, xte[:512], yte[:512], runtimes=(), chunk=256)
    # dense executions of the same weights are at least as accurate as TTFS
    # (the paper's ordering: 87.69/87.70 dense vs 87.40 TTFS)
    assert acc_fp32 >= ttfs.accuracy["reference"] - 0.02
    assert acc_int8 >= ttfs.accuracy["reference"] - 0.02
