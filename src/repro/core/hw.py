"""Hardware constants for the TPU v5e-class target and the paper's FPGA.

All roofline terms, the deployment planner (the Table-1 "resource utilization"
analogue) and the energy model (the Table-3 analogue) read from here, so the
assumptions live in exactly one place.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuTarget:
    """TPU v5e-class single-chip budget (assignment constants)."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12       # FLOP/s per chip
    hbm_bandwidth: float = 819e9          # bytes/s per chip
    ici_link_bandwidth: float = 50e9      # bytes/s per link
    ici_links_per_chip: int = 4           # 2D torus (v5e-class)
    hbm_bytes: int = 16 * 2**30           # 16 GiB HBM per chip
    vmem_bytes: int = 32 * 2**20          # ~32 MiB VMEM per core (planner budget)
    lane_width: int = 128                 # VREG lane dim == MXU tile dim
    sublane_width: int = 8
    # Energy model constants (order-of-magnitude, labeled estimates — the
    # paper's own energy numbers are tool-based estimates too, UG907).
    pj_per_flop_bf16: float = 0.25
    pj_per_hbm_byte: float = 60.0
    pj_per_vmem_byte: float = 1.0     # on-chip (the BRAM-energy analogue)
    pj_per_ici_byte: float = 120.0


@dataclasses.dataclass(frozen=True)
class FpgaReference:
    """The paper's deployed design point (PYNQ-Z2 / XC7Z020) — for scope-aware
    comparisons in the benchmark harness."""

    name: str = "pynq-z2-80mhz"
    clock_hz: float = 80e6
    first_spike_cycles: int = 12
    service_cycles: int = 11
    service_latency_us: float = 0.1375
    dynamic_energy_nj: float = 31.6
    accuracy_pct: float = 87.40
    neurons_direct: int = 2048            # 16 groups x 128
    groups: int = 16
    neurons_per_group: int = 128
    encodable_neurons: int = 4890
    packed_synapses: int = 843_776
    bram_tiles: int = 140                 # saturated — the design is BRAM-limited


TPU_V5E = TpuTarget()
PYNQ_Z2 = FpgaReference()


def matmul_flops(m: int, k: int, n: int) -> int:
    return 2 * m * k * n


def dyn_energy_joules(flops: float, hbm_bytes: float, ici_bytes: float = 0.0,
                      target: TpuTarget = TPU_V5E) -> float:
    """Dynamic-energy *estimate* (J) from the counter model. Labeled estimate,
    mirroring the paper's Vivado-based PL-dynamic estimates."""
    return (flops * target.pj_per_flop_bf16
            + hbm_bytes * target.pj_per_hbm_byte
            + ici_bytes * target.pj_per_ici_byte) * 1e-12
