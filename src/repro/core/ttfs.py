"""Time-to-first-spike (TTFS) encoding and grouped decoding.

Semantics are INTEGER and deterministic: both the software reference and the
accelerator runtime call these exact functions (or kernels proven equal to
them), which is what makes full-test-set prediction agreement a meaningful
claim rather than a float-tolerance accident.

Encoding (input layer): pixel intensity x in [0,1] maps to spike time
    t = floor((1 - x) * (T - 1))            if x >= x_min   (brighter => earlier)
    t = T  (sentinel: never spikes)          otherwise
Each input neuron spikes at most once — the TTFS contract.

Decoding (output layer, paper §2.3): 150 output neurons = 10 class groups x 15.
The decoded label is the group containing the earliest first output spike;
ties break to the lowest group id (argmin's first-index rule — deterministic).
If no output neuron spikes, an artifact-selected fallback applies:
    "membrane": argmax of group-max final membrane potential (integer compare)
    "zero":     label 0 (the degenerate but deterministic choice)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def encode_ttfs(images: jnp.ndarray, T: int, x_min: float = 1.0 / 255.0) -> jnp.ndarray:
    """images (..., N_in) float in [0,1] -> spike times (..., N_in) int32 in [0, T].

    T is the no-spike sentinel."""
    x = jnp.clip(images, 0.0, 1.0)
    t = jnp.floor((1.0 - x) * (T - 1)).astype(jnp.int32)
    return jnp.where(x >= x_min, t, jnp.int32(T))


def frames_from_times(times: jnp.ndarray, T: int) -> jnp.ndarray:
    """(..., N) int32 spike times -> (..., T, N) int8 spike raster (one spike max)."""
    steps = jnp.arange(T, dtype=jnp.int32)
    raster = times[..., None, :] == steps[:, None]
    return raster.astype(jnp.int8)


def group_map(n_groups: int, per_group: int) -> np.ndarray:
    """Neuron -> group id for contiguous grouping (paper: 10 groups x 15)."""
    return np.repeat(np.arange(n_groups, dtype=np.int32), per_group)


def grouped_first_spike(first_spike: jnp.ndarray, n_groups: int, per_group: int,
                        sentinel: int) -> jnp.ndarray:
    """(..., G*P) first-spike times -> (..., G) per-group earliest time."""
    shaped = first_spike.reshape(first_spike.shape[:-1] + (n_groups, per_group))
    del sentinel  # min over the group keeps the sentinel if none spiked
    return jnp.min(shaped, axis=-1)


def decode_labels(first_spike: jnp.ndarray, v_final: jnp.ndarray, *,
                  n_groups: int, per_group: int, sentinel: int,
                  fallback: str = "membrane") -> jnp.ndarray:
    """Grouped TTFS readout -> (...,) int32 labels.

    first_spike: (..., G*P) int32 times (sentinel = no spike)
    v_final:     (..., G*P) int32 final membrane potentials (fallback evidence)
    """
    gmin = grouped_first_spike(first_spike, n_groups, per_group, sentinel)
    ttfs_label = jnp.argmin(gmin, axis=-1).astype(jnp.int32)  # first-index tiebreak
    any_spike = jnp.min(gmin, axis=-1) < sentinel
    if fallback == "membrane":
        gv = v_final.reshape(v_final.shape[:-1] + (n_groups, per_group))
        fb_label = jnp.argmax(jnp.max(gv, axis=-1), axis=-1).astype(jnp.int32)
    elif fallback == "zero":
        fb_label = jnp.zeros_like(ttfs_label)
    else:
        raise ValueError(f"unknown fallback {fallback!r}")
    return jnp.where(any_spike, ttfs_label, fb_label)
