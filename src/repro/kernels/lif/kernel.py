"""Fused LIF kernel — membrane update + threshold compare + first-spike latch.

The FPGA evaluates one neuron group (128 neurons) per cycle against BRAM-held
state. The TPU-native tiling is the same co-design sweet spot: one 128-lane
neuron block per grid step, whole time window resident in VMEM, the T-loop
fused inside the kernel so membrane state never round-trips to HBM.

    grid  = (B, N_pad // bn)
    currents block (1, T, bn) int32   VMEM   (T*bn*4 B; T=32,bn=128 -> 16 KiB)
    thresholds     (bn,)       int32  VMEM
    out: first_spike (1, bn) int32, v_final (1, bn) int32

Integer semantics identical to core.lif_dynamics.lif_scan:
    v <- v - (v >> leak_shift) + I_t ; fire at v >= thr ; latch first time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_kernel(cur_ref, thr_ref, first_ref, v_ref, *, T: int, leak_shift: int):
    bn = thr_ref.shape[0]
    thr = thr_ref[...]

    def step(t, carry):
        v, first = carry
        i_t = cur_ref[0, t, :].astype(jnp.int32)
        v = v - jnp.right_shift(v, leak_shift) + i_t
        fired = (v >= thr) & (first == T)
        first = jnp.where(fired, t, first)
        return (v, first)

    v0 = jnp.zeros((bn,), jnp.int32)
    f0 = jnp.full((bn,), T, jnp.int32)
    v, first = jax.lax.fori_loop(0, T, step, (v0, f0))
    first_ref[0, :] = first
    v_ref[0, :] = v


def lif_fused_kernel(currents: jnp.ndarray, thresholds: jnp.ndarray,
                     leak_shift: int, *, block_n: int = 128,
                     interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """currents (B, T, N_pad) int32, thresholds (N_pad,) int32
    -> (first_spike (B, N_pad) int32, v_final (B, N_pad) int32)."""
    B, T, N = currents.shape
    assert N % block_n == 0, f"N_pad {N} must be a multiple of {block_n}"
    grid = (B, N // block_n)
    kernel = functools.partial(_lif_kernel, T=T, leak_shift=leak_shift)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, block_n), lambda b, n: (b, 0, n)),
            pl.BlockSpec((block_n,), lambda b, n: (n,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, n: (b, n)),
            pl.BlockSpec((1, block_n), lambda b, n: (b, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B, N), jnp.int32),
        ],
        interpret=interpret,
    )(currents, thresholds)
