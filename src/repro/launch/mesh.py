"""Production mesh construction and the lower-once program broadcast hook.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees its 512 placeholder devices).

    single-pod:  (16, 16)      axes ("data", "model")       = 256 chips
    multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

The "pod" axis is pure data parallelism across pods (gradient all-reduce
over DCI); "data" is in-pod data parallel / FSDP; "model" is tensor/expert
parallel over ICI.

``broadcast_program`` is the process-group companion to the per-process
``ProgramCache``: the leader lowers once and publishes the serialized
envelope, every follower deserializes it against its local artifact copy
(skipping ``_lower_uncached``) and can diff program fingerprints against the
leader's. Transport is pluggable — ``file_publisher``/``file_fetcher`` cover
the shared-filesystem launch topology ``launch/serve.py`` uses.
"""

from __future__ import annotations

import os
import time

import jax


def build_mesh(shape, axes):
    try:
        from jax.sharding import AxisType
    except ImportError:
        # jax < 0.5: make_mesh has no axis_types kwarg and axes default to
        # the same auto-sharding behavior AxisType.Auto selects
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return build_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) host devices)."""
    return build_mesh(shape, axes)


# ------------------------------------------------ program broadcast hook
class ProgramBroadcastError(RuntimeError):
    """A follower could not obtain the leader's envelope (transport failure,
    timeout, retries exhausted). Typed so launch supervisors can tell a
    distribution failure from a program-integrity failure
    (``ProgramIOError``) — the two demand different remediation (retry /
    re-elect leader vs. quarantine the envelope). Carries the transport's
    original exception as ``cause``."""

    def __init__(self, role: str, cause: Exception):
        super().__init__(f"{role}: program broadcast failed: "
                         f"{type(cause).__name__}: {cause}")
        self.role = role
        self.cause = cause


def broadcast_program(artifact, *, leader, publish=None, fetch=None):
    """Lower once per process group.

    Leader: lowers the artifact (through the active program cache) and, if
    ``publish`` is given, sends the serialized envelope to the group —
    exactly one publish per leader call, no matter how many followers fetch
    it (the transport serves the same envelope to every connection).
    Follower: peeks the local program cache first — a pre-warmed follower
    (program already resident for this artifact fingerprint) NEVER touches
    the network; otherwise ``fetch()``es the leader's envelope and
    deserializes it against the local artifact copy, never calling the
    lowering stage. Transport failures surface as a typed
    ``ProgramBroadcastError`` (bounded fetchers raise, they do not hang);
    integrity failures keep their ``ProgramIOError`` type. Both roles return
    the resident ``LoweredProgram``; fingerprint equality across the group
    is the cross-host determinism check conformance pins in-process.
    """
    from repro.core.lowering import get_cache, lower
    from repro.core.program_io import deserialize_program, serialize_program
    if leader:
        prog = lower(artifact)
        if publish is not None:
            publish(serialize_program(prog))
        return prog
    if fetch is None:
        raise ValueError("follower role requires a fetch callable "
                         "(the leader's published envelope)")
    resident = get_cache().peek(artifact.fingerprint())
    if resident is not None:
        return resident
    try:
        blob = fetch()
    except Exception as e:
        raise ProgramBroadcastError("follower", e) from e
    return deserialize_program(blob, artifact)


def file_publisher(path):
    """Publish an envelope to a shared-filesystem path, atomically: followers
    polling the path never observe a partial write."""
    def publish(blob: bytes) -> None:
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
    return publish


def file_fetcher(path, *, timeout_s: float = 30.0, poll_s: float = 0.05):
    """Fetch the leader's envelope from a shared-filesystem path, polling
    until the leader publishes or the timeout elapses."""
    def fetch() -> bytes:
        deadline = time.monotonic() + timeout_s
        while not os.path.exists(path):
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"no program envelope at {path!r} after {timeout_s}s — "
                    f"did the leader publish?")
            time.sleep(poll_s)
        with open(path, "rb") as f:
            return f.read()
    return fetch
