"""The single lowering stage: Artifact → LoweredProgram.

Covers the tentpole contracts the refactor introduced:

  * lowering is deterministic (two cache-bypassing lowers agree bit for bit)
    and the process cache returns one shared program object;
  * export → save → load → lower round-trips every execution scalar;
  * meta coercion is strict but not brittle (float-integral and digit-string
    values lower; junk, booleans and non-integral floats fail loudly with
    the offending meta path named);
  * the static-fault lowering pass corrupts a CLONE — the pristine artifact's
    bytes and cached program are untouched, the corrupted program gets its
    own fingerprint, and the checksum detector fires on the clone;
  * the compiled-bundle cache is shared across runtime instances (including
    via ``make_runtime``) and ``runtime.build`` spans record the hit;
  * source hygiene gates: no ``_``-private name is imported across modules
    inside ``src/repro``, and no runtime module reads ``artifact.m(...)``
    for execution parameters.
"""

import ast
import copy
import os
import re
import threading

import numpy as np
import pytest

from repro.core.artifact import Artifact
from repro.core.lowering import (LoweredProgram, LoweringError, PROGRAM_CACHE,
                                 ProgramCache, get_cache, install, lower,
                                 lower_with_faults, program_nbytes)

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _clone(art: Artifact) -> Artifact:
    return Artifact(copy.deepcopy(art.meta), dict(art.arrays))


# ------------------------------------------------------------ determinism
def test_lowering_deterministic_and_cached(trained_artifact):
    art, _, _ = trained_artifact
    a = lower(art, cache=False)
    b = lower(art, cache=False)
    assert a.fingerprint == b.fingerprint
    for f in ("T", "x_min", "e_max", "leak_shift", "n_in", "n_out",
              "n_groups", "per_group", "fallback", "scale", "n_pad", "lane"):
        assert getattr(a, f) == getattr(b, f), f
    cached1 = lower(art)
    cached2 = lower(art)
    assert cached1 is cached2          # one shared program object
    assert cached1.fingerprint == a.fingerprint
    # idempotent: lowering a program is the identity
    assert lower(cached1) is cached1


def test_lower_rejects_non_artifact():
    with pytest.raises(TypeError):
        lower({"not": "an artifact"})


# ------------------------------------------------------- export round-trip
def test_export_lower_roundtrip(trained_artifact):
    art, path, _ = trained_artifact
    reloaded = Artifact.load(path)
    prog = lower(reloaded, cache=False)
    assert isinstance(prog, LoweredProgram)
    assert prog.T == int(art.m("encode", "T"))
    assert prog.x_min == float(art.m("encode", "x_min"))
    assert prog.e_max == int(art.m("events", "e_max"))
    assert prog.leak_shift == int(art.m("lif", "leak_shift"))
    assert prog.n_groups * prog.per_group == prog.n_out
    assert prog.n_pad == art["thr_padded"].shape[0]
    assert prog.n_pad % prog.lane == 0
    assert prog.decode.sentinel == prog.T
    assert prog.encode.n_in == prog.n_in
    # device arrays mirror the host arrays bit for bit
    np.testing.assert_array_equal(np.asarray(prog.w_padded),
                                  reloaded["w_padded"])
    np.testing.assert_array_equal(np.asarray(prog.thr_padded),
                                  reloaded["thr_padded"])


# ----------------------------------------------------------- meta coercion
def test_meta_coercion_accepts_integral_forms(trained_artifact):
    art, _, _ = trained_artifact
    T = int(art.m("encode", "T"))
    for benign in (float(T), str(T)):
        c = _clone(art)
        c.meta["encode"]["T"] = benign
        prog = lower(c, cache=False)
        assert prog.T == T and type(prog.T) is int


@pytest.mark.parametrize("junk", ["abc", 64.5, True, None, [64]])
def test_meta_coercion_rejects_junk_T(trained_artifact, junk):
    art, _, _ = trained_artifact
    c = _clone(art)
    c.meta["encode"]["T"] = junk
    with pytest.raises(LoweringError, match=r"encode\.T"):
        lower(c, cache=False)


def test_meta_missing_path_is_named(trained_artifact):
    art, _, _ = trained_artifact
    c = _clone(art)
    del c.meta["events"]["e_max"]
    with pytest.raises(LoweringError, match=r"events\.e_max"):
        lower(c, cache=False)


def test_bad_readout_geometry_rejected(trained_artifact):
    art, _, _ = trained_artifact
    c = _clone(art)
    c.meta["readout"]["n_groups"] = int(c.meta["readout"]["n_groups"]) + 1
    with pytest.raises(LoweringError, match="geometry"):
        lower(c, cache=False)


def test_missing_array_rejected(trained_artifact):
    art, _, _ = trained_artifact
    c = Artifact(copy.deepcopy(art.meta),
                 {k: v for k, v in art.arrays.items() if k != "w_padded"})
    with pytest.raises(LoweringError, match="w_padded"):
        lower(c, cache=False)


# ---------------------------------------------------- fault lowering pass
def test_fault_pass_corrupts_a_clone_only(trained_artifact):
    from repro.faults.detect import integrity_errors
    from repro.faults.plan import FaultPlan
    art, _, _ = trained_artifact
    pristine_bytes = {k: v.tobytes() for k, v in art.arrays.items()}
    clean = lower(art)
    plan = FaultPlan(seed=11, seu_weight_flips=6, seu_threshold_flips=2)
    bad = lower_with_faults(art, plan)
    # pristine arrays are bit-identical — corruption went into the clone
    for k, v in art.arrays.items():
        assert v.tobytes() == pristine_bytes[k], k
    assert bad.artifact is not art
    assert bad.fingerprint != clean.fingerprint
    # the cached pristine program is still the clean one
    assert lower(art) is clean
    # the checksum detector fires on the clone, stays quiet on the original
    assert integrity_errors(bad.artifact)
    # deterministic: same plan, same artifact → same corrupted program
    assert lower_with_faults(art, plan).fingerprint == bad.fingerprint
    # a program input is unwrapped to its pristine backing artifact
    assert lower_with_faults(clean, plan).fingerprint == bad.fingerprint


# ------------------------------------------------------------ bundle cache
def test_bundle_shared_across_runtime_instances(trained_artifact):
    from repro.core.runtimes import make_runtime
    art, _, _ = trained_artifact
    a = make_runtime(art, "accelerator-event")
    b = make_runtime(art, "accelerator-event")
    # same jitted function object → jax reuses the compiled executable
    assert a._fwd_event is b._fwd_event
    assert b.cache_hit is True
    # a different config compiles its own bundle
    c = make_runtime(art, "accelerator-batch")
    assert getattr(c, "_fwd_batch", None) is not a._fwd_event


def test_runtime_build_span_meta_records_cache_hit(trained_artifact):
    from repro.core.runtimes import make_runtime
    from repro.telemetry.trace import Tracer, install
    art, _, _ = trained_artifact
    make_runtime(art, "board-batched")      # warm the bundle
    tr = Tracer()
    install(tr)
    try:
        make_runtime(art, "board-batched")
    finally:
        install(None)
    builds = [s for s in tr.spans if s.name == "runtime.build"]
    assert builds and builds[-1].meta.get("cache_hit") is True
    # cache_hit lives in META only — never in the canonical span form
    assert "cache_hit" not in builds[-1].canonical().get("attrs", {})


def test_distinct_artifacts_get_distinct_programs(trained_artifact):
    art, _, _ = trained_artifact
    c = _clone(art)
    c.meta["events"]["e_max"] = int(c.meta["events"]["e_max"]) + 1
    pa, pc = lower(art), lower(c)
    assert pa is not pc
    assert pa.fingerprint != pc.fingerprint
    assert pc.e_max == pa.e_max + 1


# ------------------------------------------------- cache poisoning (bugfix)
def test_host_arrays_cannot_poison_the_cached_program(trained_artifact):
    """Regression: ``host_arrays()`` used to hand out the live artifact dict;
    an in-place caller mutation silently corrupted every later cache hit
    without changing the fingerprint key."""
    art, _, _ = trained_artifact
    prog = lower(art)
    snapshot = {k: v.copy() for k, v in prog.artifact.arrays.items()}
    ha = prog.host_arrays()
    # in-place writes through the returned views must be refused...
    for name, arr in ha.items():
        with pytest.raises(ValueError):
            arr[(0,) * arr.ndim] = 1
    # ...and replacing dict entries must not reach the cached program
    ha["w_float"] = np.zeros_like(snapshot["w_float"])
    hit = lower(art)
    assert hit is prog
    for k, v in hit.artifact.arrays.items():
        assert v.tobytes() == snapshot[k].tobytes(), f"{k} was poisoned"


# -------------------------------------------------- racing miss accounting
def test_racing_program_lowers_count_one_miss(trained_artifact, monkeypatch):
    """Two threads racing ``program()`` on the same key: only the thread
    whose object was installed counts a miss (the loser's build is
    discarded), so misses == distinct builds kept."""
    import repro.core.lowering as lowering_mod
    art, _, _ = trained_artifact
    cache = ProgramCache()
    barrier = threading.Barrier(2, timeout=10)
    real = lowering_mod._lower_uncached

    def slow_lower(a):
        barrier.wait()   # both threads are past the lookup, mid-lower
        return real(a)

    monkeypatch.setattr(lowering_mod, "_lower_uncached", slow_lower)
    results: list = []

    def run():
        results.append(cache.program(art))

    threads = [threading.Thread(target=run) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    st = cache.stats()
    assert st["program_misses"] == 1
    assert st["program_hits"] == 1
    assert st["programs"] == 1
    assert results[0][0] is results[1][0]
    # exactly one thread saw a miss
    assert sorted(hit for _, hit in results) == [False, True]


def test_racing_bundle_builds_count_one_miss():
    cache = ProgramCache()
    barrier = threading.Barrier(2, timeout=10)

    def build():
        barrier.wait()
        return object()

    results: list = []
    threads = [threading.Thread(
        target=lambda: results.append(cache.bundle(("fam", "fp"), build)))
        for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    st = cache.stats()
    assert st["bundle_misses"] == 1
    assert st["bundle_hits"] == 1
    assert results[0][0] is results[1][0]


# ----------------------------------------------------- positivity (bugfix)
@pytest.mark.parametrize("section,key,pattern", [
    ("events", "e_max", r"events\.e_max"),
    ("readout", "per_group", r"readout\.per_group"),
    ("codesign", "lane", r"codesign\.lane"),
    ("quant", "scale", r"quant\.scale"),
])
@pytest.mark.parametrize("bad", [0, -1])
def test_non_positive_meta_rejected_at_lowering(trained_artifact, section,
                                                key, bad, pattern):
    """Regression: non-positive e_max/per_group/lane/scale used to survive
    lowering and fail later inside jitted code with shape/NaN errors."""
    art, _, _ = trained_artifact
    c = _clone(art)
    c.meta[section][key] = type(c.meta[section][key])(bad)
    with pytest.raises(LoweringError, match=pattern):
        lower(c, cache=False)


# ------------------------------------------------------- LRU byte budget
def _variants(art, n):
    """n distinct-fingerprint artifacts sharing the same arrays (equal
    program byte sizes — convenient for budget math)."""
    out = []
    for i in range(n):
        c = _clone(art)
        c.meta["events"]["e_max"] = int(c.meta["events"]["e_max"]) + 1 + i
        out.append(c)
    return out


def test_lru_byte_accounting_matches_sum_nbytes(trained_artifact):
    art, _, _ = trained_artifact
    cache = ProgramCache()
    prev = install(cache)
    try:
        progs = [lower(v) for v in _variants(art, 3)]
    finally:
        install(prev)
    assert cache.stats()["bytes"] == sum(program_nbytes(p) for p in progs)
    for p in progs:
        assert program_nbytes(p) == sum(
            int(getattr(p, n).nbytes)
            for n in ("w_float", "w_int8", "thresholds", "w_padded",
                      "thr_padded"))


def test_lru_evicts_cold_end_and_hits_refresh_recency(trained_artifact):
    art, _, _ = trained_artifact
    a, b, c = _variants(art, 3)
    per = program_nbytes(lower(art, cache=False))
    cache = ProgramCache(max_bytes=2 * per)   # room for 2 of 3
    prev = install(cache)
    try:
        prog_a = lower(a)
        lower(b)
        assert lower(a) is prog_a   # hit refreshes a's recency -> b is LRU
        lower(c)                    # evicts b, NOT a
        st = cache.stats()
        assert st["evictions"] == 1
        assert st["programs"] == 2
        assert st["bytes"] == 2 * per
        misses = st["program_misses"]
        assert lower(a) is prog_a               # still resident
        assert cache.stats()["program_misses"] == misses
        lower(b)                                # evicted: fresh miss
        assert cache.stats()["program_misses"] == misses + 1
    finally:
        install(prev)


def test_bundles_die_with_their_program(trained_artifact):
    art, _, _ = trained_artifact
    a, b = _variants(art, 2)
    per = program_nbytes(lower(art, cache=False))
    cache = ProgramCache(max_bytes=per)       # room for exactly 1
    prev = install(cache)
    try:
        prog_a = lower(a)
        sentinel = object()
        cache.bundle(("fam", prog_a.fingerprint, "cfg"), lambda: sentinel)
        keep = object()
        cache.bundle(("fam", "unrelated-fp", "cfg"), lambda: keep)
        assert cache.stats()["bundles"] == 2
        lower(b)                              # evicts prog_a + its bundle
        st = cache.stats()
        assert st["evictions"] == 1
        assert st["bundles"] == 1
        # the survivor is the unrelated bundle; prog_a's must rebuild
        got, hit = cache.bundle(("fam", "unrelated-fp", "cfg"),
                                lambda: object())
        assert got is keep and hit is True
        rebuilt, hit = cache.bundle(("fam", prog_a.fingerprint, "cfg"),
                                    lambda: object())
        assert rebuilt is not sentinel and hit is False
    finally:
        install(prev)


def test_orphan_bundle_bytes_enter_the_budget_once(trained_artifact):
    """Regression: bundles built over cache-bypassing ``lower(cache=False)``
    programs used to pin device arrays entirely OUTSIDE the LRU byte budget.
    They are now charged as orphans — once per distinct program fingerprint
    no matter how many bundles share it — and the charge merges (no double
    count) if the program is later properly installed."""
    art, _, _ = trained_artifact
    (a,) = _variants(art, 1)
    orphan_prog = lower(a, cache=False)       # never installed -> orphan
    per = program_nbytes(orphan_prog)
    cache = ProgramCache(max_bytes=4 * per)
    prev = install(cache)
    try:
        cache.bundle(("fam", orphan_prog.fingerprint, "x"),
                     lambda: object(), nbytes=per)
        cache.bundle(("fam", orphan_prog.fingerprint, "y"),
                     lambda: object(), nbytes=per)
        st = cache.stats()
        assert st["orphan_programs"] == 1, "one charge per fingerprint"
        assert st["orphan_bundle_bytes"] == per
        assert st["bytes"] == per

        resident = lower(a)                   # same fingerprint installs
        assert resident.fingerprint == orphan_prog.fingerprint
        st = cache.stats()
        assert st["orphan_programs"] == 0, "orphan merged into resident"
        assert st["orphan_bundle_bytes"] == 0
        assert st["bytes"] == per, "merge must not double-charge"
        assert st["programs"] == 1
    finally:
        install(prev)


def test_orphans_evict_before_programs_and_take_their_bundles(
        trained_artifact):
    art, _, _ = trained_artifact
    a, b, c = _variants(art, 3)
    orphan_prog = lower(a, cache=False)
    per = program_nbytes(orphan_prog)
    cache = ProgramCache(max_bytes=2 * per)   # resident + orphan fill it
    prev = install(cache)
    try:
        resident = lower(b)
        sentinel = object()
        cache.bundle(("fam", orphan_prog.fingerprint, "cfg"),
                     lambda: sentinel, nbytes=per)
        assert cache.stats()["bytes"] == 2 * per
        lower(c)                              # past budget: orphan dies first
        st = cache.stats()
        assert st["orphan_programs"] == 0
        assert st["orphan_bundle_bytes"] == 0
        assert st["evictions"] == 1
        assert st["programs"] == 2, "both real programs survive the orphan"
        assert st["bytes"] == 2 * per
        misses = st["program_misses"]
        assert lower(b) is resident           # b was never the victim
        assert cache.stats()["program_misses"] == misses
        # the orphan's bundle died with its charge: fresh build required
        rebuilt, hit = cache.bundle(("fam", orphan_prog.fingerprint, "cfg"),
                                    lambda: object(), nbytes=per)
        assert rebuilt is not sentinel and hit is False
    finally:
        install(prev)


def test_cache_stats_and_prometheus_surface_lru_fields(trained_artifact):
    from repro.telemetry.export import program_cache_text
    art, _, _ = trained_artifact
    a, b = _variants(art, 2)
    per = program_nbytes(lower(art, cache=False))
    cache = ProgramCache(max_bytes=per)
    prev = install(cache)
    try:
        lower(a)
        lower(b)
    finally:
        install(prev)
    st = cache.stats()
    assert st["evictions"] == 1
    assert st["bytes"] == per
    assert st["max_bytes"] == per
    text = program_cache_text(cache)
    assert "repro_program_cache_evictions 1" in text
    assert f"repro_program_cache_bytes {per}" in text
    assert f"repro_program_cache_max_bytes {per}" in text
    assert "# TYPE repro_program_cache_evictions counter" in text
    assert "# TYPE repro_program_cache_bytes gauge" in text


def test_install_scopes_cache_churn_away_from_the_singleton(trained_artifact):
    art, _, _ = trained_artifact
    resident = lower(art)                     # lives in the default cache
    scoped = ProgramCache()
    prev = install(scoped)
    try:
        assert get_cache() is scoped
        inside = lower(art)
        assert inside is not resident         # scoped cache lowered its own
        scoped.clear()                        # churn: invisible outside
    finally:
        install(prev)
    assert get_cache() is PROGRAM_CACHE
    assert lower(art) is resident             # singleton entry untouched
    # runtime.build span meta projects the ACTIVE cache's byte/eviction state
    from repro.core.runtimes import make_runtime
    from repro.telemetry.trace import Tracer
    from repro.telemetry.trace import install as trace_install
    tr = Tracer()
    trace_install(tr)
    try:
        make_runtime(art, "reference")
    finally:
        trace_install(None)
    builds = [s for s in tr.spans if s.name == "runtime.build"]
    assert builds
    assert builds[-1].meta.get("cache_bytes") == PROGRAM_CACHE.stats()["bytes"]
    assert "cache_evictions" in builds[-1].meta


# -------------------------------------------------------- hygiene: imports
def _py_files():
    for root, _, files in os.walk(SRC):
        for f in files:
            if f.endswith(".py"):
                yield os.path.join(root, f)


def test_no_private_cross_module_imports():
    """No module inside src/repro imports a ``_``-prefixed (private) name
    from another repro module — shared names must be public API."""
    bad = []
    for path in _py_files():
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.ImportFrom) or node.module is None:
                continue
            if not node.module.startswith("repro"):
                continue
            for alias in node.names:
                if alias.name.startswith("_"):
                    bad.append(f"{os.path.relpath(path, SRC)}:{node.lineno} "
                               f"imports {alias.name} from {node.module}")
    assert not bad, "private cross-module imports:\n" + "\n".join(bad)


#: every module that EXECUTES against an artifact — these must read their
#: execution parameters from the lowered program, never ``artifact.m(...)``
#: (export/serialization modules like deploy.py and artifact.py are exempt:
#: they PRODUCE the meta the lowering stage consumes)
RUNTIME_MODULES = (
    "core/reference.py", "core/accelerator.py", "core/runtimes.py",
    "board/runtime.py", "board/batched.py", "board/neuron_core.py",
    "serving/scheduler.py", "faults/detect.py",
)


def test_runtime_modules_do_not_read_artifact_meta():
    pat = re.compile(r"\.m\(")
    bad = []
    for rel in RUNTIME_MODULES:
        path = os.path.join(SRC, rel)
        with open(path) as f:
            for i, line in enumerate(f, 1):
                if pat.search(line):
                    bad.append(f"{rel}:{i}: {line.strip()}")
    assert not bad, ("runtime modules must consume LoweredProgram, not "
                     "artifact.m(...):\n" + "\n".join(bad))
