"""Whisper-tiny [arXiv:2212.04356]: enc-dec, 4+4L, d384, 6H MHA, d_ff 1536,
vocab 51865, LayerNorm+GELU, no RoPE (sinusoidal enc / learned-ish dec).
Conv frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, seq, 384) per the assignment. d_head = 384/6 = 64."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, vocab=51865,
    n_heads=6, n_kv_heads=6, d_head=64,
    d_ff=1536, enc_layers=4, cross_len=1500, dec_max_len=448,
    norm="layernorm", act="gelu", rope_theta=0.0,
    frontend="audio",
)
