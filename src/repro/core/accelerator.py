"""Accelerator runtime — the TPU-native "board" path.

Consumes the SAME deployment artifact as the software reference (no
conversion stage) and executes the padded block layout the planner emitted:

  * ``mode="batch"``  — time-batched execution: the (T, N_in) spike raster is
    a 0/1 int8 matrix fed to the MXU as one matmul, then the fused LIF scan
    runs over the (T, N_pad) currents. This is the TPU-native re-thinking of
    the FPGA's event pipeline: instead of serializing events through a router
    (which a systolic machine cannot do efficiently), we batch a whole time
    window into one hardware-shaped matrix product. Throughput-oriented.

  * ``mode="event"`` — event-frame execution: packed (T, E_max) event-id
    buffers drive per-step gathers of weight rows (HBM->VMEM in the kernel),
    accumulated into the membrane block. Work scales with ACTIVE events, the
    paper's event-driven property, and an early-exit loop stops at the first
    output spike (the TTFS decision point) for latency mode.

  * ``kernel="jnp" | "pallas" | "fused"`` — the jnp path mirrors the kernel's
    block structure op-for-op (and is fast on this CPU-only container); the
    pallas path calls the actual TPU kernels (interpret mode on CPU); the
    fused path runs the event→LIF→decode megakernel (event mode only): one
    pass, state resident on-chip, the (T, N_pad) currents tensor never
    materialized. All are bit-exact against the reference; tests assert they
    agree.

Execution parameters come from the lowered program (``core.lowering``); the
jitted callables live in the process-wide program cache keyed by
(program fingerprint, mode, kernel), so every serving lane over the same
artifact shares one compiled pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ttfs
from repro.core.artifact import Artifact
from repro.core.events import EventFrames, PAD, pack_events_batched
from repro.core.lif_dynamics import lif_scan, lif_scan_early_exit
from repro.core.lowering import (LoweredProgram, get_cache, lower,
                                 program_nbytes)
from repro.core.types import SNNOutput, decode_output
from repro.telemetry import trace as ttrace


def _build_bundle(prog: LoweredProgram, mode: str, kernel: str) -> dict:
    """Jitted pipelines for one (program, mode, kernel) config. Module-level
    closures over program fields — never methods — so two runtime instances
    with the same config share the compiled executables."""
    T, x_min, leak_shift = prog.T, prog.x_min, prog.leak_shift
    n_out = prog.n_out
    w_padded, thr_padded = prog.w_padded, prog.thr_padded
    plan = prog.decode

    # ------------------------------------------------------------ batch mode
    def currents_batch(raster: jnp.ndarray) -> jnp.ndarray:
        """(B, T, N_in) int8 raster -> (T, B, N_pad) int32 currents."""
        if kernel == "pallas":
            from repro.kernels.spike_matmul import ops as smm
            cur = smm.spike_matmul(raster, w_padded)           # (B, T, N_pad)
        else:
            cur = jax.lax.dot_general(raster, w_padded,
                                      (((2,), (0,)), ((), ())),
                                      preferred_element_type=jnp.int32)
        return jnp.moveaxis(cur, 1, 0)

    def lif(currents: jnp.ndarray):
        """(T, ..., N_pad) -> LIFResult via fused kernel or its jnp mirror."""
        if kernel == "pallas":
            from repro.kernels.lif import ops as lif_ops
            return lif_ops.lif_fused(currents, thr_padded, leak_shift)
        return lif_scan(currents, thr_padded, leak_shift, T)

    def decode_padded(first, v_final):
        first_l, v_l = first[..., :n_out], v_final[..., :n_out]
        if kernel == "pallas":
            from repro.kernels.ttfs_decode import ops as dec_ops
            labels = dec_ops.ttfs_decode(
                first_l, v_l,
                n_groups=plan.n_groups, per_group=plan.per_group,
                sentinel=plan.sentinel, fallback=plan.fallback)
        else:
            labels = decode_output(first_l, v_l, plan)
        return labels, first_l, v_l

    def forward_batch(images: jnp.ndarray) -> SNNOutput:
        times = ttfs.encode_ttfs(images, T, x_min)
        raster = ttfs.frames_from_times(times, T)
        currents = currents_batch(raster)
        res = lif(currents)
        labels, first_l, v_l = decode_padded(res.first_spike, res.v_final)
        steps = jnp.full(labels.shape, T, jnp.int32)
        return SNNOutput(labels, first_l, v_l, steps)

    # ------------------------------------------------------------ event mode
    def event_currents(ids: jnp.ndarray) -> jnp.ndarray:
        """(T, E_max) event ids -> (T, N_pad) int32 currents via row gather."""
        if kernel == "pallas":
            from repro.kernels.event_accum import ops as ea
            return ea.event_accum(ids, w_padded)
        safe = jnp.maximum(ids, 0)
        rows = w_padded[safe].astype(jnp.int32)                 # (T, E, N_pad)
        mask = (ids != PAD)[..., None]
        return jnp.sum(jnp.where(mask, rows, 0), axis=1)

    def forward_event(ids: jnp.ndarray, count: jnp.ndarray) -> SNNOutput:
        """ids: (B, T, E_max), count: (B, T).
        Full-T evaluation (throughput/accuracy mode)."""
        if kernel == "fused":
            from repro.kernels.fused_event_lif import ops as fused
            res, labels = fused.fused_event_lif_decode(
                ids, count, w_padded, thr_padded, leak_shift,
                n_out=n_out, n_groups=plan.n_groups,
                per_group=plan.per_group, fallback=plan.fallback)
            first_l = res.first_spike[..., :n_out]
            v_l = res.v_final[..., :n_out]
            steps = jnp.full(labels.shape, T, jnp.int32)
            return SNNOutput(labels, first_l, v_l, steps)
        currents = jax.vmap(event_currents)(ids)                # (B, T, N_pad)
        res = lif(jnp.moveaxis(currents, 1, 0))
        labels, first_l, v_l = decode_padded(res.first_spike, res.v_final)
        steps = jnp.full(labels.shape, T, jnp.int32)
        return SNNOutput(labels, first_l, v_l, steps)

    def forward_event_one_early_exit(ids: jnp.ndarray) -> SNNOutput:
        """ids: (T, E_max), single example, stop at first output spike."""
        currents = event_currents(ids)                          # (T, N_pad)
        res, steps = lif_scan_early_exit(currents, thr_padded, leak_shift, T)
        labels, first_l, v_l = decode_padded(res.first_spike, res.v_final)
        return SNNOutput(labels, first_l, v_l, steps)

    def forward_event_latency(ids: jnp.ndarray,
                              count: jnp.ndarray) -> SNNOutput:
        """(B, T, E_max) frames, stop each row at its first output spike."""
        if kernel == "fused":
            from repro.kernels.fused_event_lif import ops as fused
            res, steps = fused.fused_event_lif_early_exit(
                ids, count, w_padded, thr_padded, leak_shift)
            labels, first_l, v_l = decode_padded(res.first_spike, res.v_final)
            return SNNOutput(labels, first_l, v_l, steps)
        return jax.vmap(forward_event_one_early_exit)(ids)

    if mode == "batch":
        return {"batch": jax.jit(forward_batch)}
    return {"event": jax.jit(forward_event),
            "event_latency": jax.jit(forward_event_latency)}


class SNNAccelerator:
    def __init__(self, artifact: Artifact | LoweredProgram,
                 mode: str = "batch", kernel: str = "jnp"):
        if mode not in ("batch", "event"):
            raise ValueError(mode)
        if kernel not in ("jnp", "pallas", "fused"):
            raise ValueError(kernel)
        if kernel == "fused" and mode != "event":
            raise ValueError(
                "the fused megakernel consumes packed event frames; "
                "use mode='event' (batch mode has its own matmul pipeline)")
        prog = lower(artifact)
        self.program = prog
        self.art = prog.artifact
        self.mode, self.kernel = mode, kernel
        self.T = prog.T
        self.x_min = prog.x_min
        self.leak_shift = prog.leak_shift
        self.e_max = prog.e_max
        self.n_out = prog.n_out
        self.w_padded = prog.w_padded          # (N_in, N_pad) int8
        self.thr_padded = prog.thr_padded      # (N_pad,) int32
        bundle, self.cache_hit = get_cache().bundle(
            ("accelerator", prog.fingerprint, mode, kernel),
            lambda: _build_bundle(prog, mode, kernel),
            nbytes=program_nbytes(prog))
        if mode == "batch":
            self._fwd_batch = bundle["batch"]
        else:
            self._fwd_event = bundle["event"]
            self._fwd_event_latency = bundle["event_latency"]

    # -------------------------------------------------------------- frontend
    def forward(self, images=None, frames: EventFrames | None = None,
                latency_mode: bool = False,
                check_overflow: bool = True) -> SNNOutput:
        """``check_overflow=False`` skips the host-side overflow flag read for
        callers (the serving engine) that already validated the frames at pack
        time — the ``np.asarray(frames.overflow)`` read forces a device
        round-trip per call on pre-packed device-resident frames."""
        # telemetry spans (accel.forward -> [pack] / kernel) are no-ops on
        # the shared NullRecorder — nothing below allocates when disabled
        rec = ttrace.get()
        fwd = None
        if rec.enabled:
            B = (int(frames.ids.shape[0]) if frames is not None
                 else int(np.atleast_2d(np.asarray(images)).shape[0]))
            fwd = rec.begin("accel.forward", "system",
                            attrs={"mode": self.mode, "batch": B,
                                   "T": self.T,
                                   "latency": bool(latency_mode)},
                            meta={"kernel": self.kernel})
        try:
            if self.mode == "batch":
                assert images is not None, "batch mode consumes dense images"
                kr = rec.begin("accel.kernel", "accel", trace=fwd.trace,
                               parent=fwd.sid) if fwd is not None else None
                out = self._fwd_batch(jnp.asarray(images, jnp.float32))
                rec.end(kr)
                return out
            if frames is None:
                pk = rec.begin("accel.pack", "system", trace=fwd.trace,
                               parent=fwd.sid,
                               attrs={"e_max": self.e_max}) \
                    if fwd is not None else None
                times = np.asarray(ttfs.encode_ttfs(
                    jnp.asarray(images, jnp.float32), self.T, self.x_min))
                frames = pack_events_batched(times, self.T, self.e_max)
                rec.end(pk)
            if check_overflow and bool(np.any(np.asarray(frames.overflow))):
                raise OverflowError(
                    "event frames exceed artifact E_max; re-export with "
                    "larger headroom or use the dense batch path")
            kr = rec.begin("accel.kernel", "accel", trace=fwd.trace,
                           parent=fwd.sid) if fwd is not None else None
            if latency_mode:
                out = self._fwd_event_latency(frames.ids, frames.count)
            else:
                out = self._fwd_event(frames.ids, frames.count)
            rec.end(kr)
            return out
        finally:
            rec.end(fwd)

    __call__ = forward
