"""The differential oracle stack — every runtime, one fuzzed artifact.

``run_case`` takes a ``FuzzedCase`` and runs EVERY advertised runtime spec on
the same artifact and adversarial image batch, asserting:

  registry      — ``runtimes.registry_consistency_errors`` is empty: what the
                  registry advertises constructs, and what constructs is
                  advertised (both directions);
  lowering      — the single lowering stage (``core.lowering``) is
                  deterministic: two cache-bypassing lowerings agree on the
                  program fingerprint and every scalar, the process cache
                  returns the same program, and every advertised runtime's
                  ``.program`` carries that one fingerprint;
  program-io    — ``deserialize_program(serialize_program(p), artifact)`` is
                  fingerprint-identical and array-bit-identical to a fresh
                  lower (the cross-host broadcast path reconstructs the
                  leader's exact program from envelope + local artifact);
  differential  — labels, first-spike times, final membranes AND step counts
                  are bit-exact against the software reference for every spec
                  (alias specs must construct an identical runtime config and
                  are credited without a redundant run);
  sched-batched — the per-image Python board scheduler and the vectorized
                  batched fast path agree on outputs AND full cycle/energy
                  traces, in both full-T and latency mode;
  fifo          — the AER ingress never drops: per-tick queue counts sum to
                  the number of valid input spikes, and the batched trace
                  dispatched exactly that many events per image;
  cost-model    — the board trace equals an independent re-evaluation of
                  ``hw.BoardCostModel`` via ``board.energy.account`` from the
                  AER queue's own counts (cycles, energy, synops, stalls);
  quant         — ``dequantize(quantize(w))`` honors the round-to-nearest
                  error bound scale/2 on the artifact's actual weights;
  events        — the packed frames respect the artifact's calibrated E_max
                  (no overflow flag on a stream the exporter sized for);
  fault-recovery— the serving tier survives one seeded recoverable lane
                  crash: every request completes with a reference-bit-exact
                  label and the detection/requeue/restart counters agree;
  telemetry     — the telemetry tier itself is deterministic and honest:
                  two seeded board runs produce bit-identical canonical span
                  trees, the per-image python scheduler and the batched fast
                  path produce the SAME canonical tree, every span carries a
                  legal ``accel|system`` scope, and the span tree's cycle
                  totals reconcile exactly with an independent re-evaluation
                  of the ``BoardCostModel`` account.

Each oracle yields an ``OracleOutcome``; a ``ConformanceReport`` aggregates
them and renders a failure summary naming spec, oracle, and mismatch counts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.board import SNNBoard
from repro.board.energy import account
from repro.board.event_queue import AEREventQueue
from repro.conformance.fuzz import FuzzedCase
from repro.core import quant
from repro.core.events import pack_events_batched
from repro.core.runtimes import (ADVERTISED_SPECS, make_runtime,
                                 registry_consistency_errors)


@dataclasses.dataclass
class OracleOutcome:
    oracle: str
    spec: str
    passed: bool
    detail: str = ""
    stats: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ConformanceReport:
    seed: int
    notes: dict
    outcomes: list[OracleOutcome]

    @property
    def passed(self) -> bool:
        return all(o.passed for o in self.outcomes)

    def failures(self) -> list[OracleOutcome]:
        return [o for o in self.outcomes if not o.passed]

    def summary(self) -> str:
        fails = self.failures()
        head = (f"conformance case seed={self.seed} "
                f"(n_in={self.notes.get('n_in')} n_out={self.notes.get('n_out')} "
                f"T={self.notes.get('T')} leak={self.notes.get('leak_shift')} "
                f"weights={self.notes.get('weight_family')}): "
                f"{len(self.outcomes) - len(fails)}/{len(self.outcomes)} "
                f"oracles passed")
        lines = [head] + [f"  FAIL [{o.oracle}] {o.spec}: {o.detail}"
                          for o in fails]
        return "\n".join(lines)


def _np(x) -> np.ndarray:
    return np.asarray(x)


def _runtime_key(rt) -> tuple:
    """Config identity of a constructed runtime: two specs mapping to the
    same key are aliases and must behave identically by construction."""
    return (type(rt).__name__, getattr(rt, "mode", None),
            getattr(rt, "kernel", None), getattr(rt, "latency_mode", None))


def _diff_outputs(out, ref, fields=("labels", "first_spike", "v_final",
                                    "steps")) -> tuple[dict, str]:
    """Per-image mismatch counts between two SNNOutput-likes."""
    stats, parts = {}, []
    for f in fields:
        a, b = _np(getattr(out, f)), _np(getattr(ref, f))
        if a.shape != b.shape:
            # a wrong shape means every image is wrong — count it that way
            # so aggregated mismatch metrics cannot read as bit-exact
            stats[f] = int(b.shape[0]) if b.ndim else 1
            parts.append(f"{f} shape {a.shape} vs {b.shape}")
            continue
        per_img = (a != b) if a.ndim == 1 else np.any(
            a.reshape(a.shape[0], -1) != b.reshape(b.shape[0], -1), axis=1)
        n = int(np.sum(per_img))
        stats[f] = n
        if n:
            parts.append(f"{f} mismatches on {n} images")
    return stats, "; ".join(parts)


def run_case(case: FuzzedCase, specs=ADVERTISED_SPECS,
             py_slice: int = 5) -> ConformanceReport:
    """Run the full oracle stack for one fuzzed case. ``py_slice`` bounds the
    per-image Python scheduler's batch (it is deliberately slow); the fuzzer
    orders the named adversarial patterns (flood/never/ties/ramp/burst)
    first, so the default slice covers all of them."""
    art, images, times = case.artifact, case.images, case.times
    T = int(art.m("encode", "T"))
    e_max = int(art.m("events", "e_max"))
    n_pad = int(art.m("codesign", "n_pad"))
    B = images.shape[0]
    py_slice = min(py_slice, B)
    outcomes: list[OracleOutcome] = []

    # ---- registry: advertised <-> constructible, both directions ---------
    errs = registry_consistency_errors(art)
    outcomes.append(OracleOutcome("registry", "*", not errs, "; ".join(errs)))

    # ---- lowering: deterministic, and every runtime consumes ONE program -
    outcomes.append(_lowering_oracle(art, specs))

    # ---- program-io: the serialized envelope reconstructs bit-identically
    outcomes.append(_program_io_oracle(art))

    # ---- transport: detected-or-bit-exact under packet-level faults ------
    outcomes.append(_transport_oracle(art, case.seed))

    # ---- differential: every advertised spec vs the reference ------------
    ref_rt = make_runtime(art, "reference")
    out_ref = ref_rt.forward(images)
    ran: dict[tuple, str] = {_runtime_key(ref_rt): "reference"}
    board_batched = None
    for spec in specs:
        if spec == "reference":
            continue
        rt = make_runtime(art, spec)
        key = _runtime_key(rt)
        if key in ran:
            outcomes.append(OracleOutcome(
                "differential", spec, True,
                f"alias of {ran[key]!r} (identical runtime config)"))
            continue
        ran[key] = spec
        if isinstance(rt, SNNBoard):   # per-image python scheduler: slice
            out = rt.forward(images[:py_slice])
            ref_cmp = type(out_ref)(*(_np(f)[:py_slice] for f in out_ref))
            n_img = py_slice
        else:
            out = rt.forward(images)
            ref_cmp = out_ref
            n_img = B
        stats, detail = _diff_outputs(out, ref_cmp)
        stats["img"] = n_img
        outcomes.append(OracleOutcome("differential", spec,
                                      not detail, detail, stats))
        if key == ("SNNBoardBatched", None, "jnp", False):
            board_batched = rt

    # ---- scheduler <-> batched: outputs AND traces, both modes -----------
    for latency in (False, True):
        mode = "latency" if latency else "full"
        py = make_runtime(art, "board-py", latency_mode=latency)
        bt = make_runtime(art, "board", latency_mode=latency)
        out_py = py.forward(images[:py_slice])
        out_bt = bt.forward(images[:py_slice])
        stats, detail = _diff_outputs(out_bt, out_py)
        parts = [detail] if detail else []
        for f in dataclasses.fields(py.last_trace):
            a = _np(getattr(py.last_trace, f.name))
            b = _np(getattr(bt.last_trace, f.name))
            if not np.array_equal(a, b):
                parts.append(f"trace.{f.name} differs "
                             f"(py {a.tolist()} vs batched {b.tolist()})")
        outcomes.append(OracleOutcome(f"sched-batched-{mode}", "board",
                                      not parts, "; ".join(parts), stats))

    # ---- FIFO never-drops + cost-model consistency -----------------------
    totals = np.zeros(B, np.int64)
    stalls = np.zeros(B, np.int64)
    fifo_errs = []
    for b in range(B):
        q = AEREventQueue(times[b], T, e_max)
        per_tick = q.counts()
        valid = int(np.sum(times[b] < T))
        if int(per_tick.sum()) != valid or q.total_events != valid:
            fifo_errs.append(f"image {b}: queue schedules "
                             f"{int(per_tick.sum())}/{q.total_events} of "
                             f"{valid} valid events")
        totals[b] = valid
        stalls[b] = int(sum(q.stalls_at(t) for t in range(T)))
    if board_batched is None:
        # not among the requested specs: run it here; otherwise the
        # differential loop's full-batch forward already left last_trace
        board_batched = make_runtime(art, "board")
        board_batched.forward(images)
    tr = board_batched.last_trace
    if not np.array_equal(_np(tr.events), totals):
        fifo_errs.append(f"batched trace dispatched {_np(tr.events).tolist()} "
                         f"events but the AER schedule holds {totals.tolist()}"
                         " — events were dropped or double-counted")
    outcomes.append(OracleOutcome("fifo", "board", not fifo_errs,
                                  "; ".join(fifo_errs)))

    expected = account(totals, np.full(B, T, np.int64), stalls, n_pad,
                       board_batched.cost)
    cost_errs = []
    for f in dataclasses.fields(expected):
        a, b = _np(getattr(expected, f.name)), _np(getattr(tr, f.name))
        if not np.array_equal(a, b):
            cost_errs.append(f"{f.name}: expected {a.tolist()}, "
                             f"trace has {b.tolist()}")
    outcomes.append(OracleOutcome("cost-model", "board", not cost_errs,
                                  "; ".join(cost_errs)))

    # ---- quantization roundtrip bound ------------------------------------
    scale = float(art.m("quant", "scale"))
    w_f32, w_int8 = _np(art["w_float"]), _np(art["w_int8"])
    err = float(np.max(np.abs(quant.dequantize(w_int8, scale) - w_f32))) \
        if w_f32.size else 0.0
    bound = scale / 2 + 1e-6
    q_errs = []
    if not scale > 0:
        q_errs.append(f"non-positive scale {scale}")
    if err > bound:
        q_errs.append(f"roundtrip error {err:.3e} exceeds scale/2 bound "
                      f"{bound:.3e}")
    if int(np.max(np.abs(w_int8.astype(np.int32)))) > quant.INT8_MAX:
        q_errs.append("int8 weights exceed symmetric range")
    outcomes.append(OracleOutcome("quant", "*", not q_errs, "; ".join(q_errs),
                                  {"roundtrip_err": err, "bound": bound}))

    # ---- packed events respect the calibrated E_max ----------------------
    frames = pack_events_batched(times, T, e_max)
    n_over = int(np.sum(_np(frames.overflow)))
    peak = int(np.max(_np(frames.count))) if T else 0
    outcomes.append(OracleOutcome(
        "events", "*", n_over == 0,
        f"{n_over} images overflow the calibrated E_max={e_max}" if n_over
        else "",
        {"e_max": e_max, "peak_count": peak,
         "boundary_hit": int(peak == e_max)}))

    # ---- fault recovery: serve through one seeded recoverable fault ------
    outcomes.append(_fault_recovery_oracle(case, out_ref))

    # ---- telemetry: deterministic spans that reconcile with the account --
    outcomes.append(_telemetry_oracle(case, py_slice))

    return ConformanceReport(seed=case.seed, notes=case.notes,
                             outcomes=outcomes)


def _lowering_oracle(art, specs) -> OracleOutcome:
    """Lowering conformance: the single lowering stage is deterministic and
    really is single. Two independent (cache-bypassing) lowerings of the
    same artifact must agree on the program fingerprint and every scalar;
    the cached path must return that same program; and every advertised
    runtime must carry a ``program`` whose fingerprint matches — i.e. no
    runtime lowered its own divergent view of the artifact."""
    from repro.core.lowering import lower

    errs: list[str] = []
    a = lower(art, cache=False)
    b = lower(art, cache=False)
    if a.fingerprint != b.fingerprint:
        errs.append(f"lowering is nondeterministic: {a.fingerprint[:12]} != "
                    f"{b.fingerprint[:12]}")
    scalars = ("T", "x_min", "e_max", "leak_shift", "n_in", "n_out",
               "n_groups", "per_group", "fallback", "scale", "n_pad", "lane")
    for f in scalars:
        if getattr(a, f) != getattr(b, f):
            errs.append(f"lowered scalar {f} differs across runs: "
                        f"{getattr(a, f)!r} vs {getattr(b, f)!r}")
    cached = lower(art)
    if cached.fingerprint != a.fingerprint:
        errs.append("cached lowering disagrees with a fresh lowering")
    for spec in specs:
        try:
            rt = make_runtime(art, spec)
        except Exception:
            continue  # construction failures are the registry oracle's find
        prog = getattr(rt, "program", None)
        if prog is None:
            errs.append(f"runtime {spec!r} exposes no lowered program")
        elif prog.fingerprint != a.fingerprint:
            errs.append(f"runtime {spec!r} lowered a divergent program "
                        f"({prog.fingerprint[:12]} != {a.fingerprint[:12]})")
    return OracleOutcome("lowering", "*", not errs, "; ".join(errs),
                         {"fingerprint": a.fingerprint[:16]})


def _program_io_oracle(art) -> OracleOutcome:
    """Program-io conformance: the broadcast envelope is a faithful carrier.
    A deserialized program must be indistinguishable from a fresh lower —
    same fingerprint, same scalars, same plans, bit-identical device arrays —
    and a truncated/tampered envelope must be rejected, never half-applied."""
    from repro.core.lowering import REQUIRED_ARRAYS, lower
    from repro.core.program_io import (ProgramIOError, deserialize_program,
                                       serialize_program)

    errs: list[str] = []
    fresh = lower(art, cache=False)
    blob = serialize_program(fresh)
    rt = deserialize_program(blob, art, cache=False)
    if rt.fingerprint != fresh.fingerprint:
        errs.append(f"roundtrip fingerprint {rt.fingerprint[:12]} != fresh "
                    f"lower's {fresh.fingerprint[:12]}")
    scalars = ("T", "x_min", "e_max", "leak_shift", "n_in", "n_out",
               "n_groups", "per_group", "fallback", "scale", "n_pad", "lane")
    for f in scalars:
        if getattr(rt, f) != getattr(fresh, f):
            errs.append(f"roundtrip scalar {f}: {getattr(rt, f)!r} != "
                        f"{getattr(fresh, f)!r}")
    if rt.encode != fresh.encode or rt.decode != fresh.decode:
        errs.append("roundtrip encode/decode plans differ")
    for name in REQUIRED_ARRAYS:
        a, b = _np(getattr(rt, name)), _np(getattr(fresh, name))
        if not (a.shape == b.shape and a.dtype == b.dtype
                and np.array_equal(a, b)):
            errs.append(f"roundtrip array {name} is not bit-identical")
    # serialization is canonical: same program, same bytes
    if serialize_program(rt) != blob:
        errs.append("re-serializing the roundtripped program changed bytes")
    try:
        deserialize_program(blob[:-2], art, cache=False)
        errs.append("truncated envelope was accepted")
    except ProgramIOError:
        pass
    return OracleOutcome("program-io", "*", not errs, "; ".join(errs),
                         {"envelope_bytes": len(blob)})


def _transport_oracle(art, seed: int) -> OracleOutcome:
    """Transport conformance: *detected-or-bit-exact* under packet faults.

    Runs a seed-rotated window of the fault-proxy scenarios (real sockets,
    real fetcher, this case's real envelope) — every fetch must either fail
    with a typed error naming the corruption or reconstruct a program
    fingerprint-identical to the leader's. The full scenario sweep is
    ``bench_transport.py --check``'s job; the per-case window here means the
    fuzzed-artifact population collectively covers every scenario while one
    case stays cheap."""
    from repro.conformance.transport_faults import SCENARIOS, run_suite
    from repro.core.lowering import lower
    from repro.core.program_io import serialize_program

    prog = lower(art)
    blob = serialize_program(prog)
    # stale-replay needs a second artifact's envelope; the bench covers it
    pool = [sc for sc in SCENARIOS if sc.kind != "stale"]
    start = seed % len(pool)
    window = tuple(pool[(start + j) % len(pool)] for j in range(4))
    verdicts = run_suite(blob, art, prog.fingerprint,
                         scenarios=window, seed=seed)
    bad = [v for v in verdicts if not v["ok"]]
    detail = "; ".join(
        f"{v['scenario']}: expected {v['expect']}, got {v['outcome']} "
        f"({v['detail']})" for v in bad)
    return OracleOutcome(
        "transport", "*", not bad, detail,
        {"scenarios": len(verdicts),
         "detected": sum(v["outcome"] == "detected" for v in verdicts),
         "bitexact": sum(v["outcome"] == "bitexact" for v in verdicts)})


def _telemetry_oracle(case: FuzzedCase, py_slice: int) -> OracleOutcome:
    """Telemetry conformance (``telemetry_consistent``): spans are part of
    the measurement surface, so they get the same differential treatment as
    outputs — repeatable bit for bit, implementation-independent, scoped,
    and reconciled against the cost model they claim to project."""
    from repro.telemetry import SCOPES, Tracer
    from repro.telemetry import trace as ttrace

    art, images, times = case.artifact, case.images, case.times
    T = int(art.m("encode", "T"))
    e_max = int(art.m("events", "e_max"))
    n_pad = int(art.m("codesign", "n_pad"))
    imgs = images[:py_slice]
    errs: list[str] = []

    def traced_run(spec: str) -> Tracer:
        t = Tracer()
        prev = ttrace.install(t)
        try:
            make_runtime(art, spec).forward(imgs)
        finally:
            ttrace.install(prev)
        return t

    # 1) repeatability: two seeded runs → bit-identical canonical trees
    t1 = traced_run("board")
    t2 = traced_run("board")
    if t1.fingerprint() != t2.fingerprint():
        errs.append("two identical seeded board runs produced different "
                    "canonical span trees (nondeterminism in a canonical "
                    "field — wall clocks/meta belong elsewhere)")

    # 2) implementation independence: the per-image python scheduler and the
    #    vectorized fast path must project the SAME canonical tree
    tp = traced_run("board-py")
    if t1.canonical() != tp.canonical():
        a, b = t1.canonical(), tp.canonical()
        bad = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
                   min(len(a), len(b)))
        errs.append(f"board-batched and board-py canonical span trees "
                    f"diverge at span {bad} "
                    f"({len(a)} vs {len(b)} spans)")

    # 3) every span carries a legal scope tag
    bad_scope = [s.name for s in t1.sorted_spans() if s.scope not in SCOPES]
    if bad_scope:
        errs.append(f"spans with illegal scope: {bad_scope[:4]}")

    # 4) logical clocks reconcile: per-image span cycles == an independent
    #    re-evaluation of the BoardCostModel from the AER queue's own counts
    cost = make_runtime(art, "board").cost
    valid = np.asarray([int(np.sum(times[b] < T)) for b in range(len(imgs))],
                       np.int64)
    stalls = np.zeros(len(imgs), np.int64)
    for b in range(len(imgs)):
        q = AEREventQueue(times[b], T, e_max)
        stalls[b] = int(sum(q.stalls_at(t) for t in range(T)))
    expect = account(valid, np.full(len(imgs), T, np.int64), stalls, n_pad,
                     cost)
    img_spans = sorted(t1.find("board.image"),
                       key=lambda s: s.attrs.get("i", -1))
    if len(img_spans) != len(imgs):
        errs.append(f"{len(img_spans)} board.image spans for "
                    f"{len(imgs)} images")
    else:
        span_cycles = np.asarray([s.attrs["cycles"] for s in img_spans],
                                 np.int64)
        if not np.array_equal(span_cycles, np.asarray(expect.cycles)):
            errs.append(f"span cycle accounts diverge from the independent "
                        f"cost-model evaluation (spans "
                        f"{span_cycles.tolist()}, model "
                        f"{np.asarray(expect.cycles).tolist()})")
        runs = t1.find("board.run")
        tot = int(np.sum(np.asarray(expect.cycles)))
        if len(runs) != 1 or int(runs[0].attrs.get("cycles", -1)) != tot:
            errs.append(f"board.run cycle total != sum of per-image "
                        f"accounts ({runs[0].attrs.get('cycles') if runs else None} "
                        f"vs {tot})")
    return OracleOutcome(
        "telemetry", "board", not errs, "; ".join(errs),
        {"spans": len(t1.sorted_spans()), "fingerprint_stable":
         int(t1.fingerprint() == t2.fingerprint())})


def _fault_recovery_oracle(case: FuzzedCase, out_ref) -> OracleOutcome:
    """Chaos conformance: serve the fuzzed images through a scheduler whose
    single lane crashes on its first batch (seeded, recoverable). The
    resilience tier must detect the fault, requeue the batch, scrub/rebuild
    the lane, and serve EVERY request with a label bit-exact to the
    reference — and the recovery ledger must show it happened."""
    from repro.faults.plan import FaultPlan
    from repro.serving.scheduler import ServingScheduler

    images = case.images
    B = images.shape[0]
    plan = FaultPlan(seed=case.seed, crash_batches=(0,))
    errs: list[str] = []
    st: dict = {}
    try:
        with ServingScheduler(case.artifact, spec="reference", workers=1,
                              max_batch=min(B, 8), max_wait_us=500.0,
                              faults=plan,
                              resilience={"backoff_s": 0.001}) as s:
            rids = [s.submit(img) for img in images]
            done = s.drain()
            st = s.stats()
        failed = [(r, done[r].error) for r in rids
                  if done[r].error is not None]
        if failed:
            errs.append(f"{len(failed)} requests errored after a recoverable "
                        f"fault (first: rid {failed[0][0]}: {failed[0][1]})")
        else:
            got = np.asarray([done[r].label for r in rids])
            want = _np(out_ref.labels)
            n_mm = int(np.sum(got != want))
            if n_mm:
                errs.append(f"post-recovery labels mismatch reference on "
                            f"{n_mm}/{B} images")
        if st.get("lane_faults", 0) < 1:
            errs.append("injected lane crash was never detected "
                        "(lane_faults == 0)")
        if st.get("requeued", 0) < 1:
            errs.append("crashed batch was not requeued (requeued == 0)")
        if st.get("lane_restarts", 0) < 1:
            errs.append("lane was never rebuilt (lane_restarts == 0)")
        if st.get("errors", 0):
            errs.append(f"{st['errors']} requests gave up despite a "
                        "one-shot recoverable fault")
        if st.get("images_out", 0) != B:
            errs.append(f"served {st.get('images_out', 0)}/{B} images")
    except Exception as e:  # noqa: BLE001 — a hang/crash IS the failure mode
        errs.append(f"serving through the fault raised "
                    f"{type(e).__name__}: {e}")
    return OracleOutcome(
        "fault-recovery", "serving", not errs, "; ".join(errs),
        {k: st.get(k, 0) for k in ("lane_faults", "requeued",
                                   "lane_restarts", "recoveries")})
