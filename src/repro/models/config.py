"""Architecture configuration — one dataclass covers the whole assigned pool.

Families: dense / moe / ssm / hybrid / audio (enc-dec) / vlm. Heterogeneous
stacks (Jamba) are expressed as a repeating *period* of sublayers scanned
``n_layers / len(period)`` times, which keeps the lowered HLO compact enough
to compile 66 dry-run cells on one CPU core.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 128
    d_ff: int = 0                    # dense FFN hidden size
    # attention flavor
    attn_window: int | None = None   # sliding-window attention (Mixtral)
    qk_norm: bool = False            # Qwen3
    qkv_bias: bool = False           # Qwen2.5
    attn_gqa_mode: str = "grouped"   # grouped | repeat (§Perf knob, layers.py)
    rope_theta: float = 1e6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_period: int = 1              # MoE every k-th layer (Jamba: 2)
    capacity_factor: float = 1.0
    moe_buf_mode: str = "e_sharded"  # e_sharded | local (§Perf knob, moe.py)
    # SSM (Mamba2 / SSD)
    ssm_d_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_n_groups: int = 1
    # hybrid layout: sublayer kinds within one period, e.g. Jamba
    layer_period: tuple[str, ...] = ()   # ("attn","mamba",... ) len divides n_layers
    # encoder-decoder (Whisper)
    enc_layers: int = 0
    dec_max_len: int = 448
    cross_len: int = 1500
    # frontend stub ([audio]/[vlm]: precomputed embeddings via input_specs)
    frontend: str | None = None      # None|"audio"|"vision"
    n_patches: int = 256             # vlm prefix patches
    # numerics / misc
    norm: str = "rmsnorm"            # rmsnorm|layernorm
    act: str = "silu"                # silu (gated) | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    optimizer: str = "adamw"         # adamw|adafactor (co-design: fits-in-HBM)
    remat: bool = True
    remat_policy: str = "full"       # full | dots | none (§Perf iteration knob)
    # ZeRO-3 weight-gather: params stay data-sharded at rest, but each scan
    # step constrains the current layer's weights to TP-only — XLA inserts a
    # per-layer weight all-gather instead of resharding ACTIVATIONS (the
    # measured dominant collective in FSDP baselines). §Perf iteration knob.
    fsdp_weight_gather: bool = False
    # emit with_sharding_constraint on mid-layer activations (q/k heads, FFN
    # hidden, MoE buffers). §Perf finding: forcing these can FIGHT GSPMD's
    # propagation and insert (B,S,d)-sized reshards per layer; False lets
    # propagation run free except at step boundaries (tokens/logits).
    activation_constraints: bool = True
    # long-context applicability (assignment: long_500k needs sub-quadratic)
    subquadratic: bool = False

    # ------------------------------------------------------------ derived
    @property
    def period(self) -> tuple[str, ...]:
        if self.layer_period:
            return self.layer_period
        if self.family == "ssm":
            return ("mamba",)
        return ("attn",)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, \
            f"{self.name}: n_layers {self.n_layers} % period {len(self.period)}"
        return self.n_layers // len(self.period)

    def is_moe_layer(self, idx_in_period: int) -> bool:
        """Whether sublayer `idx_in_period` carries a MoE FFN."""
        if self.n_experts == 0:
            return False
        return idx_in_period % self.moe_period == 0

    @property
    def d_inner(self) -> int:        # mamba
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
        d = self.d_model
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i, kind in enumerate(self.period * self.n_periods):
            if kind == "attn":
                q = d * self.n_heads * self.d_head
                kv = 2 * d * self.n_kv_heads * self.d_head
                o = self.n_heads * self.d_head * d
                total += q + kv + o
            elif kind == "mamba":
                d_in = self.d_inner
                conv_ch = d_in + 2 * self.ssm_n_groups * self.ssm_d_state
                total += d * (2 * d_in + 2 * self.ssm_n_groups * self.ssm_d_state
                              + self.ssm_heads)      # in_proj
                total += conv_ch * self.ssm_conv     # conv
                total += d_in * d                    # out_proj
            if kind in ("attn", "mamba"):
                if self.is_moe_layer(i % len(self.period)) and self.n_experts:
                    total += self.n_experts * 3 * d * self.d_ff_expert
                elif self.d_ff:
                    mult = 3 if self.act == "silu" else 2
                    total += mult * d * self.d_ff
        if self.enc_layers:  # whisper encoder + cross-attn in decoder
            enc = self.enc_layers * (4 * d * self.n_heads * self.d_head
                                     + 2 * d * self.d_ff)
            cross = self.n_layers * 4 * d * self.n_heads * self.d_head
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        dense = self.param_count()
        n_moe = sum(1 for i in range(len(self.period))
                    if self.is_moe_layer(i)) * self.n_periods
        all_experts = n_moe * self.n_experts * 3 * self.d_model * self.d_ff_expert
        active = n_moe * self.top_k * 3 * self.d_model * self.d_ff_expert
        return dense - all_experts + active
