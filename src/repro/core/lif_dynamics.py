"""Integer LIF dynamics — the single source of truth for both runtimes.

Per timestep t (all int32, deterministic):

    v      <- v - (v >> leak_shift) + I_t          # arithmetic shift leak
    fired  <- (v >= threshold) and (first == T)    # threshold compare
    first  <- t where fired else first             # first-spike latch

``first == T`` is the no-spike sentinel. Negative membrane uses arithmetic
right shift (rounds toward -inf) — chosen because it is what the fixed-point
RTL implements; both runtimes and the Pallas kernel reproduce it exactly.

The software reference runner evaluates this with a dense (T, N) current
matrix; the accelerator runtime evaluates the same recurrence over the padded
block layout (and in the fused Pallas kernel). Bit-exact agreement of
``first`` and ``v`` between the paths is asserted by tests and by the
full-test-set agreement harness.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LIFResult(NamedTuple):
    first_spike: jnp.ndarray  # (..., N) int32, T = never fired
    v_final: jnp.ndarray      # (..., N) int32


def lif_scan(currents: jnp.ndarray, thresholds: jnp.ndarray,
             leak_shift: int, T: int, return_v_history: bool = False):
    """currents: (T, ..., N) int32 synaptic input per step.

    With ``return_v_history=True`` returns ``(LIFResult, vs)`` where
    ``vs[t]`` is the membrane AFTER step t — the board emulator's batched
    latency mode gathers the membrane at each row's exit tick from it."""
    n_shape = currents.shape[1:]
    v0 = jnp.zeros(n_shape, jnp.int32)
    first0 = jnp.full(n_shape, T, jnp.int32)

    def step(carry, xs):
        v, first = carry
        t, i_t = xs
        v = v - jnp.right_shift(v, leak_shift) + i_t
        fired = (v >= thresholds) & (first == T)
        first = jnp.where(fired, t, first)
        return (v, first), (v if return_v_history else None)

    ts = jnp.arange(T, dtype=jnp.int32)
    (v, first), vs = jax.lax.scan(step, (v0, first0), (ts, currents))
    res = LIFResult(first_spike=first, v_final=v)
    return (res, vs) if return_v_history else res


def lif_scan_early_exit(currents: jnp.ndarray, thresholds: jnp.ndarray,
                        leak_shift: int, T: int) -> tuple[LIFResult, jnp.ndarray]:
    """Event-driven latency mode: stop integrating once ANY neuron has fired
    (the grouped TTFS decision is determined by the earliest spike, so later
    steps cannot change the label unless nothing ever fires — in which case
    the loop runs to T and the membrane fallback applies, exactly as in the
    full scan).

    Returns (LIFResult, steps_executed). Labels decoded from the result are
    bit-identical to the full scan's: unfired neurons keep the sentinel, and
    argmin over groups only consults the earliest time.

    Note: v_final here is the membrane AT EXIT TIME, which differs from the
    full scan's v_final when exiting early — but the membrane fallback is only
    consulted when no spike occurred, i.e. when no early exit happened, so the
    decode rule sees identical inputs either way.
    """
    n_shape = currents.shape[1:]

    def cond(state):
        t, v, first = state
        return (t < T) & jnp.all(first == T)

    def body(state):
        t, v, first = state
        i_t = jax.lax.dynamic_index_in_dim(currents, t, axis=0, keepdims=False)
        v = v - jnp.right_shift(v, leak_shift) + i_t
        fired = (v >= thresholds) & (first == T)
        first = jnp.where(fired, t, first)
        return (t + 1, v, first)

    t0 = jnp.int32(0)
    v0 = jnp.zeros(n_shape, jnp.int32)
    first0 = jnp.full(n_shape, T, jnp.int32)
    t, v, first = jax.lax.while_loop(cond, body, (t0, v0, first0))
    return LIFResult(first_spike=first, v_final=v), t
