"""Cluster program distribution: the transport grammar + leader/follower glue.

``launch.serve`` (and anything else that wants "lower once per process
group") names its transport with one string::

    tcp://HOST:PORT      network transport (distributed.transport) — the
                         multi-host leg; PORT 0 lets a leader bind an
                         ephemeral port (its handle reports the real one)
    file:///PATH | PATH  shared-filesystem transport (launch.mesh) — the
                         single-host multi-process leg

``distribute_program`` resolves the string, builds the matching
publish/fetch hooks, and runs ``broadcast_program``; the leader additionally
gets a ``LeaderHandle`` so a launch script can block until every follower
has fetched (``await_fetches``) before tearing the endpoint down — without
it, a fast leader exits and followers see connection-refused storms.
"""

from __future__ import annotations

import dataclasses

from repro.launch.mesh import broadcast_program, file_fetcher, file_publisher

TRANSPORT_GRAMMAR = "tcp://HOST:PORT | file:///PATH | PATH"


@dataclasses.dataclass(frozen=True)
class Endpoint:
    """A parsed transport spec: ``scheme`` is ``"tcp"`` or ``"file"``."""

    scheme: str
    host: str = ""
    port: int = 0
    path: str = ""

    def __str__(self) -> str:
        if self.scheme == "tcp":
            return f"tcp://{self.host}:{self.port}"
        return f"file://{self.path}"


def parse_transport(spec: str) -> Endpoint:
    """Parse a transport spec per ``TRANSPORT_GRAMMAR``; bare paths are the
    file transport (backward compatible with ``--program-envelope``)."""
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty transport spec (expected "
                         f"{TRANSPORT_GRAMMAR})")
    spec = spec.strip()
    if spec.startswith("tcp://"):
        rest = spec[len("tcp://"):]
        host, sep, port_s = rest.rpartition(":")
        if not sep or not host:
            raise ValueError(f"tcp transport {spec!r} must be "
                             f"tcp://HOST:PORT")
        try:
            port = int(port_s, 10)
        except ValueError:
            raise ValueError(f"tcp transport {spec!r}: port {port_s!r} is "
                             f"not an integer") from None
        if not 0 <= port <= 65535:
            raise ValueError(f"tcp transport {spec!r}: port {port} out of "
                             f"range [0, 65535]")
        return Endpoint(scheme="tcp", host=host, port=port)
    if spec.startswith("file://"):
        path = spec[len("file://"):]
        if not path:
            raise ValueError(f"file transport {spec!r} has an empty path")
        return Endpoint(scheme="file", path=path)
    if "://" in spec:
        scheme = spec.split("://", 1)[0]
        raise ValueError(f"unknown transport scheme {scheme!r} (expected "
                         f"{TRANSPORT_GRAMMAR})")
    return Endpoint(scheme="file", path=spec)


class LeaderHandle:
    """What a leader holds after publishing: the barrier + teardown surface.

    For the tcp transport it wraps the live ``ProgramServer``; for the file
    transport (the envelope persists on disk, nothing to keep alive or wait
    on) it is inert — ``await_fetches`` is immediately satisfied."""

    def __init__(self, server=None):
        self.server = server

    @property
    def endpoint(self) -> str | None:
        return self.server.endpoint if self.server is not None else None

    @property
    def serves(self) -> int:
        return self.server.serves if self.server is not None else 0

    def await_fetches(self, n: int, timeout_s: float = 30.0) -> bool:
        if self.server is None or n <= 0:
            return True
        return self.server.await_serves(n, timeout_s)

    def stop(self) -> None:
        if self.server is not None:
            self.server.stop()

    def __enter__(self) -> "LeaderHandle":
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def distribute_program(artifact, spec: str, *, role: str,
                       timeout_s: float = 30.0, retries: int = 3,
                       backoff_s: float = 0.05, seed: int = 0):
    """Run the leader/follower program broadcast over a named transport.

    Returns ``(program, handle)``. The handle is only meaningful to the
    leader (followers get an inert one); a tcp leader should
    ``handle.await_fetches(n)`` before exiting so followers are never
    orphaned mid-fetch, then ``handle.stop()``.

    The follower's fetch is bounded end to end: the tcp fetcher splits the
    caller's ``timeout_s`` across its connect/read deadlines and retries
    with seeded-jitter backoff; the file fetcher polls until ``timeout_s``.
    Either way a distribution failure surfaces as the typed
    ``ProgramBroadcastError`` from ``broadcast_program`` — never a hang.
    """
    if role not in ("leader", "follower"):
        raise ValueError(f"role must be 'leader' or 'follower', got {role!r}")
    ep = parse_transport(spec)
    leader = role == "leader"
    if ep.scheme == "tcp":
        from repro.distributed.transport import tcp_fetcher, tcp_publisher
        if leader:
            publish = tcp_publisher(ep.host, ep.port)
            prog = broadcast_program(artifact, leader=True, publish=publish)
            return prog, LeaderHandle(publish.server)
        # each attempt gets an equal slice of the budget so retries fit
        per_try = max(0.05, timeout_s / (retries + 1) / 2)
        fetch = tcp_fetcher(ep.host, ep.port, connect_timeout_s=per_try,
                            read_timeout_s=per_try, retries=retries,
                            backoff_s=backoff_s, seed=seed)
        return (broadcast_program(artifact, leader=False, fetch=fetch),
                LeaderHandle())
    if leader:
        prog = broadcast_program(artifact, leader=True,
                                 publish=file_publisher(ep.path))
        return prog, LeaderHandle()
    fetch = file_fetcher(ep.path, timeout_s=timeout_s)
    return (broadcast_program(artifact, leader=False, fetch=fetch),
            LeaderHandle())
