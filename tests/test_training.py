"""Training substrate: optimizers, checkpoint fault tolerance, gradient
compression, real LM training convergence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.model import LM
from repro.training import compress as C
from repro.training import lm_step, optim as O
from repro.training.checkpoint import CheckpointManager


# ------------------------------------------------------------- optimizers
def test_adamw_matches_reference_math():
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    grads = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]], jnp.float32)}
    opt = O.adamw(lr=0.01, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    state = opt.init(params)
    p1, state = opt.update(grads, state, params)
    # closed form for step 1: m_hat = g, v_hat = g^2 -> update = g/(|g|+eps)
    expect = np.asarray(params["w"]) - 0.01 * np.sign(np.asarray(grads["w"]))
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, atol=1e-5)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32), jnp.float32),
              "b": jnp.zeros((32,), jnp.float32)}
    opt = O.adafactor(lr=0.01)
    state = opt.init(params)
    assert state["f"]["w"]["vr"].shape == (64,)
    assert state["f"]["w"]["vc"].shape == (32,)
    assert state["f"]["b"]["v"].shape == (32,)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    p1, _ = opt.update(grads, state, params)
    assert np.all(np.isfinite(np.asarray(p1["w"])))


def test_optimizers_reduce_quadratic_loss():
    # adafactor's clipped relative update is sign-like: it needs more steps
    # to traverse |x0|/lr, hence the larger budget for it.
    for name, lr, steps in (("adamw", 0.05, 60), ("adafactor", 0.1, 150),
                            ("sgd", 0.02, 60)):
        opt = O.get(name, lr)
        params = {"x": jnp.asarray([3.0, -2.0], jnp.float32)}
        state = opt.init(params)
        def loss(p):
            return jnp.sum(p["x"] ** 2)
        start = float(loss(params))
        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < 0.2 * start, name


# -------------------------------------------------------------- checkpoint
def _tiny_setup(seed=0):
    cfg = reduced(get_config("yi-6b"))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(seed), jnp.float32)
    optimizer = O.adamw(lr=1e-3)
    step = jax.jit(lm_step.make_train_step(lm, optimizer))
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=16,
                                             global_batch=4))
    return cfg, lm, params, optimizer, step, pipe


def test_checkpoint_restore_bitexact_trajectory(tmp_path):
    """Kill/restore: trajectory after restore == uninterrupted trajectory."""
    cfg, lm, params, optimizer, step, pipe = _tiny_setup()
    opt_state = optimizer.init(params)
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)

    # uninterrupted: 6 steps
    p, o = params, opt_state
    for i in range(6):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        p, o, _ = step(p, o, batch)
    ref = jax.tree.leaves(p)

    # interrupted at step 3
    p2, o2 = params, opt_state
    for i in range(3):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        p2, o2, _ = step(p2, o2, batch)
    mgr.save(3, {"params": p2, "opt": o2}, meta={"note": "pre-crash"})
    del p2, o2
    # "new process": restore and continue with the SAME data stream
    target = {"params": params, "opt": opt_state}
    at, restored = mgr.restore(target)
    assert at == 3
    p3, o3 = restored["params"], restored["opt"]
    for i in range(3, 6):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        p3, o3, _ = step(p3, o3, batch)
    for a, b in zip(ref, jax.tree.leaves(p3)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c"), keep=2)
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    path = mgr.save(1, tree)
    # flip a byte in the stored array
    import glob
    victim = [f for f in glob.glob(path + "/*.npy")][0]
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        mgr.restore(tree)


def test_checkpoint_prunes_and_lists(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c"), keep=2)
    tree = {"w": np.zeros(3, np.float32)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore applies a caller-provided sharding_fn — the elastic re-mesh
    path (checkpoint saved on mesh A, restored for mesh B)."""
    mgr = CheckpointManager(str(tmp_path / "c"))
    tree = {"w": np.arange(8, dtype=np.float32)}
    mgr.save(1, tree)
    seen = []

    def sharding_fn(key, arr):
        seen.append((key, arr.shape))
        return jax.devices()[0]          # single-device placement stands in
    _, restored = mgr.restore(tree, sharding_fn=sharding_fn)
    assert seen == [("w", (8,))]
    assert np.array_equal(np.asarray(restored["w"]), tree["w"])


# -------------------------------------------------------------- compression
def test_compress_error_feedback_sums_are_preserved():
    """Over many steps, sum(decompressed) ~= sum(true grads): the residual
    carries what quantization dropped (the EF property)."""
    rng = np.random.RandomState(0)
    grads_seq = [{"w": jnp.asarray(rng.randn(32, 8) * (0.1 + i * 0.01),
                                   jnp.float32)} for i in range(20)]
    res = C.init_residual(grads_seq[0])
    sent_sum = np.zeros((32, 8), np.float32)
    true_sum = np.zeros((32, 8), np.float32)
    for g in grads_seq:
        comp, res = C.compress(g, res)
        sent_sum += np.asarray(C.decompress(comp)["w"])
        true_sum += np.asarray(g["w"])
    # |true - sent| == |final residual| <= one quantization step
    gap = np.abs(true_sum - sent_sum)
    assert np.max(gap) <= float(np.asarray(res["w"]).__abs__().max()) + 1e-5


def test_compress_wire_bytes_4x_smaller():
    g = {"w": jnp.zeros((1024, 256), jnp.float32)}
    comp, _ = C.compress(g, C.init_residual(g))
    assert C.wire_bytes(comp) < 1024 * 256 * 4 / 3.9


def test_training_with_compression_still_converges():
    cfg, lm, params, optimizer, _, pipe = _tiny_setup(seed=1)
    step_c = jax.jit(lm_step.make_train_step(lm, optimizer,
                                             compress_grads=True))
    opt_state = lm_step.make_opt_state(params, optimizer, compress_grads=True)
    losses = []
    p = params
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        p, opt_state, m = step_c(p, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


# ------------------------------------------------------ LM loss goes down
def test_lm_training_loss_decreases():
    cfg, lm, params, optimizer, step, pipe = _tiny_setup(seed=2)
    opt_state = optimizer.init(params)
    losses = []
    p, o = params, opt_state
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(i))
        p, o, m = step(p, o, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_grad_accumulation_matches_full_batch():
    cfg = reduced(get_config("yi-6b"))
    cfg = dataclasses.replace(cfg, remat=False)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(5), jnp.float32)
    optimizer = O.sgd(lr=0.1, momentum=0.0)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 16))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)))}
    s1 = jax.jit(lm_step.make_train_step(lm, optimizer, grad_accum=1))
    s2 = jax.jit(lm_step.make_train_step(lm, optimizer, grad_accum=2))
    p1, _, m1 = s1(params, optimizer.init(params), batch)
    p2, _, m2 = s2(params, optimizer.init(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_checkpoint_durability_ordering(tmp_path, monkeypatch):
    """save() must fsync every payload file AND the tmp directory entry
    BEFORE the atomic os.replace publish, and fsync the parent directory
    AFTER it — otherwise a power loss can publish an empty checkpoint or
    roll back a save() that already returned."""
    import os as os_mod

    events = []
    real_fsync, real_replace = os_mod.fsync, os_mod.replace

    def spy_fsync(fd):
        events.append(("fsync", os_mod.fstat(fd).st_mode & 0o170000))
        return real_fsync(fd)

    def spy_replace(src, dst):
        events.append(("replace", src, dst))
        return real_replace(src, dst)

    monkeypatch.setattr(os_mod, "fsync", spy_fsync)
    monkeypatch.setattr(os_mod, "replace", spy_replace)

    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    tree = {"w": np.arange(6, dtype=np.float32), "b": np.zeros(2)}
    mgr.save(1, tree)

    kinds = [e[0] for e in events]
    assert kinds.count("replace") == 1
    rep = kinds.index("replace")
    import stat
    pre = events[:rep]
    # every array file + manifest.json fsynced before publish...
    file_syncs = [e for e in pre if e[0] == "fsync"
                  and e[1] == stat.S_IFREG]
    assert len(file_syncs) == len(tree) + 1            # arrays + manifest
    # ...plus the tmp directory entry itself
    dir_syncs_pre = [e for e in pre if e[0] == "fsync"
                     and e[1] == stat.S_IFDIR]
    assert len(dir_syncs_pre) == 1
    # and exactly one directory fsync AFTER the rename pins the publish
    post = events[rep + 1:]
    assert [e[0] for e in post] == ["fsync"]
    assert post[0][1] == stat.S_IFDIR

    # the spied-on save is still a valid checkpoint
    step, back = mgr.restore({"w": np.zeros(6, np.float32),
                              "b": np.zeros(2)})
    assert step == 1 and np.array_equal(back["w"], tree["w"])
