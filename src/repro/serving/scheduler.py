"""Continuous-batching serving scheduler — the tier behind ``SNNServeEngine``.

One scheduler owns the whole request path the paper's §2.3 discipline wants
measured: an admission queue, deadline-aware micro-batch formation, N worker
lanes each owning a runtime built from a registry spec string
(``core.runtimes.make_runtime``), and per-request latency percentiles on top
of the accelerator/system scope split. The overflow→dense reroute and the
board cycle/energy account both live HERE — every front-end (the synchronous
``SNNServeEngine`` facade, the load bench's open/closed-loop drivers) goes
through the same code path, so serving semantics cannot fork per caller.

Batch formation (the continuous-batching policy):
  * a batch OPENS when a lane picks up the oldest queued request;
  * it CLOSES at ``max_batch`` requests OR ``max_wait_us`` after opening,
    whichever comes first — bounded formation latency under light load,
    full batches under heavy load;
  * every batch is zero-padded to ``max_batch`` rows so each lane runs ONE
    compiled program regardless of traffic (the artifact's padded shapes).

Worker lanes:
  * ``workers >= 1`` — that many daemon threads, each with its OWN runtime
    instance (own compiled programs, own lazy dense-fallback runtime, own
    board trace) so lanes never contend on jax state;
  * ``workers == 0`` — inline mode: no threads; ``drain()`` forms greedy
    ``max_batch``-sized batches and serves them on the calling thread via
    lane 0. Deterministic batch count — the facade's flush() semantics.

Resilience (the fault-injection subsystem's consumer — ``repro.faults``):
  * ``faults=`` takes a seeded ``FaultPlan`` (or its spec string) and splits
    it per lane; lane-fault fields drive a ``LaneFaultInjector`` around the
    serve call, static/dynamic fields ride into ``make_runtime``;
  * every lane runs a health state machine::

        healthy --fault detected--> suspect --scrub+rebuild OK--> healthy
                                       |                       (restarted)
                                       '--checks still fail--> quarantined
                                                                   |
                                             (degrade=True)        v
        degraded  <---- circuit breaker / quarantine ----  [dense fallback]

    a detected fault (worker exception, post-batch verification failure,
    watchdog timeout) requeues the in-flight batch (bounded per-request
    retries with exponential backoff; multi-request batches are re-queued
    ``solo`` so one poison request cannot re-kill its batchmates), then the
    lane is rebuilt from the pristine artifact and must pass its startup
    checks (artifact checksum + canary probes) to re-enter service;
  * detection is ``faults.detect``: artifact SHA-256 re-hash at lane
    startup / per batch, golden-canary probes per lane, board-trace
    cross-checks, membrane-ECC readout — every counter lands in ``stats()``;
  * the invariant all of this buys (the chaos bench's ``--check`` gate):
    every admitted request completes with either a bit-exact label or an
    explicit ``error`` — never a silent wrong answer, never a hang.

Bit-exactness holds regardless of batching: every runtime evaluates rows
independently, and pad rows never influence real ones, so a label served at
queue depth 60 equals the label served alone — the load bench's ``--check``
gate asserts exactly this against the software reference.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

import jax
import numpy as np

from repro.core.artifact import Artifact
from repro.core.lowering import LoweredProgram, get_cache, lower
from repro.core.runtimes import make_runtime
from repro.faults.detect import (Canary, ecc_errors, runtime_integrity_errors,
                                 trace_errors)
from repro.faults.plan import FaultPlan
from repro.telemetry import trace as ttrace
from repro.telemetry.metrics import (DEPTH_BUCKETS, LATENCY_BUCKETS_US,
                                     RECOVERY_BUCKETS_MS, MetricsRegistry)


class ServingError(RuntimeError):
    """A request completed with ``.error`` set; carries the request."""

    def __init__(self, request: "ServeRequest"):
        super().__init__(f"request {request.rid} failed after "
                         f"{request.attempts + 1} attempt(s): {request.error}")
        self.request = request


@dataclasses.dataclass
class ResilienceConfig:
    """Knobs for the scheduler's detection/recovery machinery. Defaults are
    conservative: startup checks on, per-batch verification and the watchdog
    off (they cost a detector pass / a monitor thread per batch)."""

    max_retries: int = 2          # re-serves per request before giving up
    backoff_s: float = 0.005      # base of the exponential restart backoff
    watchdog_s: float | None = None   # per-batch serve deadline (threaded)
    breaker_threshold: int = 3    # lane faults before the circuit breaker
    startup_checks: bool = True   # checksum+canary at lane (re)commission
    verify: bool = False          # post-batch detectors BEFORE completion
    canary_every: int = 0         # also run canaries every N batches (0=off)
    degrade: bool = True          # quarantined/flapping lanes → dense path

    @classmethod
    def coerce(cls, obj) -> "ResilienceConfig":
        if obj is None:
            return cls()
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(**obj)
        raise TypeError(f"cannot build a ResilienceConfig from "
                        f"{type(obj).__name__}")


@dataclasses.dataclass
class ServeRequest:
    """One admitted classification request, completed in place."""
    rid: int
    image: np.ndarray             # (N_in,) float32 in [0, 1]
    label: int | None = None      # filled at completion
    steps: int | None = None      # timesteps consumed (latency mode)
    fallback_dense: bool = False  # served via the dense reroute / degraded lane
    lane: int | None = None       # worker lane that served it
    t_submit: float = 0.0         # perf_counter at admission
    t_done: float = 0.0           # perf_counter at completion
    error: str | None = None      # set instead of label if serving failed
    attempts: int = 0             # re-serves consumed (0 = first try)
    solo: bool = False            # poison isolation: serve in a batch of one
    # telemetry handles (set only while a Tracer is installed): the request
    # root span opened at submit and the admission child closed at formation
    _span: object = dataclasses.field(default=None, repr=False, compare=False)
    _adm: object = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def latency_us(self) -> float:
        return 1e6 * (self.t_done - self.t_submit)


class _Lane:
    """One worker lane: a runtime built from the spec, plus the lane-local
    serve path (event packing, overflow reroute, board accounting) and the
    lane's health record. Each lane's counters are merged into the scheduler
    under its lock, so lanes themselves stay lock-free on the hot path."""

    def __init__(self, lane_id: int, artifact: Artifact, spec: str,
                 kernel: str | None, latency_mode: bool,
                 plan: FaultPlan | None = None,
                 program: LoweredProgram | None = None):
        self.lane_id = lane_id
        self.art = artifact              # pristine — backs scrub/reload
        self.spec = spec
        self.family, _, _ = spec.partition("-")
        self.latency_mode = bool(latency_mode)
        self.plan = plan
        # one lowering per artifact: the scheduler lowers once and every lane
        # (including watchdog-spawned replacements) reuses that program — the
        # serve-path scalars below come from it, not from repeated meta reads
        self.program = program if program is not None else lower(artifact)
        kw = {"latency_mode": latency_mode}
        if kernel is not None:
            kw["kernel"] = kernel        # None = the family's own default
        if plan is not None:
            kw["faults"] = plan          # static/dynamic injection sites
        self.runtime = make_runtime(self.program, spec, **kw)
        self._dense = None               # built lazily on first overflow
        self.T = self.program.T
        self.x_min = self.program.x_min
        self.e_max = self.program.e_max
        self.injector = None             # host-side fault site (lane faults)
        if plan is not None and plan.has_lane_faults:
            from repro.faults.models import LaneFaultInjector
            self.injector = LaneFaultInjector(plan)
        # ------------------------------------------------- health record
        self.health = "healthy"          # healthy|suspect|quarantined|degraded
        self.fault_count = 0             # detected faults (feeds the breaker)
        self.restarts = 0                # successful scrub/rebuild cycles
        self.batches_served = 0          # serve attempts (canary cadence)
        self.busy_since: float | None = None   # watchdog: batch start time
        self.current: list | None = None       # watchdog: (request, token)s
        self.hung = False                # watchdog fired on this lane
        self.retired = False             # removed from service for good
        self.degraded = False            # circuit-broken to the dense path

    # ------------------------------------------------------------- serve path
    def serve(self, images: np.ndarray, k: int, probe: bool = False) -> dict:
        """Serve a zero-padded (max_batch, N_in) buffer whose first ``k``
        rows are real traffic; returns labels/steps/fallback plus the
        lane-local stat deltas for the scheduler to merge. ``probe`` marks
        canary traffic: it takes the same datapath but does not advance the
        host-fault injector's batch clock."""
        if self.injector is not None and not probe:
            self.injector.before_batch()
        if self.degraded:
            return self._serve_dense(images, k)
        if self.family == "accelerator" and self.runtime.mode == "event":
            return self._serve_event(images, k)
        return self._serve_forward(images, k)

    def _serve_forward(self, images: np.ndarray, k: int) -> dict:
        """board / reference / dense-accelerator path: forward(images)."""
        t0 = time.perf_counter()
        out = self.runtime.forward(images)
        jax.block_until_ready(out.labels)
        delta = {"accel_s": time.perf_counter() - t0,
                 "labels": np.asarray(out.labels),
                 "steps": np.asarray(out.steps),
                 "fallback": np.zeros(len(images), bool),
                 "overflow_fallbacks": 0}
        trace = getattr(self.runtime, "last_trace", None)
        if trace is not None:
            # board family: PL cycles / dynamic energy for the REAL rows only
            # (pad rows clock too, but they are not served traffic)
            delta["board_cycles"] = int(np.sum(trace.cycles[:k]))
            delta["board_nj"] = float(np.sum(trace.energy_nj[:k]))
            delta["board_stalls"] = int(np.sum(trace.stalls[:k]))
        return delta

    def _serve_event(self, images: np.ndarray, k: int) -> dict:
        """Packed-event accelerator path with the overflow→dense reroute."""
        from repro.core import ttfs
        from repro.core.events import pack_events_batched
        import jax.numpy as jnp

        times = np.asarray(ttfs.encode_ttfs(
            jnp.asarray(images, jnp.float32), self.T, self.x_min))
        frames = pack_events_batched(times, self.T, self.e_max)
        overflow = np.asarray(frames.overflow)  # checked ONCE, on host arrays

        t0 = time.perf_counter()
        out = self.runtime.forward(frames=frames,
                                   latency_mode=self.latency_mode,
                                   check_overflow=False)
        jax.block_until_ready(out.labels)
        accel_s = time.perf_counter() - t0
        labels = np.array(out.labels)           # writable copies (reroute
        steps = np.array(out.steps)             # rows are patched below)

        bad = np.nonzero(overflow[:k])[0]
        if bad.size:
            # overflow policy: reroute those rows through the dense
            # time-batched path (same artifact, same semantics, no E_max
            # cap). Runs on the full fixed-shape padded buffer so the dense
            # program compiles once, not per distinct overflow-row count.
            self._ensure_dense()
            t0 = time.perf_counter()
            dense_out = self._dense.forward(images=images)
            jax.block_until_ready(dense_out.labels)
            accel_s += time.perf_counter() - t0
            labels[bad] = np.asarray(dense_out.labels)[bad]
            steps[bad] = np.asarray(dense_out.steps)[bad]
        return {"accel_s": accel_s, "labels": labels, "steps": steps,
                "fallback": overflow, "overflow_fallbacks": int(bad.size)}

    # ----------------------------------------------------- degraded fallback
    def _ensure_dense(self) -> None:
        if self._dense is None:
            # built from the lane's PRISTINE lowered program — a degraded
            # lane must not inherit the faulted datapath it is escaping
            # (static faults corrupt a clone inside make_runtime, never
            # the shared program)
            self._dense = make_runtime(self.program, "accelerator-batch")

    def _serve_dense(self, images: np.ndarray, k: int) -> dict:
        """Circuit-broken path: the whole batch through the dense
        time-batched runtime. Correct labels, none of the event-path
        speed — graceful degradation, flagged per request."""
        self._ensure_dense()
        t0 = time.perf_counter()
        out = self._dense.forward(images=images)
        jax.block_until_ready(out.labels)
        return {"accel_s": time.perf_counter() - t0,
                "labels": np.asarray(out.labels),
                "steps": np.asarray(out.steps),
                "fallback": np.ones(len(images), bool),
                "overflow_fallbacks": 0}


class ServingScheduler:
    """Admission queue + deadline-aware micro-batching + N worker lanes.

    ``submit()`` is thread-safe and returns immediately with a request id;
    ``result(rid)`` blocks one caller until its request completes (the
    closed-loop client API) and raises ``ServingError`` if the request
    completed with ``.error`` set; ``drain()`` blocks until the queue is
    empty and returns every completed-but-unclaimed request (the synchronous
    facade API — errored requests are returned, not raised). ``stats()``
    reports both measurement scopes plus request-latency percentiles,
    queue-depth stats, and every fault-detection/recovery counter;
    ``reset_stats()`` zeroes them (e.g. after a warmup pass, so compile time
    does not pollute percentiles).

    ``faults=`` injects a seeded ``repro.faults.FaultPlan`` (or its spec
    string, e.g. ``"crash=0,lanes=0,seed=7"``); ``resilience=`` tunes the
    detection/recovery machinery (see ``ResilienceConfig``);
    ``canary_pool=`` supplies held-out images for the golden-canary
    detector (enables canary checks at lane startup/restart)."""

    def __init__(self, artifact: Artifact | LoweredProgram, *,
                 spec: str = "accelerator-event",
                 workers: int = 0, max_batch: int = 64,
                 max_wait_us: float = 2000.0, kernel: str | None = None,
                 latency_mode: bool = False, faults=None, resilience=None,
                 canary_pool: np.ndarray | None = None):
        if workers < 0:
            raise ValueError(f"workers must be >= 0, got {workers}")
        self.spec = spec
        self.family = spec.partition("-")[0]
        self.kernel = kernel
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        self.workers = int(workers)
        self.latency_mode = bool(latency_mode)
        # lower once; every lane (and watchdog replacement) shares this
        # program, so rebuilds skip straight to the cached compiled bundle.
        # An already-lowered program passes through (the multi-host follower
        # path hands the scheduler a deserialized program directly).
        self.program = lower(artifact)
        self.art = self.program.artifact
        self.n_in = self.program.n_in
        self.plan = FaultPlan.coerce(faults)
        self.resilience = ResilienceConfig.coerce(resilience)

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._admission: collections.deque[ServeRequest] = collections.deque()
        self._completed: dict[int, ServeRequest] = {}
        self._claims: set[int] = set()       # rids owned by result() waiters
        self._outstanding: set[int] = set()  # submitted, not yet completed
        self._requests: dict[int, ServeRequest] = {}  # every outstanding req
        self._pending = 0
        self._next_rid = 0
        self._stop = False
        self._all_quarantined = False
        # every scheduler counter/gauge/histogram and the typed fault ledger
        # live in ONE registry (one internal lock), so stats() is a
        # consistent snapshot — no torn reads while lanes keep mutating
        self.metrics = MetricsRegistry()
        self._batch_seq = 0
        self.reset_stats()

        self.canary: Canary | None = None
        if canary_pool is not None or self.resilience.canary_every:
            self.canary = Canary.from_program(self.program, pool=canary_pool)
        self.lanes = [self._commission(i) for i in range(max(1, workers))]
        if all(lane.retired for lane in self.lanes):
            # persistent faults + degrade=False can retire every lane at
            # commission time: refuse admission instead of hanging drain()
            self._all_quarantined = True
        self._lane_gens = [0] * len(self.lanes)
        self._threads = [
            threading.Thread(target=self._worker, args=(lane.lane_id, 0),
                             daemon=True, name=f"serve-lane-{lane.lane_id}")
            for lane in (self.lanes if workers else [])]
        for t in self._threads:
            t.start()
        self._watchdog_thread = None
        if self._threads and self.resilience.watchdog_s:
            self._watchdog_thread = threading.Thread(
                target=self._watchdog_loop, daemon=True, name="serve-watchdog")
            self._watchdog_thread.start()

    # ---------------------------------------------------------------- client
    def submit(self, image: np.ndarray) -> int:
        image = np.asarray(image, np.float32)
        if image.shape != (self.n_in,):
            # reject malformed traffic at admission — a bad shape must never
            # reach a lane where it would poison a whole batch
            raise ValueError(f"image must have shape ({self.n_in},), got "
                             f"{image.shape}")
        with self._cv:
            if self._stop:
                raise RuntimeError("scheduler is closed")
            if self._all_quarantined:
                raise RuntimeError("all lanes quarantined — no serving "
                                   "capacity left (degrade=False)")
            rid = self._next_rid
            self._next_rid += 1
            req = ServeRequest(rid, image, t_submit=time.perf_counter())
            rec = ttrace.get()
            if rec.enabled:
                # request root span: opened here, closed by the completion
                # choke point (possibly on another thread) — begin/end, not
                # the context manager
                req._span = rec.begin("request", "system",
                                      trace=f"req-{rid:08d}",
                                      attrs={"rid": rid})
                if req._span is not None:
                    req._adm = rec.begin("admission", "system",
                                         trace=req._span.trace,
                                         parent=req._span.sid)
            self._admission.append(req)
            self._outstanding.add(rid)
            self._requests[rid] = req
            self._pending += 1
            self._sample_depth()
            self._cv.notify_all()
            return rid

    def result(self, rid: int, timeout: float | None = None) -> ServeRequest:
        """Block until request ``rid`` completes; pops and returns it (the
        closed-loop client API). Raises ``ServingError`` (carrying the
        request) if it completed with ``.error`` set. Inline mode serves the
        queue first. The rid is CLAIMED while waiting — a concurrent
        ``drain()`` will not return it out from under this caller — and a
        rid that is neither outstanding nor completed (already drained or
        returned) raises KeyError instead of blocking forever."""
        with self._cv:
            if rid not in self._completed and rid not in self._outstanding:
                raise KeyError(f"request {rid} is not outstanding — already "
                               "claimed by drain()/result() or never "
                               "submitted")
            self._claims.add(rid)
        try:
            if not self._threads:
                self._drain_inline()
            deadline = (None if timeout is None
                        else time.perf_counter() + timeout)
            with self._cv:
                while rid not in self._completed:
                    remaining = (None if deadline is None
                                 else deadline - time.perf_counter())
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(f"request {rid} not completed "
                                           f"within {timeout}s")
                    self._cv.wait(timeout=remaining)
                req = self._completed.pop(rid)
        finally:
            with self._cv:
                self._claims.discard(rid)
        if req.error is not None:
            raise ServingError(req)
        return req

    def drain(self) -> dict[int, ServeRequest]:
        """Serve/await everything queued; pop and return every completed
        request not claimed by a ``result()`` waiter."""
        if not self._threads:
            self._drain_inline()
        with self._cv:
            while self._pending:
                self._cv.wait()
            done = {rid: r for rid, r in self._completed.items()
                    if rid not in self._claims}
            for rid in done:
                del self._completed[rid]
            return done

    def close(self, drain: bool = False) -> None:
        """Stop the worker lanes. Batches in flight finish. With
        ``drain=True`` the queued backlog is served first (graceful drain);
        by default it is NOT served — its requests complete immediately with
        ``error="scheduler closed"``. Either way every admitted request is
        completed: no waiter hangs, nothing is dropped silently."""
        if drain and not self._stop:
            if self._threads:
                with self._cv:
                    while (self._pending
                           and any(t.is_alive() for t in self._threads)):
                        self._cv.wait(timeout=0.05)
            else:
                self._drain_inline()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=30.0)
        if self._watchdog_thread is not None:
            self._watchdog_thread.join(timeout=30.0)
        with self._cv:
            now = time.perf_counter()
            self._admission.clear()
            # queued AND in-flight-on-a-dead-lane: everything still
            # outstanding is error-completed so no submitter is stranded
            for rid in sorted(self._outstanding):
                r = self._requests[rid]
                r.error = "scheduler closed"
                r.t_done = now
                self._complete_locked(r)
                self._pending -= 1
            self._cv.notify_all()

    # completed-but-unclaimed backlog bound: past this, the oldest unclaimed
    # results are abandoned (counted in stats) instead of pinning their
    # request images forever in a server whose callers never drain()
    COMPLETED_WINDOW = 65536

    def _complete_locked(self, r: ServeRequest) -> None:
        """Caller holds the lock: publish a finished request, releasing its
        outstanding slot and bounding the unclaimed backlog. This is the ONE
        place a request span closes — success, error, and close() paths all
        funnel through here, so no request span can leak open."""
        sp = r._span
        if sp is not None:
            rec = ttrace.get()
            if r.error is not None:
                rec.emit("complete", "system", trace=sp.trace, parent=sp.sid,
                         attrs={"error": r.error}, meta={"lane": r.lane})
            else:
                rec.emit("complete", "system", trace=sp.trace, parent=sp.sid,
                         attrs={"label": r.label, "steps": r.steps,
                                "fallback": r.fallback_dense,
                                "attempts": r.attempts},
                         meta={"lane": r.lane})
            rec.end(sp)
            r._span = r._adm = None
        self._outstanding.discard(r.rid)
        self._requests.pop(r.rid, None)
        self._completed[r.rid] = r
        while len(self._completed) > self.COMPLETED_WINDOW:
            victim = next((rid for rid in self._completed
                           if rid not in self._claims), None)
            if victim is None:               # everything left has a waiter
                break
            del self._completed[victim]
            self.metrics.inc("abandoned_results")

    def _fail_locked(self, r: ServeRequest, tok: int, msg: str,
                     lane_id: int | None, now: float) -> None:
        """Caller holds the lock: error-complete one request (token-guarded
        so a stale thread cannot double-complete a requeued request)."""
        if r.rid not in self._outstanding or r.attempts != tok:
            return
        r.error = msg
        r.lane = lane_id
        r.t_done = now
        self._complete_locked(r)
        self._pending -= 1
        self.metrics.inc("errors")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------- batch formation
    def _form_batch(self) -> list[ServeRequest] | None:
        """Blocking formation for worker lanes: open on the oldest queued
        request, close at max_batch OR max_wait_us — whichever first.
        ``solo`` requests (poison isolation after a batch failure) always
        form a batch of one."""
        with self._cv:
            while not self._admission and not self._stop:
                self._cv.wait()
            if self._stop:                   # no NEW batches after close():
                return None                  # the backlog is failed, not served
            batch = [self._admission.popleft()]
            if batch[0].solo:
                self._sample_depth()
                return batch
            deadline = time.perf_counter() + self.max_wait_us * 1e-6
            while len(batch) < self.max_batch:
                if self._admission:
                    if self._admission[0].solo:
                        break                # isolation batch forms alone
                    batch.append(self._admission.popleft())
                    continue
                remaining = deadline - time.perf_counter()
                if self._stop or remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
            self._sample_depth()
            return batch

    def _worker(self, lane_id: int, gen: int) -> None:
        while True:
            with self._cv:
                if self._lane_gens[lane_id] != gen:
                    return   # superseded by a watchdog replacement thread
                lane = self.lanes[lane_id]
                if lane.retired:
                    return
            batch = self._form_batch()
            if batch is None:
                return
            self._serve_batch(lane, batch)

    def _drain_inline(self) -> None:
        """Inline mode: greedy max_batch-sized batches on the caller thread
        (deterministic batch count — the facade's flush() semantics)."""
        while True:
            with self._cv:
                if not self._admission:
                    return
                batch = []
                while self._admission and len(batch) < self.max_batch:
                    batch.append(self._admission.popleft())
            self._serve_batch(self.lanes[0], batch)

    # -------------------------------------------------------------- serving
    def _serve_batch(self, lane: _Lane, batch: list[ServeRequest]) -> None:
        t0 = time.perf_counter()
        k = len(batch)
        pairs = [(r, r.attempts) for r in batch]   # completion tokens
        lane.current = pairs
        lane.busy_since = t0
        lane.batches_served += 1
        rec = ttrace.get()
        bspan = lspan = None
        if rec.enabled:
            with self._lock:
                seq = self._batch_seq
                self._batch_seq += 1
            bspan = rec.begin("batch", "system", trace=f"batch-{seq:06d}",
                              attrs={"k": k, "max_batch": self.max_batch},
                              meta={"lane": lane.lane_id,
                                    "rids": [r.rid for r in batch]})
            for r, _ in pairs:
                rec.end(r._adm)     # admission ends where the batch forms
                if r._span is not None:
                    rec.emit("batch-form", "system", trace=r._span.trace,
                             parent=r._span.sid, meta={"batch": seq})
            if bspan is not None:
                lspan = rec.begin("lane", "system", trace=bspan.trace,
                                  parent=bspan.sid,
                                  meta={"lane": lane.lane_id,
                                        "health": lane.health})
        failure: str | None = None
        exc: BaseException | None = None
        delta = None
        try:
            images = np.zeros((self.max_batch, self.n_in), np.float32)
            for j, r in enumerate(batch):
                images[j] = r.image          # zero-pad to the fixed shape
            if lspan is not None:
                # context-managed so the runtime's own spans (board.forward,
                # accel.kernel, …) nest under this batch's tree
                with rec.span("runtime", "system", trace=bspan.trace,
                              parent=lspan.sid, meta={"spec": lane.spec}):
                    delta = lane.serve(images, k)
            else:
                delta = lane.serve(images, k)
            if self.resilience.verify:
                errs = self._verify_errors(lane, images)
                if errs:
                    failure = "detected fault: " + "; ".join(errs)
        except Exception as e:  # noqa: BLE001 — any serve failure is a fault
            exc = e
            failure = f"{type(e).__name__}: {e}"
        finally:
            lane.busy_since = None
            lane.current = None
        now = time.perf_counter()
        rec.end(lspan)
        if bspan is not None:
            rec.end(bspan, attrs={"failed": failure is not None})

        if failure is not None:
            if not self._threads:
                # inline mode: no retry machinery — complete with .error so
                # nothing strands, then surface to the synchronous caller
                with self._cv:
                    self.metrics.inc("lane_faults")
                    for r, tok in pairs:
                        self._fail_locked(r, tok, failure, lane.lane_id, now)
                    self._cv.notify_all()
                if exc is not None:
                    raise exc
                raise ServingError(batch[0])
            self._handle_lane_fault(lane, pairs, failure)
            return

        with self._cv:
            if self.lanes[lane.lane_id] is not lane or lane.hung:
                return  # superseded mid-serve; the watchdog requeued these
            completed = 0
            m = self.metrics
            for j, (r, tok) in enumerate(pairs):
                if r.rid not in self._outstanding or r.attempts != tok:
                    continue                 # stale: requeued/completed away
                r.label = int(delta["labels"][j])
                r.steps = int(delta["steps"][j])
                r.fallback_dense = bool(delta["fallback"][j])
                r.lane = lane.lane_id
                r.t_done = now
                self._complete_locked(r)
                m.observe("request_latency_us", r.latency_us,
                          LATENCY_BUCKETS_US)
                completed += 1
            self._pending -= completed
            m.inc("images_out", completed)
            m.inc("batches")
            m.observe("batch_fill", k, DEPTH_BUCKETS)
            m.inc("accel_s", delta["accel_s"])
            m.inc("system_s", now - t0)
            m.inc("overflow_fallbacks", delta["overflow_fallbacks"])
            m.inc("board_cycles", delta.get("board_cycles", 0))
            m.inc("board_nj", delta.get("board_nj", 0.0))
            m.inc("board_stalls", delta.get("board_stalls", 0))
            self._cv.notify_all()

    # ------------------------------------------------------------- detection
    def _verify_errors(self, lane: _Lane, images: np.ndarray) -> list[str]:
        """Post-batch detector pass, run BEFORE completion so a corrupted
        label can never escape to a caller: membrane-ECC readout, board
        trace cross-check, artifact checksum, periodic canaries."""
        if lane.degraded:
            return []                        # dense fallback: clean by build
        m = self.metrics
        errs = ecc_errors(lane.runtime)
        if errs:
            m.inc("ecc_detected")
            m.event("detector", kind="ecc", lane=lane.lane_id, n=len(errs))
        t_errs = trace_errors(lane.runtime, images)
        m.inc("trace_checks")
        if t_errs:
            m.inc("trace_failures")
            m.event("detector", kind="trace", lane=lane.lane_id,
                    n=len(t_errs))
        errs += t_errs
        i_errs = runtime_integrity_errors(lane.runtime)
        m.inc("integrity_checks")
        if i_errs:
            m.inc("integrity_failures")
            m.event("detector", kind="checksum", lane=lane.lane_id,
                    n=len(i_errs))
        errs += i_errs
        every = self.resilience.canary_every
        if (self.canary is not None and every
                and lane.batches_served % every == 0):
            errs += self._canary_errors(lane)
        return errs

    def _canary_errors(self, lane: _Lane) -> list[str]:
        """Serve the pinned canary probes through the lane's OWN datapath
        and compare against the reference labels built at startup."""
        got: list[int] = []
        try:
            imgs = self.canary.images
            for i in range(0, len(imgs), self.max_batch):
                chunk = imgs[i:i + self.max_batch]
                buf = np.zeros((self.max_batch, self.n_in), np.float32)
                buf[:len(chunk)] = chunk
                delta = lane.serve(buf, len(chunk), probe=True)
                got.extend(int(x) for x in delta["labels"][:len(chunk)])
            errs = self.canary.mismatches(got)
        except Exception as e:  # noqa: BLE001 — a crash IS a failed probe
            errs = [f"canary probe serve failed: {type(e).__name__}: {e}"]
        self.metrics.inc("canary_checks")
        if errs:
            self.metrics.inc("canary_failures")
            self.metrics.event("detector", kind="canary", lane=lane.lane_id,
                               n=len(errs))
        return errs

    def _startup_errors(self, lane: _Lane) -> list[str]:
        """Commission / quarantine re-entry checks: artifact checksum on the
        lane's in-memory copy, then the canary probes (when built)."""
        errs = runtime_integrity_errors(lane.runtime)
        self.metrics.inc("integrity_checks")
        if errs:
            self.metrics.inc("integrity_failures")
            self.metrics.event("detector", kind="checksum",
                               lane=lane.lane_id, n=len(errs))
        if self.canary is not None:
            errs = errs + self._canary_errors(lane)
        return errs

    def _warm_errors(self, lane: _Lane) -> list[str]:
        """Prime the lane's compiled programs with a zero probe batch BEFORE
        it enters service — the watchdog must never mistake first-serve
        compilation for a hang (a lane is 'ready' only once programmed, as a
        bitstream load would be). A warmup crash is a commissioning fault."""
        try:
            lane.serve(np.zeros((self.max_batch, self.n_in), np.float32), 0,
                       probe=True)
            return []
        except Exception as e:  # noqa: BLE001 — failed warmup = failed lane
            return [f"lane warmup failed: {type(e).__name__}: {e}"]

    # -------------------------------------------------------------- recovery
    def _transition(self, lane: _Lane, to: str, reason: str) -> None:
        """Move a lane's health state, recording the transition as a typed
        event in the ledger (no event for a self-transition)."""
        if lane.health != to:
            self.metrics.event("lane_transition", lane=lane.lane_id,
                               frm=lane.health, to=to, reason=reason)
        lane.health = to

    def _commission(self, lane_id: int) -> _Lane:
        """Build lane ``lane_id`` and gate it through the startup checks: a
        lane that fails (e.g. an SEU already in its BRAM image) is scrubbed
        and rebuilt once; if the fault survives the rebuild (persistent), it
        is quarantined — degraded to the dense path when allowed."""
        plan = self.plan.for_lane(lane_id) if self.plan is not None else None
        lane = _Lane(lane_id, self.art, self.spec, self.kernel,
                     self.latency_mode, plan, program=self.program)
        errs = self._warm_errors(lane)
        if not errs and self.resilience.startup_checks:
            errs = self._startup_errors(lane)
        if not errs:
            return lane
        t0 = time.perf_counter()
        self.metrics.inc("lane_faults")
        fresh = _Lane(lane_id, self.art, self.spec, self.kernel,
                      self.latency_mode,
                      plan.after_scrub() if plan is not None else None,
                      program=self.program)
        fresh.fault_count = 1
        fresh.restarts = 1
        errs = self._warm_errors(fresh)
        if not errs and self.resilience.startup_checks:
            errs = self._startup_errors(fresh)
        if not errs:
            self.metrics.inc("lane_restarts")
            self.metrics.inc("recoveries")
            self.metrics.observe("recovery_ms",
                                 1e3 * (time.perf_counter() - t0),
                                 RECOVERY_BUCKETS_MS)
            return fresh
        self._transition(fresh, "quarantined", "startup checks failed")
        self.metrics.inc("quarantines")
        if self.resilience.degrade:
            self._degrade(fresh)
        else:
            fresh.retired = True
        return fresh

    def _handle_lane_fault(self, lane: _Lane, pairs: list, reason: str
                           ) -> None:
        """Threaded fault path: requeue-or-fail the batch, then take the
        lane through suspect → (restarted | quarantined | degraded)."""
        t_fault = time.perf_counter()
        with self._cv:
            if self.lanes[lane.lane_id] is not lane or lane.hung:
                self._cv.notify_all()
                return  # the watchdog superseded this lane mid-serve
            self._transition(lane, "suspect", "fault detected")
            lane.fault_count += 1
            self.metrics.inc("lane_faults")
            self._requeue_locked(pairs, reason, lane.lane_id)
            self._cv.notify_all()
        self._recover_lane(lane, t_fault)

    def _requeue_locked(self, pairs: list, reason: str, lane_id: int) -> None:
        """Caller holds the lock: push a failed batch's requests back to the
        FRONT of the admission queue (bounded retries; batches of more than
        one requeue ``solo`` so a poison request cannot re-kill batchmates)."""
        now = time.perf_counter()
        isolate = len(pairs) > 1
        for r, tok in reversed(pairs):
            if r.rid not in self._outstanding or r.attempts != tok:
                continue                     # stale token: already handled
            r.attempts += 1
            if r.attempts > self.resilience.max_retries:
                r.attempts -= 1              # restore for the error message
                self._fail_locked(r, tok, f"{reason} (gave up after "
                                  f"{r.attempts + 1} attempts)", lane_id, now)
                continue
            if isolate:
                r.solo = True
            if r._span is not None:
                ttrace.get().emit("requeue", "system", trace=r._span.trace,
                                  parent=r._span.sid,
                                  attrs={"attempt": r.attempts},
                                  meta={"lane": lane_id,
                                        "reason": reason[:120]})
            self._admission.appendleft(r)
            self.metrics.inc("requeued")

    def _recover_lane(self, lane: _Lane, t_fault: float) -> None:
        """Scrub/reload recovery: exponential backoff, rebuild the lane's
        runtime from the pristine artifact, re-gate through the startup
        checks. Flapping lanes hit the circuit breaker and degrade."""
        res = self.resilience
        time.sleep(min(res.backoff_s * (2 ** min(lane.restarts, 6)), 1.0))
        if res.degrade and lane.fault_count >= res.breaker_threshold:
            self._degrade(lane)              # circuit breaker: stop flapping
            return
        fresh = None
        errs: list[str] = []
        try:
            fresh = _Lane(lane.lane_id, self.art, self.spec, self.kernel,
                          self.latency_mode,
                          lane.plan.after_scrub() if lane.plan is not None
                          else None, program=self.program)
            errs = self._warm_errors(fresh)
            if not errs and res.startup_checks:
                errs = self._startup_errors(fresh)
        except Exception as e:  # noqa: BLE001 — a failed rebuild quarantines
            errs = [f"lane rebuild failed: {type(e).__name__}: {e}"]
        with self._cv:
            if self.lanes[lane.lane_id] is not lane:
                return
            if fresh is not None and not errs:
                fresh.fault_count = lane.fault_count
                fresh.restarts = lane.restarts + 1
                self.lanes[lane.lane_id] = fresh
                self.metrics.inc("lane_restarts")
                self.metrics.inc("recoveries")
                self.metrics.observe(
                    "recovery_ms", 1e3 * (time.perf_counter() - t_fault),
                    RECOVERY_BUCKETS_MS)
                self.metrics.event("lane_transition", lane=lane.lane_id,
                                   frm="suspect", to="healthy",
                                   reason="scrub+rebuild passed checks")
                self._cv.notify_all()
                return
            self._transition(lane, "quarantined", "rebuild failed checks")
            self.metrics.inc("quarantines")
            self._cv.notify_all()
        if res.degrade:
            self._degrade(lane)
        else:
            self._retire(lane)

    def _degrade(self, lane: _Lane) -> None:
        """Circuit breaker: route the lane's traffic through the dense
        fallback runtime (built from the pristine artifact) and disarm any
        host-fault injector — correctness preserved, event path abandoned."""
        try:
            lane._ensure_dense()
        except Exception:  # noqa: BLE001 — no fallback either: retire
            self._retire(lane)
            return
        with self._cv:
            lane.degraded = True
            self._transition(lane, "degraded", "circuit breaker")
            self.metrics.event("breaker_trip", lane=lane.lane_id,
                               fault_count=lane.fault_count)
            if lane.injector is not None:
                lane.injector.disarm()
            self.metrics.inc("breaker_degraded")
            self._cv.notify_all()

    def _retire(self, lane: _Lane) -> None:
        """Remove a lane from service for good. If that was the last one,
        fail the queue rather than letting it hang forever. (During
        ``__init__`` commissioning ``self.lanes`` does not exist yet; the
        all-retired case there is handled after the lane list is built.)"""
        with self._cv:
            lane.retired = True
            self._transition(lane, "quarantined", "retired from service")
            lanes = getattr(self, "lanes", None)
            if lanes is not None and all(ln.retired for ln in lanes) \
                    and getattr(self, "_threads", None):
                self._all_quarantined = True
                now = time.perf_counter()
                while self._admission:
                    r = self._admission.popleft()
                    self._fail_locked(r, r.attempts,
                                      "all lanes quarantined", None, now)
            self._cv.notify_all()

    # -------------------------------------------------------------- watchdog
    def _watchdog_loop(self) -> None:
        """Monitor thread: a lane whose batch exceeds ``watchdog_s`` is
        declared hung — its in-flight requests are requeued immediately and
        a replacement lane (fresh thread, scrubbed runtime) takes its slot;
        the hung thread's eventual results are discarded by token checks."""
        w = float(self.resilience.watchdog_s)
        tick = max(w / 4.0, 0.002)
        while True:
            victims = []
            with self._cv:
                if self._stop:
                    return
                now = time.perf_counter()
                for lane in list(self.lanes):
                    b = lane.busy_since
                    if b is not None and now - b > w and not lane.hung:
                        lane.hung = True
                        self._transition(lane, "suspect", "watchdog timeout")
                        lane.fault_count += 1
                        self.metrics.inc("lane_faults")
                        self.metrics.inc("watchdog_timeouts")
                        self._requeue_locked(
                            lane.current or [],
                            f"watchdog: batch exceeded {w:.3f}s on lane "
                            f"{lane.lane_id}", lane.lane_id)
                        victims.append((lane, now))
                if victims:
                    self._cv.notify_all()
            for lane, t_fault in victims:
                self._replace_hung_lane(lane, t_fault)
            time.sleep(tick)

    def _replace_hung_lane(self, lane: _Lane, t_fault: float) -> None:
        fresh = None
        errs: list[str] = []
        try:
            fresh = _Lane(lane.lane_id, self.art, self.spec, self.kernel,
                          self.latency_mode,
                          lane.plan.after_scrub() if lane.plan is not None
                          else None, program=self.program)
            errs = self._warm_errors(fresh)
            if not errs and self.resilience.startup_checks:
                errs = self._startup_errors(fresh)
        except Exception as e:  # noqa: BLE001
            errs = [f"lane rebuild failed: {type(e).__name__}: {e}"]
        spawn = None
        with self._cv:
            if self.lanes[lane.lane_id] is not lane:
                return
            if fresh is not None and not errs:
                fresh.fault_count = lane.fault_count
                fresh.restarts = lane.restarts + 1
                self.lanes[lane.lane_id] = fresh
                self._lane_gens[lane.lane_id] += 1
                gen = self._lane_gens[lane.lane_id]
                self.metrics.inc("lane_restarts")
                self.metrics.inc("recoveries")
                self.metrics.observe(
                    "recovery_ms", 1e3 * (time.perf_counter() - t_fault),
                    RECOVERY_BUCKETS_MS)
                self.metrics.event("lane_transition", lane=lane.lane_id,
                                   frm="suspect", to="healthy",
                                   reason="hung lane replaced")
                spawn = threading.Thread(
                    target=self._worker, args=(lane.lane_id, gen),
                    daemon=True, name=f"serve-lane-{lane.lane_id}r{gen}")
                self._threads.append(spawn)
            else:
                self._transition(lane, "quarantined",
                                 "hung-lane replacement failed checks")
                self.metrics.inc("quarantines")
            self._cv.notify_all()
        if spawn is not None:
            spawn.start()
        else:
            # the hung thread still owns the old lane object, so the breaker
            # cannot reuse it — a failed replacement retires the slot
            self._retire(lane)

    # ---------------------------------------------------------------- stats
    def _sample_depth(self) -> None:
        d = len(self._admission)
        self.metrics.observe("queue_depth", d, DEPTH_BUCKETS)
        self.metrics.set_max("queue_depth_peak", d)

    # percentile window: enough to hold any bench run exactly, bounded so a
    # long-running server cannot leak memory (percentiles become a sliding
    # window over the most recent requests past this point)
    LATENCY_WINDOW = 65536

    def reset_stats(self) -> None:
        """Zero the registry in place (post-warmup semantics) and eagerly
        register the fixed-bucket histograms so their boundaries are pinned
        once, at reset, not wherever the first observation lands."""
        m = self.metrics
        m.reset()
        m.histogram("request_latency_us", LATENCY_BUCKETS_US,
                    window=self.LATENCY_WINDOW)
        m.histogram("recovery_ms", RECOVERY_BUCKETS_MS)
        m.histogram("batch_fill", DEPTH_BUCKETS)
        m.histogram("queue_depth", DEPTH_BUCKETS)

    def stats(self) -> dict:
        """Legacy-shaped view over one consistent ``metrics.snapshot()`` —
        every key the pre-telemetry scheduler reported, same semantics, but
        all totals were true at the same instant (no torn reads)."""
        with self._lock:
            snap = self.metrics.snapshot()
            lane_health = [lane.health for lane in self.lanes]
        n = int(snap.get("images_out", 0))
        # ONE denominator guard for every per-image rate (board and
        # accelerator branches used to disagree: `if n` vs `max(1, n)`)
        def per_image(x):
            return x / n if n else 0.0
        accel_s = float(snap.get("accel_s", 0.0))
        system_s = float(snap.get("system_s", 0.0))
        batches = int(snap.get("batches", 0))
        st = {
            "spec": self.spec,
            "workers": self.workers,
            "max_batch": self.max_batch,
            "max_wait_us": self.max_wait_us,
            "accelerator_s": accel_s,
            "system_s": system_s,
            "host_overhead_s": max(0.0, system_s - accel_s),
            "images_out": n,
            "overflow_fallbacks": int(snap.get("overflow_fallbacks", 0)),
            "errors": int(snap.get("errors", 0)),
            "abandoned_results": int(snap.get("abandoned_results", 0)),
            "batches": batches,
            "accel_us_per_image": per_image(1e6 * accel_s),
            "system_us_per_image": per_image(1e6 * system_s),
            "p50_latency_us": snap.get("request_latency_us_p50", 0.0),
            "p95_latency_us": snap.get("request_latency_us_p95", 0.0),
            "p99_latency_us": snap.get("request_latency_us_p99", 0.0),
            "mean_latency_us": snap.get("request_latency_us_mean", 0.0),
            "queue_depth_mean": snap.get("queue_depth_mean", 0.0),
            "queue_depth_peak": int(snap.get("queue_depth_peak", 0)),
            "batch_fill_mean": snap.get("batch_fill_mean", 0.0),
            # ---- resilience ledger (counters from the same snapshot) ----
            "lane_faults": int(snap.get("lane_faults", 0)),
            "requeued": int(snap.get("requeued", 0)),
            "watchdog_timeouts": int(snap.get("watchdog_timeouts", 0)),
            "lane_restarts": int(snap.get("lane_restarts", 0)),
            "quarantines": int(snap.get("quarantines", 0)),
            "breaker_degraded": int(snap.get("breaker_degraded", 0)),
            "recoveries": int(snap.get("recoveries", 0)),
            "recovery_ms_mean": snap.get("recovery_ms_mean", 0.0),
            "integrity_checks": int(snap.get("integrity_checks", 0)),
            "integrity_failures": int(snap.get("integrity_failures", 0)),
            "canary_checks": int(snap.get("canary_checks", 0)),
            "canary_failures": int(snap.get("canary_failures", 0)),
            "trace_checks": int(snap.get("trace_checks", 0)),
            "trace_failures": int(snap.get("trace_failures", 0)),
            "ecc_detected": int(snap.get("ecc_detected", 0)),
            "lane_health": lane_health,
            # ---- telemetry tier ----
            "events_total": int(snap.get("events_total", 0)),
            "events_dropped": int(snap.get("events_dropped", 0)),
        }
        # program-cache residency for the process this scheduler runs in —
        # an ops view: growing evictions under steady traffic means the
        # byte budget is thrashing live programs
        cache_stats = get_cache().stats()
        st["program_cache_bytes"] = int(cache_stats["bytes"])
        st["program_cache_evictions"] = int(cache_stats["evictions"])
        # transport health for the same process — how this scheduler's
        # program arrived (and whether followers are retrying/failing to
        # fetch from here). Lazy import: schedulers in single-host launches
        # never pay for the transport module.
        from repro.distributed.transport import metrics_snapshot
        tsnap = metrics_snapshot()
        st["transport_publishes"] = int(tsnap.get("publishes", 0))
        st["transport_serves"] = int(tsnap.get("serves", 0))
        st["transport_fetches"] = int(tsnap.get("fetches", 0))
        st["transport_fetch_bytes"] = int(tsnap.get("fetch_bytes", 0))
        st["transport_fetch_retries"] = int(tsnap.get("fetch_retries", 0))
        st["transport_fetch_failures"] = int(tsnap.get("fetch_failures", 0))
        st["transport_fetch_ms_p95"] = float(tsnap.get("fetch_ms_p95", 0.0))
        if self.family == "board":
            board_cycles = int(snap.get("board_cycles", 0))
            cost = getattr(self.lanes[0].runtime, "cost", None)
            clock = cost.clock_hz if cost is not None else 1.0
            st.update({
                "board_cycles": board_cycles,
                "board_stalls": int(snap.get("board_stalls", 0)),
                "board_cycles_per_image": per_image(board_cycles),
                "board_model_us_per_image":
                    per_image(1e6 * board_cycles / clock),
                "board_nj_per_image": per_image(snap.get("board_nj", 0.0)),
            })
        return st
