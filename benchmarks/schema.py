"""Shared schema for ``results/bench/*.json`` — keeps files comparable
across PRs.

Every bench emits a flat list of row dicts through ``common.emit``, which
validates here before writing. The contract is deliberately small:

  * rows is a non-empty list of dicts;
  * every row carries ``scope`` (str — which measurement scope the numbers
    belong to: accelerator / system / host / engine / board / planner /
    agreement / paper-reference, the paper's §2.3 discipline);
  * every row carries an identity field naming what was measured — one of
    ``runtime``, ``path``, ``platform``, ``config``, ``stage``;
  * every row carries at least one METRIC: a key whose underscore-separated
    tokens include a unit (s, us, ms, hz, nj, pj, pct, bytes, cycles, img,
    image) — e.g. ``us_per_image``, ``energy_nj_img``, ``vmem_bytes``;
  * values are JSON scalars (or lists of them): no nested dicts, so rows
    diff cleanly — with ONE structured exception: an optional ``telemetry``
    block (``{"span_count": int, "dropped_spans": int, "overhead_pct":
    float}``, any subset) carrying the row's tracing account. It is the
    only nested dict the schema admits, and its keys are closed so it
    cannot become a dumping ground.

Violations raise ``SchemaError`` naming the file, row index, and reason.
"""

from __future__ import annotations

import numpy as np

ID_FIELDS = ("runtime", "path", "platform", "config", "stage")
UNIT_TOKENS = {"s", "us", "ms", "hz", "nj", "pj", "pct", "bytes", "cycles",
               "img", "image"}
# numpy scalars are accepted — emit() serializes them via json default=float
_SCALARS = (str, int, float, bool, type(None), np.integer, np.floating,
            np.bool_)
# the one structured field: closed key set, numeric values only
TELEMETRY_KEYS = {"span_count", "dropped_spans", "overhead_pct"}
_NUMERIC = (int, float, np.integer, np.floating)


class SchemaError(ValueError):
    pass


def is_metric(key: str) -> bool:
    return any(tok in UNIT_TOKENS for tok in key.split("_"))


def validate_rows(name: str, rows) -> None:
    if not isinstance(rows, list) or not rows:
        raise SchemaError(f"{name}: rows must be a non-empty list, "
                          f"got {type(rows).__name__}")
    for i, row in enumerate(rows):
        where = f"{name}.json row {i}"
        if not isinstance(row, dict):
            raise SchemaError(f"{where}: not a dict")
        if not isinstance(row.get("scope"), str):
            raise SchemaError(f"{where}: missing required str field 'scope'")
        if not any(f in row for f in ID_FIELDS):
            raise SchemaError(f"{where}: needs an identity field, one of "
                              f"{ID_FIELDS}")
        if not any(is_metric(k) for k in row):
            raise SchemaError(f"{where}: no metric field (a key with a unit "
                              f"token from {sorted(UNIT_TOKENS)})")
        for k, v in row.items():
            if k == "telemetry":
                _validate_telemetry(where, v)
                continue
            ok = isinstance(v, _SCALARS) or (
                isinstance(v, list) and all(isinstance(x, _SCALARS) for x in v))
            if not ok:
                raise SchemaError(f"{where}: field {k!r} is not a JSON "
                                  f"scalar or list of scalars "
                                  f"({type(v).__name__})")


def _validate_telemetry(where: str, v) -> None:
    if not isinstance(v, dict) or not v:
        raise SchemaError(f"{where}: 'telemetry' must be a non-empty dict "
                          f"with keys from {sorted(TELEMETRY_KEYS)}")
    extra = set(v) - TELEMETRY_KEYS
    if extra:
        raise SchemaError(f"{where}: 'telemetry' has unknown keys "
                          f"{sorted(extra)} (allowed: "
                          f"{sorted(TELEMETRY_KEYS)})")
    for k, x in v.items():
        if not isinstance(x, _NUMERIC) or isinstance(x, bool):
            raise SchemaError(f"{where}: telemetry.{k} must be numeric, "
                              f"got {type(x).__name__}")
